"""Paper Table 1: intervention-framework overhead.

The paper compares NNsight to hook libraries (baukit/pyvene/TransformerLens)
and finds near-identical setup + activation-patching runtime — i.e. the
intervention *mechanism* costs nothing over raw hooks.  The JAX analogues:

  plain            jitted forward, no interventions (floor)
  interleaved      OUR mechanism: graph compiled into the program
  eager_hooks      torch-hook-style: Python callbacks, no jit (what eager
                   interpretation of the graph costs — the paper's world)
  collect_modify   two-pass: jitted collect-all-activations, modify on host,
                   jitted re-inject (a common JAX workaround without taps)

Claim reproduced if: interleaved ≈ plain (overhead ~0) and beats the
non-compiled alternatives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, build, ioi_batch, timeit
from repro.core import analysis, taps
from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import InterleaveState, Interleaver, run_interleaved
from repro.models import registry as R

LAYER, TOK_A, TOK_B = 4, 5, 6


def patch_graph(cfg) -> InterventionGraph:
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=LAYER)
    src = g.add("getitem", Ref(t.id), (0, TOK_A, slice(None)))
    upd = g.add(
        "update_path", Ref(t.id), ((1, TOK_B, slice(None)),), Ref(src.id)
    )
    g.add("tap_set", Ref(upd.id), site="layers.output", layer=LAYER)
    o = g.add("tap_get", site="logits")
    s = g.add("save", Ref(o.id))
    g.mark_saved("out", s)
    return g


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    tokens = jnp.asarray(ioi_batch(cfg))
    schedule = model.site_schedule("unrolled")
    g = patch_graph(cfg)

    def model_fn(p, t):
        return model.forward(p, {"tokens": t}, mode="unrolled")["logits"]

    out: list[Row] = []

    # plain forward (floor)
    plain = jax.jit(model_fn)
    jax.block_until_ready(plain(params, tokens))
    m, s = timeit(lambda: jax.block_until_ready(plain(params, tokens)))
    floor = m
    out.append(Row("table1/plain_forward", m * 1e6, f"std={s*1e6:.1f}us"))

    # interleaved (ours)
    @jax.jit
    def inter(p, t):
        _, saves, _ = run_interleaved(model_fn, g, schedule, (p, t), {})
        return saves["out"]

    jax.block_until_ready(inter(params, tokens))
    m, s = timeit(lambda: jax.block_until_ready(inter(params, tokens)))
    out.append(Row("table1/interleaved", m * 1e6,
                   f"overhead={100*(m-floor)/floor:.1f}%"))
    solo = m

    # static preflight (repro.core.analysis): the per-trace analyze pass
    # every layer runs before executing — site avals are captured ONCE per
    # batch signature (jax.eval_shape, cached), so steady state is pure
    # graph analysis.  Bar: a few percent of one solo trace.
    site_avals = analysis.capture_forward_avals(
        model_fn, (params, tokens)
    )
    order = list(schedule.order)
    analysis.analyze(g, site_order=order, site_avals=site_avals)
    m, s = timeit(
        lambda: analysis.analyze(g, site_order=order, site_avals=site_avals)
    )
    out.append(Row("table1/preflight_analyze", m * 1e6,
                   f"vs_solo_trace={100*m/solo:.1f}%"))

    # eager hook-style (graph interpreted per call, no jit)
    def eager():
        _, saves, _ = run_interleaved(model_fn, g, schedule, (params, tokens), {})
        return jax.block_until_ready(saves["out"])

    eager()
    m, s = timeit(eager, n=5)
    out.append(Row("table1/eager_hooks", m * 1e6,
                   f"overhead={100*(m-floor)/floor:.1f}%"))

    # two-pass collect+modify (no tap infrastructure)
    @jax.jit
    def collect(p, t):
        acts = {}

        class Cap:
            def on_site(self, name, value, layer=None):
                if name == "layers.output":
                    acts[layer] = value
                return value

            def scan_collect_values(self):
                return {}

            def deliver_scan(self, ys):
                pass

        taps.push_state(Cap())
        try:
            logits = model_fn(p, t)
        finally:
            taps.pop_state()
        return logits, acts[LAYER]

    @jax.jit
    def reinject(p, t, injected):
        class Inj:
            def on_site(self, name, value, layer=None):
                if name == "layers.output" and layer == LAYER:
                    return injected
                return value

            def scan_collect_values(self):
                return {}

            def deliver_scan(self, ys):
                pass

        taps.push_state(Inj())
        try:
            return model_fn(p, t)
        finally:
            taps.pop_state()

    def two_pass():
        _, h = collect(params, tokens)
        h = np.array(h)  # host copy (the point: data leaves the device)
        h[1, TOK_B] = h[0, TOK_A]
        return jax.block_until_ready(reinject(params, tokens, jnp.asarray(h)))

    two_pass()
    m, s = timeit(two_pass, n=5)
    out.append(Row("table1/collect_modify_2pass", m * 1e6,
                   f"overhead={100*(m-floor)/floor:.1f}%"))

    # correctness cross-check: interleaved == 2-pass
    a = np.asarray(inter(params, tokens))
    b = np.asarray(two_pass())
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
