# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: python -m benchmarks.run [--only substr]

One module per paper table/figure:
  table1_framework_overhead  -> paper Table 1
  fig6_remote                -> paper Fig. 6a/6b + Table 2
  fig6c_petals_comparison    -> paper Fig. 6c
  fig9_concurrent_users      -> paper Fig. 9 (+ beyond-paper parallel mode)
  cotenancy_ragged           -> ragged traffic: sequential vs exact-match vs
                                padding-aware parallel co-tenancy
  kernel_bench               -> kernels/fallbacks microbench
"""
import argparse
import sys
import traceback

MODULES = [
    "benchmarks.table1_framework_overhead",
    "benchmarks.fig6_remote",
    "benchmarks.fig6c_petals_comparison",
    "benchmarks.fig9_concurrent_users",
    "benchmarks.cotenancy_ragged",
    "benchmarks.gen_decode",
    "benchmarks.kernel_bench",
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = []
    import importlib

    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = importlib.import_module(mod_name)
            for row in mod.rows():
                print(row.csv(), flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(mod_name)
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
