# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: python -m benchmarks.run [--only substr] [--json-dir D]

One module per paper table/figure:
  table1_framework_overhead  -> paper Table 1
  fig6_remote                -> paper Fig. 6a/6b + Table 2
  fig6c_petals_comparison    -> paper Fig. 6c
  fig9_concurrent_users      -> paper Fig. 9 (+ beyond-paper parallel mode)
  cotenancy_ragged           -> ragged traffic: sequential vs exact-match vs
                                padding-aware parallel co-tenancy
  cotenancy_continuous       -> staggered arrivals: sequential vs burst-drain
                                vs continuous (slot-table) batching
  paged_memory               -> paged vs dense KV at an equal cell budget:
                                peak concurrency + p95 under mixed lengths
  invoke_batching            -> paper Fig. 3 multi-invoke API: N solo traces
                                vs one N-invoke trace (one merged forward)
  fused_decode               -> whole decode loop as ONE lax.scan dispatch
                                vs eager per-step (plain + steered)
  compiled_islands           -> log/grad/stop workloads on the fused path
                                vs the eager islands they used to be
  live_serving               -> 200 real client threads through the live
                                threaded front door (Poisson arrivals,
                                streaming, backpressure, zero recompiles)
  chaos_serving              -> the same Poisson load under a seeded fault
                                plan (engine crashes, lost messages, alloc
                                bursts): termination, bit-exact recovery,
                                zero post-restart recompiles
  kernel_bench               -> kernels/fallbacks microbench

Besides the CSV on stdout, every module's rows are written to
``<json-dir>/BENCH_<module>.json`` (timings + any machine-readable stats the
module attaches via ``Row.extra``) so the perf trajectory is tracked across
PRs; disable with ``--json-dir ''``.
"""
import argparse
import json
import os
import sys
import traceback

MODULES = [
    "benchmarks.table1_framework_overhead",
    "benchmarks.fig6_remote",
    "benchmarks.fig6c_petals_comparison",
    "benchmarks.fig9_concurrent_users",
    "benchmarks.cotenancy_ragged",
    "benchmarks.cotenancy_continuous",
    "benchmarks.paged_memory",
    "benchmarks.invoke_batching",
    "benchmarks.gen_decode",
    "benchmarks.fused_decode",
    "benchmarks.compiled_islands",
    "benchmarks.live_serving",
    "benchmarks.chaos_serving",
    "benchmarks.kernel_bench",
]


def write_json(json_dir: str, mod_name: str, rows) -> None:
    short = mod_name.rsplit(".", 1)[-1]
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{short}.json")
    payload = {"benchmark": short, "rows": [r.to_json() for r in rows]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json-dir", default="benchmarks/out",
        help="directory for BENCH_<name>.json files ('' disables)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = []
    import importlib

    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = importlib.import_module(mod_name)
            rows = list(mod.rows())
            for row in rows:
                print(row.csv(), flush=True)
            if args.json_dir:
                write_json(args.json_dir, mod_name, rows)
        except Exception:
            traceback.print_exc()
            failures.append(mod_name)
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
