"""Beyond-paper ablation: SHARDED interventions vs the paper's DTensor
gather (Appendix B.2: NDIF "converts DTensors to full tensors using
torch.distributed gather operations, injects the full tensors into the
intervention graph, and then re-shards").

Here the intervention graph is compiled INTO the sharded program, so tap
values keep the activation's sharding and no gather is needed.  This
benchmark lowers a serve step with an interleaved graph (save + edit on a
mid-layer output) twice on the production mesh:

  sharded   — our default: tap values inherit shardings;
  gathered  — paper-faithful: every tapped value is forced to full
              replication at the tap (with_sharding_constraint P()) before
              the graph runs, then re-constrained back.

and reports the collective bytes of each compiled program.  Run inside the
512-device environment:

  PYTHONPATH=src python -m benchmarks.sharded_interventions
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import taps
from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import run_interleaved
from repro.distributed import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import registry as R
from repro.models.registry import batch_pspecs, fsdp_pspecs, input_specs
from repro.roofline.hlo_cost import analyze_hlo

import os as _os

ARCH = _os.environ.get("ABLATION_ARCH", "qwen3-8b")
LAYER = int(_os.environ.get("ABLATION_LAYER", "18"))
# site to edit: the MLA latent for minicpm3 (a value torch hooks cannot
# cleanly expose), the residual stream for everyone else
SITE = ("layers.attn.kv_latent" if ARCH.startswith("minicpm3")
        else "layers.output")


def experiment_graph():
    g = InterventionGraph()
    t = g.add("tap_get", site=SITE, layer=LAYER)
    v = g.add("mul", Ref(t.id), 1.5)
    g.add("tap_set", Ref(v.id), site=SITE, layer=LAYER)
    s = g.add("save", Ref(t.id))
    g.mark_saved("acts", s)
    o = g.add("tap_get", site="logits")
    m = g.add("jnp.mean", Ref(o.id))
    sm = g.add("save", Ref(m.id))
    g.mark_saved("metric", sm)
    return g


class _GatherShim:
    """Wraps the real InterleaveState, forcing replication at tap sites
    (the paper's gather-before-intervene semantics)."""

    def __init__(self, inner, mesh):
        self.inner = inner
        self.mesh = mesh

    def on_site(self, name, value, layer=None):
        key_sites = {n.site for n in self.inner.plan.graph.nodes
                     if n.site is not None}
        if name in key_sites:
            rep = NamedSharding(self.mesh, P())
            value = jax.tree.map(
                lambda v: jax.lax.with_sharding_constraint(v, rep), value
            )
        return self.inner.on_site(name, value, layer)

    def scan_collect_values(self):
        return self.inner.scan_collect_values()

    def deliver_scan(self, ys):
        return self.inner.deliver_scan(ys)


def lower_variant(gather: bool):
    mesh = make_production_mesh()
    cfg = R.get_config(ARCH)
    model = R.build_model(ARCH, cfg)
    shape = R.SHAPES["train_4k"]
    specs = input_specs(cfg, shape, model=model)
    del specs["labels"]
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    g = experiment_graph()
    schedule = model.site_schedule("scan")
    plan_order = list(schedule.order) + [("output", None)]
    from repro.core.interleave import SiteSchedule

    schedule = SiteSchedule(plan_order, schedule.scan_sites, schedule.n_layers)

    def model_fn(p, batch):
        out = model.forward(p, batch, mode="scan")["logits"]
        return taps.site("output", out)

    from repro.core.interleave import Interleaver, InterleaveState

    plan = Interleaver(g, schedule, mode="scan")

    def step(p, batch):
        state = InterleaveState(plan)
        st = _GatherShim(state, mesh) if gather else state
        taps.push_state(st)
        try:
            out = model_fn(p, batch)
        finally:
            taps.pop_state()
        state.finalize(include_grad_dependents=True)
        return state.saves()

    with use_mesh(mesh):
        from repro.distributed import named_sharding

        p_sh = jax.tree.map(
            lambda s, v: named_sharding(mesh, s, tuple(v.shape)),
            fsdp_pspecs(params_sds, mesh.devices.shape[-2]), params_sds,
        )
        b_sh = jax.tree.map(
            lambda s, v: named_sharding(mesh, s, tuple(v.shape)),
            batch_pspecs(specs), specs,
        )
        compiled = (
            jax.jit(step, in_shardings=(p_sh, b_sh))
            .lower(params_sds, specs)
            .compile()
        )
    return analyze_hlo(compiled.as_text())


def main():
    print("variant,collective_GiB,bytes_TiB")
    for gather in (False, True):
        c = lower_variant(gather)
        name = "gathered(paper B.2)" if gather else "sharded(ours)"
        print(f"{name},{c.collective_bytes/2**30:.2f},"
              f"{c.bytes_accessed/2**40:.2f}")


if __name__ == "__main__":
    main()
