"""Fused decode: the whole decode loop as ONE lax.scan dispatch vs eager.

Before this PR every decode step was a separate host dispatch (and, when
instrumented, a Python re-merge + the eager interleaver), so per-token host
overhead — not the model — bounded generation throughput (the overhead the
paper's Table 1 benchmarks against bare execution).  Step-uniform graphs
now compile prefill + N decode steps into one scan program
(repro.core.generation.make_fused_step); this module measures what that
buys at N=64.

Like the paper's Table 1, the gated rows isolate FRAMEWORK overhead: they
run a micro config (2 layers, d=64) where per-step compute is small, so the
per-token cost is the dispatch/merge machinery being removed.  At sizes
where single-core model compute dominates the step (the `2m` ladder entry
on this container, ~4ms/step), fusion still wins — the `*_2m` reference
rows report that ratio — but the win is bounded by compute, so those rows
carry no gate.

Rows (per-token wall-clock):
  fused_plain_decode     uninstrumented, one fused dispatch      [gated]
  eager_plain_decode     uninstrumented, N cached-jit dispatches
  fused_steered_decode   all_steps() steering + per-step logit saves, fused
  eager_steered_decode   same graph through the eager per-step interleaver
  fused_plain_2m         uninstrumented at the 2m ladder size    [no gate]
  eager_plain_2m

Asserted (the PR's acceptance gate): fused is >= 3x faster per token than
eager for the uninstrumented micro case, with token-exact results (saves
match at the repo's 1e-5 cross-strategy tolerance — the eager instrumented
baseline runs unjitted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, build, opt_suite, timeit
from repro.core.graph import ALL_STEPS, InterventionGraph, Ref
from repro.models.config import ModelConfig
from repro.models.transformer import TransformerModel
from repro.serving.engine import InferenceEngine

N_NEW = 64
SPEEDUP_GATE = 3.0


def _micro() -> ModelConfig:
    """Table-1-style framework-overhead config: compute per decode step is
    negligible, so per-token time IS the host machinery."""
    return ModelConfig(
        name="opt-micro", arch_type="dense", vocab_size=512,
        n_layers=2, d_model=64, n_heads=4, d_ff=256, n_kv_heads=4,
        dtype=jnp.float32, rope_theta=10000.0,
    )


def _steer_graph(cfg) -> InterventionGraph:
    """all_steps() steering + per-step stacked logit saves — step-uniform."""
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.mlp.output", layer=1, step=ALL_STEPS)
    c = g.add("constant", np.float32(5.0))
    u = g.add("add", Ref(t.id), Ref(c.id))
    g.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=1,
          step=ALL_STEPS)
    for s in range(N_NEW):
        tt = g.add("tap_get", site="logits", step=s)
        g.mark_saved(f"lg@step{s}", g.add("save", Ref(tt.id)))
    return g


def _measure(engine, toks, graph_fn, fused):
    def call():
        return engine.generate_interleaved(
            graph_fn(), {"tokens": toks}, N_NEW, fused=fused)

    mean, _std = timeit(call, n=5, warmup=1)
    return mean


def rows() -> list[Row]:
    cfg = _micro()
    model = TransformerModel(cfg)
    params = model.init(jax.random.key(0))
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 16)).astype(np.int32)
    engine = InferenceEngine(model, params)
    out = []

    plain = lambda: InterventionGraph()
    steered = lambda: _steer_graph(cfg)

    def run(graph_fn, fused):
        return engine.generate_interleaved(
            graph_fn(), {"tokens": toks}, N_NEW, fused=fused)

    # ---- parity gate (also warms every executable) ----------------------
    rf, re_ = run(plain, True), run(plain, False)
    np.testing.assert_array_equal(np.asarray(rf.tokens),
                                  np.asarray(re_.tokens))
    np.testing.assert_array_equal(np.asarray(rf.logits),
                                  np.asarray(re_.logits))
    sf, se = run(steered, True), run(steered, False)
    np.testing.assert_array_equal(np.asarray(sf.tokens),
                                  np.asarray(se.tokens))
    assert sorted(sf.saves) == sorted(se.saves)
    for k in se.saves:
        np.testing.assert_allclose(np.asarray(sf.saves[k]),
                                   np.asarray(se.saves[k]),
                                   rtol=1e-5, atol=1e-5)

    timings = {
        name: _measure(engine, toks, graph_fn, fused)
        for name, graph_fn, fused in (
            ("fused_plain_decode", plain, True),
            ("eager_plain_decode", plain, False),
            ("fused_steered_decode", steered, True),
            ("eager_steered_decode", steered, False),
        )
    }

    # ---- compute-bound reference: the 2m ladder size (no gate) ----------
    cfg2 = opt_suite(("2m",))["2m"]
    model2, params2 = build(cfg2)
    toks2 = np.random.default_rng(0).integers(
        0, cfg2.vocab_size, (2, 16)).astype(np.int32)
    engine2 = InferenceEngine(model2, params2)
    for fused in (True, False):  # warm + parity
        engine2.generate_interleaved(InterventionGraph(), {"tokens": toks2},
                                     N_NEW, fused=fused)
    timings["fused_plain_2m"] = _measure(
        engine2, toks2, lambda: InterventionGraph(), True)
    timings["eager_plain_2m"] = _measure(
        engine2, toks2, lambda: InterventionGraph(), False)

    for pair in ("plain", "steered", "plain_2m"):
        suffix = pair if pair.endswith("2m") else f"{pair}_decode"
        fname, ename = f"fused_{suffix}", f"eager_{suffix}"
        speedup = timings[ename] / timings[fname]
        for name in (fname, ename):
            per_tok = timings[name] / N_NEW * 1e6
            derived = (f"speedup={speedup:.1f}x" if name == fname
                       else f"n_new={N_NEW}")
            out.append(Row(name, per_tok, derived, extra={
                "per_token_us": round(per_tok, 2),
                "total_ms": round(timings[name] * 1e3, 2),
                "speedup_vs_eager": round(speedup, 2),
                "n_new": N_NEW,
            }))

    plain_speedup = timings["eager_plain_decode"] / timings[
        "fused_plain_decode"]
    assert plain_speedup >= SPEEDUP_GATE, (
        f"fused decode must be >= {SPEEDUP_GATE}x faster per token than "
        f"eager for uninstrumented N={N_NEW} generation, got "
        f"{plain_speedup:.2f}x"
    )
    return out
