"""Paper Fig. 9 (Appendix D.2): response time vs concurrent users.

Simulates N users submitting random-layer activation requests in one burst
(the paper's Code Example 9 workload).  Reproduces the paper's finding for
SEQUENTIAL co-tenancy — median response time grows ~linearly with N — and
adds the beyond-paper result: PARALLEL co-tenancy (batch-grouped execution,
the paper's Appendix B.2 future work) flattens the curve.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, build
from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.serving import NDIFServer, Request


def user_request(cfg, rng) -> Request:
    g = InterventionGraph()
    layer = int(rng.integers(0, cfg.n_layers))
    t = g.add("tap_get", site="layers.output", layer=layer)
    s = g.add("save", Ref(t.id))
    g.mark_saved("acts", s)
    seq = 24  # paper: prompts up to 24 tokens; fixed so requests batch-merge
    toks = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks})


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    out: list[Row] = []
    for policy in ("sequential", "parallel"):
        server = NDIFServer()
        server.host(cfg.name, model, params, policy=policy,
                    max_batch_rows=128)
        sched = server.schedulers[cfg.name]
        for n_users in (1, 4, 16, 64):
            # Warm pass: identical burst once, so the executable cache is hot
            # (the paper measures warm, preloaded instances).
            rng = np.random.default_rng(n_users)
            for _ in range(n_users):
                sched.submit(user_request(cfg, rng))
            sched.drain()
            # Measured pass: same burst composition, fresh tickets.
            rng = np.random.default_rng(n_users)
            tickets = [sched.submit(user_request(cfg, rng))
                       for _ in range(n_users)]
            sched.drain()
            times = np.array([t.response_time for t in tickets])
            out.append(Row(
                f"fig9/{policy}/users_{n_users}",
                float(np.median(times)) * 1e6,
                f"p25={np.percentile(times,25)*1e3:.1f}ms;"
                f"p75={np.percentile(times,75)*1e3:.1f}ms;"
                f"max={times.max()*1e3:.1f}ms;"
                f"executions={server.engines[cfg.name].stats.executions}",
            ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
