"""Shared benchmark utilities."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.models import registry as R
from repro.models.config import ModelConfig


def timeit(fn: Callable, n: int = 10, warmup: int = 2) -> tuple[float, float]:
    """Returns (mean_s, std_s) over n calls after warmup."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def opt_suite(sizes=("2m", "8m", "20m")) -> dict[str, ModelConfig]:
    """OPT-style size ladder (paper Fig. 6a/6b uses OPT-125m..66b; on one
    CPU core we ladder 2M..20M — the scaling *shape* is the claim)."""
    specs = {
        "2m":  dict(n_layers=4,  d_model=128, n_heads=4,  d_ff=512),
        "8m":  dict(n_layers=6,  d_model=256, n_heads=8,  d_ff=1024),
        "20m": dict(n_layers=8,  d_model=384, n_heads=8,  d_ff=1536),
        "50m": dict(n_layers=10, d_model=512, n_heads=8,  d_ff=2048),
    }
    import jax.numpy as jnp

    out = {}
    for name in sizes:
        s = specs[name]
        out[name] = ModelConfig(
            name=f"opt-{name}", arch_type="dense", vocab_size=2048,
            n_kv_heads=s["n_heads"], dtype=jnp.float32,
            rope_theta=10000.0, **s,
        )
    return out


def build(cfg: ModelConfig):
    from repro.models.transformer import TransformerModel

    model = TransformerModel(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def ioi_batch(cfg: ModelConfig, batch=32, seq=16, seed=0) -> np.ndarray:
    """Stand-in for the paper's 32-example IOI batch."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str
    # Optional machine-readable payload (per-benchmark timing distributions,
    # occupancy/waste stats); run.py folds it into BENCH_<name>.json so the
    # perf trajectory is trackable across PRs.  Not part of the CSV line.
    extra: dict | None = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def to_json(self) -> dict:
        out = {
            "name": self.name,
            "us_per_call": round(self.us_per_call, 3),
            "derived": self.derived,
        }
        if self.extra:
            out["extra"] = self.extra
        return out
