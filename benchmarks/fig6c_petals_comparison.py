"""Paper Fig. 6c: NDIF vs Petals-style client-side interventions.

Two protocols for the SAME experiment (patch the residual stream at layer L,
report the last-token logit difference), measured in wire bytes + modeled
transfer time on the paper's ~60 MB/s link + compute time:

  petals_style — the client RECEIVES hidden states at layer L, modifies
    locally, SENDS them back; the server resumes from layer L (implemented
    faithfully: request 2 carries the modified states as a graph constant
    written into the layer-L tap).  Wire cost ~ 2 × |hidden states|.
  ndif_style   — ONE request carrying only the graph; the metric is computed
    server-side; the reply is a scalar per row.  Wire cost ~ KBs.

Also reproduces the "standard remote inference" panel where the two systems
are comparable (both return final hidden states).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, build, ioi_batch, timeit
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer

LAYER = 4
BANDWIDTH = 60e6  # paper's measured ~60 MB/s


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="sequential")
    toks = ioi_batch(cfg)
    out: list[Row] = []

    # ---------------- standard remote inference (comparable) -------------
    transport = LoopbackTransport(server.handle, bandwidth_bytes_per_s=BANDWIDTH)
    client = NDIFClient(transport, cfg.name)
    client.hidden_states(toks)  # warm
    b0 = (transport.stats.bytes_sent, transport.stats.bytes_received)
    m, _ = timeit(lambda: client.hidden_states(toks), n=3, warmup=0)
    sent = (transport.stats.bytes_sent - b0[0]) / 3
    recv = (transport.stats.bytes_received - b0[1]) / 3
    xfer = (sent + recv) / BANDWIDTH
    out.append(Row("fig6c/standard_inference", (m + xfer) * 1e6,
                   f"bytes={int(sent+recv)};xfer_ms={xfer*1e3:.1f}"))

    # ---------------- Petals-style intervention --------------------------
    lm = traced_lm(model, None, backend=client)

    def petals_style():
        # request 1: download hidden states at layer L
        with lm.trace(toks, remote=True):
            h = lm.layers[LAYER].output.save("h")
        h = np.asarray(h.value)
        # local modification on the client
        h[1, 6, :] = h[0, 5, :]
        # request 2: upload modified states, resume, get logits back
        with lm.trace(toks, remote=True) as tr:
            lm.layers[LAYER].output = tr.constant(h)
            logits = lm.output.save("logits")
        lg = np.asarray(logits.value)
        return lg[:, -1, 7] - lg[:, -1, 3]

    petals_style()  # warm/compile
    b0 = (transport.stats.bytes_sent, transport.stats.bytes_received)
    m_p, _ = timeit(petals_style, n=3, warmup=0)
    sent = (transport.stats.bytes_sent - b0[0]) / 3
    recv = (transport.stats.bytes_received - b0[1]) / 3
    xfer_p = (sent + recv) / BANDWIDTH
    out.append(Row("fig6c/petals_style_patch", (m_p + xfer_p) * 1e6,
                   f"bytes={int(sent+recv)};xfer_ms={xfer_p*1e3:.1f}"))

    # ---------------- NDIF-style intervention ----------------------------
    def ndif_style():
        with lm.trace(toks, remote=True):
            lm.layers[LAYER].output[1, 6, :] = lm.layers[LAYER].output[0, 5, :]
            logits = lm.output
            metric = (logits[:, -1, 7] - logits[:, -1, 3]).save("m")
        return np.asarray(metric.value)

    ndif_style()
    b0 = (transport.stats.bytes_sent, transport.stats.bytes_received)
    m_n, _ = timeit(ndif_style, n=3, warmup=0)
    sent = (transport.stats.bytes_sent - b0[0]) / 3
    recv = (transport.stats.bytes_received - b0[1]) / 3
    xfer_n = (sent + recv) / BANDWIDTH
    out.append(Row("fig6c/ndif_style_patch", (m_n + xfer_n) * 1e6,
                   f"bytes={int(sent+recv)};xfer_ms={xfer_n*1e3:.1f}"))

    # correctness: both protocols agree on the metric
    np.testing.assert_allclose(petals_style(), ndif_style(), rtol=2e-4,
                               atol=2e-4)
    out.append(Row("fig6c/speedup", 0.0,
                   f"ndif_over_petals={(m_p+xfer_p)/(m_n+xfer_n):.2f}x"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
