"""Live front-door load harness: 200 concurrent clients, real threads.

Every other serving benchmark replays arrivals on a VIRTUAL clock against a
synchronously-pumped scheduler.  This one exercises the actual threaded
path: 200 client threads submit through the wire protocol (submit/stream
kinds over a LoopbackTransport) under seeded Poisson arrivals while the
FrontDoor's engine thread steps the decode loop — queueing, backpressure,
admission and streaming all happen live, with real sleeping and real lock
contention.

Asserted (hard failures, not just reported):
  * every admitted client's tokens are BIT-EXACT vs the solo synchronous
    path (chunked streams concatenate to the exact solo result);
  * ZERO steady-state recompiles — the measured phase performs no XLA
    traces (power-of-two window ladder + one length bucket + warmup);
  * bounded queue: the high-water backlog never exceeds the configured
    ``max_queue_depth``;
  * an over-budget burst is refused with STRUCTURED backpressure
    (``code="backpressure"`` + ``retry_after_ms``), and the system keeps
    serving afterwards;
  * sustained throughput and p95 response stay within scale-invariant
    bounds derived from the machine's own measured per-step cost (one
    noise retry, same idiom as the co-tenancy benchmarks).

Reported: tokens/s, p50/p95 response, p95 time-to-first-token, refusal
counts, queue high-water — ``tokens_per_s`` is gated HIGHER-better by
scripts/bench_check.py.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import Row, build
from repro.models import registry as R
from repro.serving import (
    AdmissionRefused,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
)

N_CLIENTS = 200
N_JOBS = 8          # distinct (prompt, n_new) jobs shared across clients
NUM_SLOTS = 8
SLOT_MAX_LEN = 48
MAX_QUEUE_DEPTH = 32
SEQ_LEN = 6         # one length bucket -> one compiled prefill shape
STREAM_EVERY = 3    # every 3rd client streams; the rest are batch clients


def make_jobs(cfg):
    rng = np.random.default_rng(17)
    jobs = []
    for _ in range(N_JOBS):
        toks = rng.integers(0, cfg.vocab_size, (1, SEQ_LEN)).astype(np.int32)
        n_new = int(rng.integers(4, 11))
        jobs.append((toks, n_new))
    return jobs


def run_load(client, jobs, arrivals, job_of, *, collect):
    """Replay one full arrival schedule from N_CLIENTS real threads.

    Backpressure refusals back off by the server's ``retry_after_ms`` hint
    and retry — every client eventually completes (bounded queue trades
    admission latency, not answers).  Returns per-client timings.
    """
    t0 = time.perf_counter()
    lock = threading.Lock()
    out = {"resp": [], "refused": 0, "errors": [], "results": {}}

    def worker(i):
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        toks, n_new = jobs[job_of[i]]
        submit_t = time.perf_counter()
        for _ in range(500):
            try:
                tk = client.submit(toks, n_new,
                                   stream=(i % STREAM_EVERY == 0))
            except AdmissionRefused as e:
                if e.code != "backpressure":
                    with lock:
                        out["errors"].append(f"{i}: refused {e.code}")
                    return
                with lock:
                    out["refused"] += 1
                time.sleep(max(e.retry_after_ms or 1.0, 1.0) / 1000.0)
                continue
            try:
                res = tk.result(timeout=900.0)
            except Exception as e:
                with lock:
                    out["errors"].append(f"{i}: {type(e).__name__}: {e}")
                return
            with lock:
                out["resp"].append(time.perf_counter() - submit_t)
                if collect:
                    out["results"][i] = np.asarray(res["tokens"])
            return
        with lock:
            out["errors"].append(f"{i}: starved after 500 refusals")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(arrivals))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    out["wall"] = time.perf_counter() - t0
    return out


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    server = NDIFServer()
    server.host("live", model, params, policy="continuous",
                num_slots=NUM_SLOTS, slot_max_len=SLOT_MAX_LEN,
                max_queue_depth=MAX_QUEUE_DEPTH)
    client = NDIFClient(LoopbackTransport(server.handle), "live")
    engine = server.engines["live"]
    jobs = make_jobs(cfg)

    # solo references (front door idle: nothing competes for the engine) —
    # also warms the prefill/decode/fused executables for this bucket
    refs = [np.asarray(client.generate(toks, n)["tokens"])
            for toks, n in jobs]

    rng = np.random.default_rng(23)
    job_of = rng.integers(0, N_JOBS, N_CLIENTS)

    # --- warmup: cover every admission-group row count 1..NUM_SLOTS (each
    # group size is a distinct prefill/write_rows shape) plus the window
    # ladder, so the measured phase hits only cached executables
    for g in range(1, NUM_SLOTS + 1):
        tickets = [client.submit(*jobs[k % N_JOBS]) for k in range(g)]
        for tk in tickets:
            tk.result(timeout=900.0)

    # calibrate offered load to THIS machine: ~1.2x the loop's measured
    # service capacity, so the queue genuinely builds without starving
    step = engine.stats.step_cost_ema or 0.01
    mean_tokens = float(np.mean([n for _, n in jobs]))
    service_rate = NUM_SLOTS / (mean_tokens * step)  # requests/s capacity
    gaps = rng.exponential(1.0 / (1.2 * service_rate), N_CLIENTS)
    arrivals = np.cumsum(gaps)

    # --- stabilization pass: absorb any executable this exact arrival
    # pattern still manages to need (first fused windows, odd group mixes)
    run_load(client, jobs, arrivals[: N_CLIENTS // 4],
             job_of, collect=False)

    out: list[Row] = []
    for attempt in range(2):  # one retry absorbs shared-CPU noise
        compiles_before = engine.stats.compiles
        recs_before = len(engine.stats.ticket_records)
        load = run_load(client, jobs, arrivals, job_of, collect=True)
        compiles_delta = engine.stats.compiles - compiles_before
        assert not load["errors"], load["errors"][:5]
        assert len(load["resp"]) == N_CLIENTS, len(load["resp"])

        # bit-exact vs solo, for every client, streamed or batch
        for i, toks_out in load["results"].items():
            np.testing.assert_array_equal(
                toks_out, refs[job_of[i]],
                err_msg=f"client {i} diverged from solo",
            )

        # zero steady-state recompiles
        assert compiles_delta == 0, (
            f"measured phase performed {compiles_delta} XLA traces"
        )

        snap = engine.stats.snapshot()
        assert snap["queue_depth_max"] <= MAX_QUEUE_DEPTH, (
            snap["queue_depth_max"], MAX_QUEUE_DEPTH
        )

        resp = np.asarray(load["resp"])
        p50 = float(np.percentile(resp, 50))
        p95 = float(np.percentile(resp, 95))
        total_tokens = int(sum(jobs[job_of[i]][1] for i in range(N_CLIENTS)))
        tokens_per_s = total_tokens / load["wall"]
        # measured-pass tickets ONLY: warmup/stabilization records carry
        # XLA compile stalls in their first-token times and would
        # dominate the p95 with numbers that say nothing about serving
        ttfts = [t["time_to_first_token"]
                 for t in snap["tickets"][recs_before:]
                 if t.get("time_to_first_token") is not None]
        ttft_p95 = float(np.percentile(ttfts, 95)) if ttfts else 0.0

        # scale-invariant SLO: the whole offered load, served at the
        # measured steady-state step cost by NUM_SLOTS rows, takes
        # ~total_tokens/NUM_SLOTS steps; p95 must stay within a small
        # multiple of that full-drain bound (queueing included)
        step_now = engine.stats.step_cost_ema
        drain_bound = (total_tokens / NUM_SLOTS) * step_now
        floor_rate = 0.25 * NUM_SLOTS / step_now  # >=25% of ideal tokens/s
        ok_p95 = p95 <= 3.0 * drain_bound
        ok_thr = tokens_per_s >= floor_rate
        if not (ok_p95 and ok_thr) and attempt == 0:
            continue  # noise retry
        assert ok_p95, (f"p95 {p95 * 1e3:.0f}ms vs bound "
                        f"{3.0 * drain_bound * 1e3:.0f}ms")
        assert ok_thr, (f"{tokens_per_s:.1f} tok/s vs floor "
                        f"{floor_rate:.1f}")
        break

    # --- over-budget burst: rapid-fire submits from one thread must hit
    # the structured refusal, and the system keeps serving afterwards
    burst_refusals = []
    burst_tickets = []
    for _ in range(MAX_QUEUE_DEPTH + 24):
        try:
            burst_tickets.append(client.submit(*jobs[0]))
        except AdmissionRefused as e:
            burst_refusals.append(e)
    assert burst_refusals, "over-budget burst was never refused"
    assert all(e.code == "backpressure" for e in burst_refusals)
    assert all(e.retry_after_ms and e.retry_after_ms > 0
               for e in burst_refusals)
    for tk in burst_tickets:
        np.testing.assert_array_equal(
            np.asarray(tk.result(timeout=900.0)["tokens"]), refs[0]
        )

    snap = engine.stats.snapshot()
    server.shutdown()
    out.append(Row(
        f"live_serving/poisson/clients_{N_CLIENTS}",
        float(np.mean(resp)) * 1e6,
        f"tok_s={tokens_per_s:.1f};p95_ms={p95 * 1e3:.1f};"
        f"refused={load['refused'] + len(burst_refusals)}",
        extra={
            "tokens_per_s": round(tokens_per_s, 2),
            "p50_ms": round(p50 * 1e3, 3),
            "p95_ms": round(p95 * 1e3, 3),
            "ttft_p95_ms": round(ttft_p95 * 1e3, 3),
            "mean_ms": round(float(np.mean(resp)) * 1e3, 3),
            "wall_s": round(load["wall"], 3),
            "clients": N_CLIENTS,
            "refused_backpressure": load["refused"],
            "burst_refusals": len(burst_refusals),
            "queue_depth_max": snap["queue_depth_max"],
            "rejected_submissions": snap["rejected_submissions"],
            "stream_chunks": snap["stream_chunks"],
            "compiles_measured_phase": 0,
            "step_cost_ema_ms": round(snap["step_cost_ema"] * 1e3, 3),
            "prefill_cost_ema_ms": round(
                snap["prefill_cost_ema"] * 1e3, 3),
        },
    ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
