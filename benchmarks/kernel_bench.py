"""Kernel-level microbenchmarks: Pallas-fallback (XLA chunked) attention and
SSD vs their dense/sequential references on CPU, plus the roofline-relevant
derived quantities (arithmetic intensity per variant).

On TPU the pallas kernels replace the chunked path; on this CPU container we
benchmark the XLA fallbacks (what the dry-run lowers) and verify the
kernels in interpret mode for correctness only (interpret timing is
meaningless).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.models.common import attention, _ssd_chunked
from repro.kernels import ref


def rows() -> list[Row]:
    out: list[Row] = []
    B, S, K, G, hd = 2, 1024, 4, 2, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, K * G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    flops = 4 * B * K * G * S * S * hd  # qk + pv

    for impl in ("dense", "chunked"):
        fn = jax.jit(lambda q, k, v, impl=impl: attention(
            q, k, v, q_pos=pos, k_pos=pos, causal=True, impl=impl))
        jax.block_until_ready(fn(q, k, v))
        m, _ = timeit(lambda: jax.block_until_ready(fn(q, k, v)), n=5)
        out.append(Row(f"kernel/attention_{impl}_S{S}", m * 1e6,
                       f"gflops={flops/1e9:.1f};gflops_per_s={flops/m/1e9:.1f}"))

    Bb, S2, H, P, N, chunk = 2, 2048, 8, 64, 64, 128
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (Bb, S2, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S2, H)))
    A = jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (Bb, S2, N))
    C = jax.random.normal(ks[4], (Bb, S2, N))
    D = jnp.ones((H,))

    ssd = jax.jit(lambda *a: _ssd_chunked(*a, chunk))
    f = lambda: jax.block_until_ready(ssd(x, dt, A, B_, C, D)[0])
    f()
    m, _ = timeit(f, n=3)
    out.append(Row(f"kernel/ssd_chunked_S{S2}", m * 1e6,
                   f"chunk={chunk}"))

    seq = jax.jit(ref.reference_ssd)
    f2 = lambda: jax.block_until_ready(seq(x, dt, A, B_, C, D)[0])
    f2()
    m2, _ = timeit(f2, n=3)
    out.append(Row(f"kernel/ssd_sequential_S{S2}", m2 * 1e6,
                   f"chunked_speedup={m2/m:.2f}x"))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
