"""Paper Fig. 6a/6b (+ Table 2): HPC vs NDIF setup time and runtime.

6a — setup: HPC users load weights per-experiment (grows ~linearly with
     parameter count); NDIF preloads once, user setup is ~constant.
6b — runtime: remote execution adds a roughly CONSTANT overhead
     (serialization + transport) independent of model size.

The OPT ladder is scaled to CPU (2M/8M/20M) — the paper's claims are about
scaling shape, which survives the rescale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, build, ioi_batch, opt_suite, timeit
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer


def _patch(lm, toks, remote):
    with lm.trace(toks, remote=remote):
        lm.layers[1].output[1, 3, :] = lm.layers[1].output[0, 2, :]
        out = lm.output.save("out")
    return out.value


def rows() -> list[Row]:
    out: list[Row] = []
    suite = opt_suite()

    # one shared NDIF server hosting every size (preloaded = paid once)
    server = NDIFServer()
    models = {}
    for name, cfg in suite.items():
        model, params = build(cfg)
        server.host(cfg.name, model, params, policy="sequential")
        models[name] = (cfg, model, params)

    for name, (cfg, model, params) in models.items():
        n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        toks = ioi_batch(cfg)

        # --- 6a setup: HPC = init weights locally (disk-load stand-in)
        def hpc_setup():
            p = model.init(jax.random.key(1))
            jax.block_until_ready(jax.tree.leaves(p)[0])

        m_su, s_su = timeit(hpc_setup, n=3, warmup=1)
        out.append(Row(f"fig6a/hpc_setup/{name}", m_su * 1e6,
                       f"params={n_params}"))

        # NDIF setup: client connects to the preloaded instance
        def ndif_setup():
            transport = LoopbackTransport(server.handle)
            NDIFClient(transport, cfg.name)

        m_ns, _ = timeit(ndif_setup, n=3, warmup=1)
        out.append(Row(f"fig6a/ndif_setup/{name}", m_ns * 1e6,
                       f"params={n_params}"))

        # --- 6b runtime: local vs remote activation patching
        lm_local = traced_lm(model, params)
        _patch(lm_local, jnp.asarray(toks), remote=False)  # warm
        m_l, _ = timeit(lambda: _patch(lm_local, jnp.asarray(toks), False), n=5)
        out.append(Row(f"fig6b/local_patch/{name}", m_l * 1e6,
                       f"params={n_params}"))

        transport = LoopbackTransport(server.handle)
        client = NDIFClient(transport, cfg.name)
        lm_remote = traced_lm(model, None, backend=client)
        _patch(lm_remote, toks, remote=True)  # warm (server compiles once)
        m_r, _ = timeit(lambda: _patch(lm_remote, toks, True), n=5)
        out.append(Row(
            f"fig6b/remote_patch/{name}", m_r * 1e6,
            f"params={n_params};overhead_us={1e6*(m_r-m_l):.0f}",
        ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
