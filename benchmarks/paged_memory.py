"""Paged vs dense KV memory at a FIXED cache budget (the tentpole claim).

A dense slot table spends ``max_len`` cache cells per row the moment a
request admits, whatever the request's actual length: 4 slots of 48 cells
pin 192 cells to serve at most 4 concurrent rows.  The paged pool spends
cells by ACTUAL lifetime extent (prompt + max_new_tokens, rounded up to
pages), so the same 192 cells serve however many mixed-length requests fit
— short requests stop paying for the long tail they never use.

Method: both configurations get an EQUAL usable-cell budget

    dense —  4 slots x 48 cells            = 192 cells
    paged — 12 slots, 24 pages x 8 cells   = 192 cells

and replay the SAME staggered mixed-length arrival schedule through the
continuous-batching scheduler on a virtual clock (measured wall time per
pump; arrivals gate admission — the cotenancy_continuous method).  With
per-request lifetime need of 3 pages (24 cells), the pool hosts up to 8
concurrent rows where the dense table caps at 4.

Reported per configuration: peak concurrent residents (the capacity claim,
asserted >= 1.5x), p50/p95 response time (the latency claim — more
concurrency means less queueing, asserted paged < dense), page/slot
occupancy.  The pool's two reserved pages (null read target, trash write
sink) are constant allocator overhead and sit outside the usable budget.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, build
from repro.core.graph import InterventionGraph
from repro.models import registry as R
from repro.models.paged import FIRST_PAGE
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request, _bucket_ceiling

N_USERS = 24
PAD_SLACK = 7
MAX_LEN = 48
CELL_BUDGET = 192            # usable cache cells, both configurations
DENSE_SLOTS = 4              # 4 x 48 = 192
PAGED_SLOTS = 12             # row slots (cheap); pages are the budget
PAGE_SIZE = 8
NUM_PAGES = FIRST_PAGE + CELL_BUDGET // PAGE_SIZE
REPLAYS = 3


def workload(cfg):
    """Mixed-length short-request traffic with tight staggered arrivals:
    prompts 8..15 (one pad_slack=7 bucket), 4..8 new tokens — lifetime
    extent <= 22 cells, or 3 pages of 8 after padding to the bucket."""
    rng = np.random.default_rng(11)
    gaps = [((2 * i) % 3 + (i % 2)) / 4.0 for i in range(N_USERS)]
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(N_USERS):
        seq = int(rng.integers(8, 16))
        n_new = int(rng.integers(4, 9))
        toks = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
        out.append((toks, n_new, float(arrivals[i])))
    return out


def run_config(model, params, jobs, step_unit, *, paged):
    engine = InferenceEngine(model, params)
    num_slots = PAGED_SLOTS if paged else DENSE_SLOTS
    sched = CoTenantScheduler(engine, policy="continuous",
                              pad_slack=PAD_SLACK, num_slots=num_slots,
                              slot_max_len=MAX_LEN)
    if paged:
        sched._loop = engine.start_decode_loop(
            num_slots, MAX_LEN, page_size=PAGE_SIZE, num_pages=NUM_PAGES)
    else:
        sched._loop = engine.start_decode_loop(num_slots, MAX_LEN,
                                               paged=False)

    # Warm EVERY admission-group shape this bucket can produce (1..num_slots
    # rows at the bucket ceiling): replayed groupings drift with wall-clock
    # noise, and a first-seen prefill shape compiling inside the timed
    # replay would charge trace time to the tail percentiles.
    ceil = _bucket_ceiling(max(t.shape[1] for t, _, _ in jobs), PAD_SLACK)
    for r in range(1, num_slots + 1):
        sched.loop.admit_group(
            [(InterventionGraph(), {"tokens": jobs[i % len(jobs)][0]}, 1,
              None) for i in range(r)],
            pad_to=ceil)
        sched.loop.run_to_completion()

    def replay():
        arrival_of = {}
        clock, resp, peak = 0.0, [], 0
        pending = [(toks, n, a * step_unit) for toks, n, a in jobs]
        inflight = 0
        while pending or inflight:
            for toks, n_new, arrive in [j for j in pending
                                        if j[2] <= clock]:
                req = Request(graph=InterventionGraph(),
                              batch={"tokens": toks}, max_new_tokens=n_new)
                sched.submit(req)
                arrival_of[req.request_id] = arrive
                inflight += 1
            pending = [j for j in pending if j[2] > clock]
            if not inflight:
                clock = min(j[2] for j in pending)
                continue
            t0 = time.perf_counter()
            finished = sched.pump()  # admit -> ONE step -> retirements
            clock += time.perf_counter() - t0
            peak = max(peak, len(sched.loop.resident) + len(finished))
            for ticket in finished:
                resp.append(clock - arrival_of[ticket.request_id])
                inflight -= 1
        return resp, peak

    for _ in range(REPLAYS - 1):
        replay()
    resp, peak = replay()
    return resp, peak, engine


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    jobs = workload(cfg)

    # one arrival slot == one warm decode-step of the paged loop at a
    # representative occupancy (averaged: a single cold measurement skews
    # the whole arrival schedule)
    engine = InferenceEngine(model, params)
    loop = engine.start_decode_loop(PAGED_SLOTS, MAX_LEN,
                                    page_size=PAGE_SIZE,
                                    num_pages=NUM_PAGES)
    for toks, _, _ in jobs[:4]:
        loop.admit(InterventionGraph(), {"tokens": toks}, 12)
    loop.step()
    loop.step()
    t0 = time.perf_counter()
    for _ in range(5):
        loop.step()
    step_unit = (time.perf_counter() - t0) / 5
    loop.run_to_completion()

    out: list[Row] = []
    for attempt in range(2):
        out.clear()
        stats = {}
        for name, paged in (("dense", False), ("paged", True)):
            resp, peak, eng = run_config(model, params, jobs, step_unit,
                                         paged=paged)
            assert len(resp) == N_USERS
            p50 = float(np.percentile(resp, 50))
            p95 = float(np.percentile(resp, 95))
            stats[name] = (p95, peak)
            snap = eng.stats.snapshot()
            out.append(Row(
                f"paged_memory/{name}/cells_{CELL_BUDGET}",
                float(np.mean(resp)) * 1e6,
                f"p95_ms={p95 * 1e3:.2f};peak_residents={peak};"
                f"slot_occupancy={snap['slot_occupancy']:.2f}",
                extra={
                    "p50_ms": round(p50 * 1e3, 3),
                    "p95_ms": round(p95 * 1e3, 3),
                    "mean_ms": round(float(np.mean(resp)) * 1e3, 3),
                    "peak_residents": peak,
                    "cell_budget": CELL_BUDGET,
                    "slot_occupancy": round(snap["slot_occupancy"], 4),
                    "page_occupancy": round(snap["page_occupancy"], 4),
                    "page_allocs": snap["page_allocs"],
                    "alloc_retries": snap["alloc_retries"],
                    "frag_events_avoided": snap["frag_events_avoided"],
                    "step_unit_ms": round(step_unit * 1e3, 3),
                },
            ))
        ratio = stats["paged"][1] / stats["dense"][1]
        if stats["paged"][0] < stats["dense"][0] and ratio >= 1.5:
            break
        # wall-clock noise can invert one latency measurement; remeasure
        # once before declaring the claim false
    # the tentpole claims, checked where the numbers are produced:
    # equal memory must buy >= 1.5x concurrency and a p95 win
    assert ratio >= 1.5, (
        f"paged pool should host >= 1.5x concurrent rows at an equal cell "
        f"budget: peak {stats['paged'][1]} vs dense {stats['dense'][1]}"
    )
    assert stats["paged"][0] < stats["dense"][0], (
        "paged admission should beat the dense slot table's p95 under "
        f"staggered mixed-length arrivals: {stats}"
    )
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
