"""Compiled islands: log / grad / stop workloads on the fused path vs eager.

Before this PR three workload classes were EAGER ISLANDS — graphs the fused
planner refused, so every decode step fell back to the per-step interleaver
(Python dispatch + re-merge per token):

  * ``log()`` taps — the callback could not live inside the scan;
  * ``.grad`` — the perturbation driver only ran outside the compiled step;
  * ``tracer.stop()`` — truncation was "raise at trace time", so truncated
    forwards skipped the compile cache entirely.

The harvest-mold interpreter lowers all three into the compiled body
(``jax.debug.callback`` for log, carry-threaded perturbations for grad,
trace-time ``EarlyStop`` for stop), so the whole stretch fuses.  This module
measures what that buys at N=64 on the Table-1-style micro config, where
per-step compute is negligible and per-token cost IS the host machinery.

Rows (per-token wall-clock for decode; per-call for the stop forward):
  fused_log_decode       log() every step, one fused dispatch     [gated]
  eager_log_decode       same graph, per-step eager interleaver
  fused_grad_decode      backward loss + grad_get riding the scan
  eager_grad_decode      same graph, fully eager (the pre-PR path)
  compiled_stop_forward  truncated forward, compiled + cached
  eager_stop_forward     truncated forward, unjitted run_interleaved

Asserted (the PR's acceptance gate): the fused log-instrumented decode is
>= 3x faster per token than the eager island it replaces, with identical
tokens and matching logged values; grad results match at 1e-4.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import last_referenced_site, run_interleaved
from repro.models.config import ModelConfig
from repro.models.transformer import TransformerModel
from repro.serving.engine import InferenceEngine

N_NEW = 64
SPEEDUP_GATE = 3.0


def _micro(n_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="opt-micro", arch_type="dense", vocab_size=512,
        n_layers=n_layers, d_model=64, n_heads=4, d_ff=256, n_kv_heads=4,
        dtype=jnp.float32, rope_theta=10000.0,
    )


def _log_graph() -> InterventionGraph:
    """A scalar log() tap on every decode step — step-uniform, but an
    eager island pre-harvest (FusionVerdict reason "log")."""
    g = InterventionGraph()
    for s in range(N_NEW):
        t = g.add("tap_get", site="logits", step=s)
        m = g.add("jnp.max", Ref(t.id), step=s)
        g.add("log", Ref(m.id), step=s)
    return g


def _grad_graph() -> InterventionGraph:
    """A backward loss on one decode step with the gradient read at an MLP
    site — pre-harvest the whole stretch ran eager (reason "grad")."""
    g = InterventionGraph()
    gg = g.add("grad_get", site="layers.mlp.output", layer=1, step=1)
    g.mark_saved("g", g.add("save", Ref(gg.id), step=1))
    t = g.add("tap_get", site="logits", step=1)
    sq = g.add("mul", Ref(t.id), Ref(t.id), step=1)
    loss = g.add("jnp.sum", Ref(sq.id), step=1)
    g.backward_loss = loss.id
    return g


def _stop_graph() -> InterventionGraph:
    """Read layer 0 of a 4-layer model and stop — 3/4 of the forward is
    never lowered."""
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=0)
    g.mark_saved("h", g.add("save", Ref(t.id)))
    return g


def rows() -> list[Row]:
    cfg = _micro()
    model = TransformerModel(cfg)
    params = model.init(jax.random.key(0))
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 16)).astype(np.int32)
    engine = InferenceEngine(model, params)
    out = []

    def run(graph_fn, fused):
        return engine.generate_interleaved(
            graph_fn(), {"tokens": toks}, N_NEW, fused=fused)

    # ---- parity gates (also warm every executable) ----------------------
    lf, le = run(_log_graph, True), run(_log_graph, False)
    np.testing.assert_array_equal(np.asarray(lf.tokens),
                                  np.asarray(le.tokens))
    assert len(lf.logs) == N_NEW and len(le.logs) == N_NEW
    for (_, a), (_, b) in zip(lf.logs, le.logs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert engine.stats.snapshot()["islands_compiled"] >= 1

    gf, ge = run(_grad_graph, True), run(_grad_graph, False)
    np.testing.assert_array_equal(np.asarray(gf.tokens),
                                  np.asarray(ge.tokens))
    np.testing.assert_allclose(np.asarray(gf.saves["g"]),
                               np.asarray(ge.saves["g"]),
                               rtol=1e-4, atol=1e-5)
    assert np.any(np.asarray(gf.saves["g"]))

    # zero steady-state recompiles: the parity runs above warmed every
    # executable, so repeat log-instrumented generations must reuse them
    c0 = engine.stats.compiles
    run(_log_graph, True)
    assert engine.stats.compiles == c0, (
        "steady-state log-instrumented generation must not retrace"
    )

    timings = {
        name: timeit(lambda: run(graph_fn, fused), n=5, warmup=1)[0]
        for name, graph_fn, fused in (
            ("fused_log_decode", _log_graph, True),
            ("eager_log_decode", _log_graph, False),
            ("fused_grad_decode", _grad_graph, True),
            ("eager_grad_decode", _grad_graph, False),
        )
    }
    for pair in ("log", "grad"):
        fname, ename = f"fused_{pair}_decode", f"eager_{pair}_decode"
        speedup = timings[ename] / timings[fname]
        for name in (fname, ename):
            per_tok = timings[name] / N_NEW * 1e6
            derived = (f"speedup={speedup:.1f}x" if name == fname
                       else f"n_new={N_NEW}")
            out.append(Row(name, per_tok, derived, extra={
                "per_token_us": round(per_tok, 2),
                "total_ms": round(timings[name] * 1e3, 2),
                "speedup_vs_eager": round(speedup, 2),
                "n_new": N_NEW,
            }))

    # ---- stopped forward: compiled+cached vs unjitted -------------------
    cfg4 = _micro(n_layers=4)
    model4 = TransformerModel(cfg4)
    params4 = model4.init(jax.random.key(0))
    engine4 = InferenceEngine(model4, params4)
    batch = {"tokens": np.random.default_rng(1).integers(
        0, cfg4.vocab_size, (2, 16)).astype(np.int32)}

    def compiled_stop():
        saves, _ = engine4.execute(_stop_graph(), dict(batch), stop=True)
        return saves

    sched = engine4.schedule

    def eager_stop():
        g = _stop_graph()
        _out, saves, _logs = run_interleaved(
            engine4._model_fn, g, sched, (engine4.params, dict(batch)), {},
            mode=engine4.mode,
            stop_after_site=last_referenced_site(g, sched),
        )
        return jax.tree.map(lambda x: np.asarray(x), saves)

    np.testing.assert_allclose(
        np.asarray(compiled_stop()["h"]), np.asarray(eager_stop()["h"]),
        rtol=1e-5, atol=1e-6)
    stop_t = {
        "compiled_stop_forward": timeit(compiled_stop, n=10, warmup=2)[0],
        "eager_stop_forward": timeit(eager_stop, n=10, warmup=2)[0],
    }
    stop_speedup = stop_t["eager_stop_forward"] / stop_t[
        "compiled_stop_forward"]
    for name, mean in stop_t.items():
        derived = (f"speedup={stop_speedup:.1f}x"
                   if name.startswith("compiled") else "truncated@layer0")
        out.append(Row(name, mean * 1e6, derived, extra={
            "per_call_us": round(mean * 1e6, 2),
            "speedup_vs_eager": round(stop_speedup, 2),
        }))

    log_speedup = timings["eager_log_decode"] / timings["fused_log_decode"]
    assert log_speedup >= SPEEDUP_GATE, (
        f"the compiled log island must be >= {SPEEDUP_GATE}x faster per "
        f"token than the eager island it replaces at N={N_NEW}, got "
        f"{log_speedup:.2f}x"
    )
    return out
