"""Ragged-traffic co-tenancy: throughput under three scheduling policies.

Real traffic sends prompts of DIFFERENT lengths, so the exact-shape merger
(`pad_slack=0`, PR 1 and earlier) almost never groups requests and degrades
to the paper's sequential baseline (Appendix D.2: response time linear in
users).  This benchmark submits one burst of N requests with prompt lengths
drawn from a small range and measures

  sequential            — the paper's one-at-a-time queue,
  parallel/exact        — batch merging, exact length match only,
  parallel/padded       — padding-aware merging (this PR): lengths bucketed
                          by ``pad_slack``, shorter rows padded + masked.

`derived` reports executions (forwards actually run), the merged-group
sizes, and the padding-waste fraction — the cost the slack bounds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, build, timeit
from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.serving import NDIFServer, Request
from repro.serving.scheduler import CoTenantScheduler


def user_request(cfg, rng) -> Request:
    g = InterventionGraph()
    layer = int(rng.integers(0, cfg.n_layers))
    t = g.add("tap_get", site="layers.output", layer=layer)
    g.mark_saved("acts", g.add("save", Ref(t.id)))
    # the paper's fig9 workload, but RAGGED: prompts of 12..28 tokens
    seq = int(rng.integers(12, 29))
    toks = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks})


POLICIES = [
    ("sequential", dict(policy="sequential")),
    ("parallel_exact", dict(policy="parallel", pad_slack=0)),
    ("parallel_padded", dict(policy="parallel", pad_slack=16)),
]


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    out: list[Row] = []
    n_users = 24
    for name, kw in POLICIES:
        server = NDIFServer()
        server.host(cfg.name, model, params, max_batch_rows=128, **kw)
        sched = server.schedulers[cfg.name]
        engine = server.engines[cfg.name]

        def burst():
            rng = np.random.default_rng(7)
            tickets = [sched.submit(user_request(cfg, rng))
                       for _ in range(n_users)]
            sched.drain()
            assert all(t.error is None for t in tickets), [t.error for t in tickets]
            return tickets

        burst()  # warm: compile every group executable once
        e0 = engine.stats.executions
        mean_s, _ = timeit(burst, n=3, warmup=0)
        execs = (engine.stats.executions - e0) // 3
        snap = engine.stats.snapshot()
        out.append(Row(
            f"cotenancy_ragged/{name}/users_{n_users}",
            mean_s * 1e6 / n_users,  # us per request served
            f"executions={execs};groups={snap['group_sizes'][-8:]};"
            f"padding_waste={snap['padding_waste']:.3f}",
            extra={
                "executions_per_burst": execs,
                "group_sizes": snap["group_sizes"][-8:],
                "padding_waste": round(snap["padding_waste"], 4),
                "mean_group_size": round(snap["mean_group_size"], 3),
                "cap_splits_rows": snap["cap_splits_rows"],
                "cap_splits_cells": snap["cap_splits_cells"],
            },
        ))
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
