"""Generation decode-path benchmark: cached compiled step vs per-call jit.

The seed's ``InferenceEngine.generate`` rebuilt ``jax.jit(lambda ...)`` on
every call, so the decode step re-traced and re-compiled per ``generate()``
invocation.  The engine now caches ONE jitted prefill and ONE jitted decode
step; this module measures what that buys, plus the cost of riding an
intervention graph along the decode loop.

Rows:
  gen_cached_decode     engine.generate after warmup (zero retraces)
  gen_fresh_jit_decode  the seed's pattern: fresh jax.jit per call
  gen_interleaved_1step one decode step instrumented (logit collection)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, build, opt_suite, timeit
from repro.core.graph import InterventionGraph, Ref
from repro.serving.engine import InferenceEngine

N_NEW = 8


def rows() -> list[Row]:
    cfg = opt_suite(("2m",))["2m"]
    model, params = build(cfg)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16), np.int64)
        .astype(np.int32)
    )
    out = []

    engine = InferenceEngine(model, params)
    engine.generate(toks, max_new_tokens=N_NEW)  # warm the caches
    c0 = engine.stats.compiles
    mean, _ = timeit(lambda: engine.generate(toks, max_new_tokens=N_NEW), n=5)
    retr = engine.stats.compiles - c0
    out.append(Row("gen_cached_decode", mean * 1e6,
                   f"retraces_per_call={retr / 5:.1f}"))

    def fresh_jit_generate():
        # the seed's anti-pattern: a new jit closure every call
        B, S = toks.shape
        o, cache = model.prefill(params, {"tokens": toks},
                                 max_len=S + N_NEW)
        step = jax.jit(
            lambda p, c, t, ps: model.decode_step(
                p, c, {"token": t, "pos": ps})
        )
        tok = jnp.argmax(o["logits"][:, -1], -1).astype(jnp.int32)[:, None]
        for t in range(N_NEW - 1):
            pos = jnp.full((B,), S + t, jnp.int32)
            o, cache = step(params, cache, tok, pos)
            tok = jnp.argmax(o["logits"][:, 0], -1).astype(jnp.int32)[:, None]

    mean, _ = timeit(fresh_jit_generate, n=5, warmup=1)
    out.append(Row("gen_fresh_jit_decode", mean * 1e6,
                   "retraces_per_call=1.0"))

    g = InterventionGraph()
    t = g.add("tap_get", site="logits", step=3)
    sv = g.add("save", Ref(t.id))
    g.mark_saved("lg@step3", sv)
    engine.generate_interleaved(g, {"tokens": toks}, N_NEW)  # warm
    mean, _ = timeit(
        lambda: engine.generate_interleaved(g, {"tokens": toks}, N_NEW), n=5
    )
    out.append(Row("gen_interleaved_1step", mean * 1e6, "steps_tapped=1"))
    return out
