"""Invoke batching: N separate traces vs ONE N-invoke trace.

The paper's Fig. 3 multi-invoke API exists for throughput as much as for
ergonomics: declaring N prompts inside one ``lm.trace()`` lowers them into
ONE merged padded forward, so a user iterating over a prompt set pays one
model execution per *trace*, not one per *prompt*.  This benchmark times

  solo_traces     — N single-invoke traces, one forward each,
  one_trace       — one N-invoke trace, ONE merged forward (this PR),

over ragged prompts (lengths 12..28, the cotenancy_ragged workload) and
reports the per-prompt speedup plus the padding waste the merge paid —
``Tracer.pad_stats`` records real vs padded cells after lowering.

`derived` carries forwards-per-batch and the padding-waste fraction; the
same numbers land in BENCH_invoke_batching.json via ``Row.extra``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, build, timeit
from repro.models import registry as R
from repro.models.traced import traced_lm


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    lm = traced_lm(model, params)
    out: list[Row] = []
    n_prompts = 12
    rng = np.random.default_rng(7)
    prompts = [
        rng.integers(0, cfg.vocab_size,
                     (1, int(rng.integers(12, 29)))).astype(np.int32)
        for _ in range(n_prompts)
    ]
    layers = [int(rng.integers(0, cfg.n_layers)) for _ in range(n_prompts)]

    def solo_traces():
        acts = []
        for toks, layer in zip(prompts, layers):
            with lm.trace(toks):
                a = lm.layers[layer].output.save("acts")
            acts.append(np.asarray(a.value))
        return acts

    def one_trace():
        saves = []
        with lm.trace() as tr:
            for toks, layer in zip(prompts, layers):
                with tr.invoke(toks):
                    saves.append(lm.layers[layer].output.save("acts"))
        return [np.asarray(s.value) for s in saves], tr

    # correctness gate: merged-vs-solo at the usual 1e-5 (a 12-row batch
    # retiles GEMM reductions; see tests/test_ragged.py's noise baseline)
    ref = solo_traces()
    got, tr = one_trace()
    for r, g in zip(ref, got):
        np.testing.assert_allclose(r, g, rtol=1e-5, atol=1e-5)
    waste = tr.pad_stats["padded_cells"] / max(
        tr.pad_stats["padded_cells"] + tr.pad_stats["real_cells"], 1
    )

    solo_s, _ = timeit(solo_traces, n=5, warmup=1)
    one_s, _ = timeit(lambda: one_trace()[0], n=5, warmup=1)
    out.append(Row(
        f"invoke_batching/solo_traces/prompts_{n_prompts}",
        solo_s * 1e6 / n_prompts,
        f"forwards={n_prompts}",
        extra={"forwards_per_batch": n_prompts,
               "total_ms": round(solo_s * 1e3, 3)},
    ))
    out.append(Row(
        f"invoke_batching/one_trace/prompts_{n_prompts}",
        one_s * 1e6 / n_prompts,
        f"forwards=1;padding_waste={waste:.3f};"
        f"speedup={solo_s / one_s:.2f}x",
        extra={"forwards_per_batch": 1,
               "padding_waste": round(waste, 4),
               "speedup_vs_solo": round(solo_s / one_s, 3),
               "total_ms": round(one_s * 1e3, 3)},
    ))
    return out


if __name__ == "__main__":
    for row in rows():
        print(row.csv())
