"""Chaos harness: the live front door under a SEEDED fault plan.

Replays the live_serving Poisson client load (real threads, wire
protocol) while a deterministic :class:`FaultPlan` breaks the serving
stack on purpose — engine crashes mid-decode, a page-pool exhaustion
burst, lost transport messages in both directions, injected latency
spikes — plus deliberately doomed co-tenants (tiny ``deadline_ms``,
client-side cancels) riding next to the healthy load.

Asserted (hard failures, not just reported):
  * TERMINATION — every client ends with a result or a STRUCTURED error
    (a known machine-readable code); nothing hangs, nothing times out,
    nothing dies with an unstructured exception;
  * BIT-EXACTNESS — every surviving client's tokens match the solo
    synchronous path exactly, crashes and requeues notwithstanding;
  * the faults actually happened: ``faults_injected > 0`` and the
    supervisor performed ``engine_restarts >= 1``;
  * NO THREAD LEAKS — ``threading.active_count()`` returns to its
    pre-chaos baseline once the load drains;
  * RECOVERY REACHES STEADY STATE — a final fault-free pass over the
    same arrival schedule completes with ZERO additional XLA traces
    (the rebuilt loop reuses every cached executable) and full
    bit-exactness.

Reported: chaos-pass and recovery-pass tokens/s + p95, fault counters
(faults_injected / engine_restarts / tickets_requeued / cancellations /
deadline_evictions).  ``tokens_per_s`` (recovery pass) is gated
HIGHER-better by scripts/bench_check.py.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import Row, build
from repro.core.generation import SlotAllocationError
from repro.models import registry as R
from repro.serving import (
    AdmissionRefused,
    FaultError,
    FaultPlan,
    FaultSpec,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
    RetryPolicy,
    TicketError,
    TransportError,
)
from repro.serving import faults

N_CLIENTS = 80
N_JOBS = 8
NUM_SLOTS = 8
SLOT_MAX_LEN = 48
MAX_QUEUE_DEPTH = 32
SEQ_LEN = 6
STREAM_EVERY = 3    # every 3rd client streams
CANCEL_EVERY = 23   # these clients cancel right after admission
DEADLINE_EVERY = 29  # these clients carry an immediately-expiring deadline

#: terminal error codes the serving stack is ALLOWED to hand a client —
#: anything else (or any non-TicketError exception) fails the harness
STRUCTURED_CODES = {
    "deadline", "cancelled", "engine_restart", "engine_failed",
    "engine_stalled", "closed",
}


def make_jobs(cfg):
    rng = np.random.default_rng(17)
    jobs = []
    for _ in range(N_JOBS):
        toks = rng.integers(0, cfg.vocab_size, (1, SEQ_LEN)).astype(np.int32)
        n_new = int(rng.integers(4, 11))
        jobs.append((toks, n_new))
    return jobs


def chaos_plan(stats) -> FaultPlan:
    """The seeded fault schedule: same seed + same workload => the same
    fault sequence, so a chaos failure reproduces bit-for-bit."""
    return FaultPlan(
        [
            # two engine crashes mid-decode: supervisor restarts, requeues
            FaultSpec("decode.step", nth=6, error=FaultError,
                      message="chaos: injected engine crash #1"),
            FaultSpec("decode.step", nth=30, error=FaultError,
                      message="chaos: injected engine crash #2"),
            # latency spikes on decode windows (pure stalls, no error)
            FaultSpec("decode.step", every=13, delay_s=0.02, error=None,
                      max_fires=4),
            # one page-pool exhaustion burst at admission
            FaultSpec("page.alloc", nth=3, error=SlotAllocationError),
            # lossy transport, both directions (clients retry under
            # idempotency keys; polls are cursor reads)
            FaultSpec("transport.send", p=0.01, error=TransportError,
                      max_fires=6),
            FaultSpec("transport.recv", p=0.01, error=TransportError,
                      max_fires=6),
        ],
        seed=1234,
        stats=stats,
    )


def run_load(mk_client, jobs, arrivals, job_of, *, collect):
    """Replay one arrival schedule from real client threads.

    Each worker gets its OWN retrying client (per-client seeded jitter).
    Returns results, structured terminations, and hard errors (which the
    caller asserts empty).
    """
    t0 = time.perf_counter()
    lock = threading.Lock()
    out = {"resp": [], "refused": 0, "errors": [], "results": {},
           "structured": {}}

    def worker(i):
        client = mk_client(i)
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        toks, n_new = jobs[job_of[i]]
        deadline_ms = 1.0 if i % DEADLINE_EVERY == 7 else None
        submit_t = time.perf_counter()
        for _ in range(500):
            try:
                tk = client.submit(toks, n_new,
                                   stream=(i % STREAM_EVERY == 0),
                                   deadline_ms=deadline_ms)
            except AdmissionRefused as e:
                if e.code != "backpressure":
                    with lock:
                        out["errors"].append(f"{i}: refused {e.code}")
                    return
                with lock:
                    out["refused"] += 1
                time.sleep(max(e.retry_after_ms or 1.0, 1.0) / 1000.0)
                continue
            if i % CANCEL_EVERY == 5:
                tk.cancel()
            try:
                res = tk.result(timeout=900.0)
            except TicketError as e:
                with lock:
                    if e.code in STRUCTURED_CODES:
                        out["structured"][i] = e.code
                    else:
                        out["errors"].append(
                            f"{i}: unstructured code {e.code!r}: {e}"
                        )
                return
            except Exception as e:
                with lock:
                    out["errors"].append(f"{i}: {type(e).__name__}: {e}")
                return
            with lock:
                out["resp"].append(time.perf_counter() - submit_t)
                if collect:
                    out["results"][i] = np.asarray(res["tokens"])
            return
        with lock:
            out["errors"].append(f"{i}: starved after 500 refusals")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(arrivals))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    out["wall"] = time.perf_counter() - t0
    return out


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    server = NDIFServer()
    server.host("chaos", model, params, policy="continuous",
                num_slots=NUM_SLOTS, slot_max_len=SLOT_MAX_LEN,
                max_queue_depth=MAX_QUEUE_DEPTH,
                door_kwargs=dict(max_restarts=10, restart_backoff_s=0.01,
                                 quarantine_after=4))
    engine = server.engines["chaos"]
    jobs = make_jobs(cfg)

    def mk_client(i):
        return NDIFClient(
            LoopbackTransport(server.handle), "chaos",
            retry=RetryPolicy(max_attempts=8, base_delay_ms=2.0, seed=i),
        )

    base_client = NDIFClient(LoopbackTransport(server.handle), "chaos")
    refs = [np.asarray(base_client.generate(toks, n)["tokens"])
            for toks, n in jobs]

    rng = np.random.default_rng(23)
    job_of = rng.integers(0, N_JOBS, N_CLIENTS)

    # warmup: every admission-group row count + the window ladder, so the
    # chaos AND recovery passes run against cached executables only
    for g in range(1, NUM_SLOTS + 1):
        tickets = [base_client.submit(*jobs[k % N_JOBS]) for k in range(g)]
        for tk in tickets:
            tk.result(timeout=900.0)

    step = engine.stats.step_cost_ema or 0.01
    mean_tokens = float(np.mean([n for _, n in jobs]))
    service_rate = NUM_SLOTS / (mean_tokens * step)
    gaps = rng.exponential(1.0 / (1.2 * service_rate), N_CLIENTS)
    arrivals = np.cumsum(gaps)

    threads_before = threading.active_count()
    restarts_before = engine.stats.engine_restarts

    # ---------------------------------------------------------- chaos pass
    plan = chaos_plan(engine.stats)
    with faults.inject(plan):
        load = run_load(mk_client, jobs, arrivals, job_of, collect=True)
    assert not load["errors"], load["errors"][:5]

    # TERMINATION: every client has a result or a structured error
    accounted = len(load["results"]) + len(load["structured"])
    assert accounted == N_CLIENTS, (
        f"{N_CLIENTS - accounted} clients unaccounted for"
    )
    # the doomed co-tenants really terminated via their structured path
    assert any(c == "deadline" for c in load["structured"].values())
    assert any(c == "cancelled" for c in load["structured"].values())

    # BIT-EXACTNESS for every survivor, streamed or batch
    for i, toks_out in load["results"].items():
        np.testing.assert_array_equal(
            toks_out, refs[job_of[i]],
            err_msg=f"client {i} diverged from solo after recovery",
        )

    # the chaos actually happened, and the supervisor recovered from it
    assert plan.fires() > 0, "fault plan never fired"
    restarts = engine.stats.engine_restarts - restarts_before
    assert restarts >= 1, "no supervised engine restart happened"
    chaos_resp = np.asarray(load["resp"])
    chaos_tokens = int(sum(jobs[job_of[i]][1] for i in load["results"]))
    chaos_tok_s = chaos_tokens / load["wall"]

    # NO THREAD LEAKS: workers joined, supervisor still owns ONE engine
    # thread, nothing else survived the chaos
    deadline = time.time() + 10.0
    while threading.active_count() > threads_before \
            and time.time() < deadline:
        time.sleep(0.05)
    assert threading.active_count() <= threads_before, (
        f"thread leak: {threads_before} before chaos, "
        f"{threading.active_count()} after "
        f"({[t.name for t in threading.enumerate()]})"
    )

    # ------------------------------------------------------- recovery pass
    # fault-free replay of the SAME schedule: the recovered door must be
    # in steady state — zero additional XLA traces, full bit-exactness
    compiles_before = engine.stats.compiles
    load2 = run_load(mk_client, jobs, arrivals, job_of, collect=True)
    compiles_delta = engine.stats.compiles - compiles_before
    assert not load2["errors"], load2["errors"][:5]
    assert len(load2["results"]) + len(load2["structured"]) == N_CLIENTS
    survivors2 = {i for i in range(N_CLIENTS)
                  if i % CANCEL_EVERY != 5 and i % DEADLINE_EVERY != 7}
    assert set(load2["results"]) == survivors2
    for i, toks_out in load2["results"].items():
        np.testing.assert_array_equal(toks_out, refs[job_of[i]])
    assert compiles_delta == 0, (
        f"recovered door performed {compiles_delta} XLA traces"
    )

    resp2 = np.asarray(load2["resp"])
    tokens2 = int(sum(jobs[job_of[i]][1] for i in load2["results"]))
    tok_s2 = tokens2 / load2["wall"]

    snap = engine.stats.snapshot()
    server.shutdown()
    return [Row(
        f"chaos_serving/recovery/clients_{N_CLIENTS}",
        float(np.mean(resp2)) * 1e6,
        f"tok_s={tok_s2:.1f};restarts={restarts};"
        f"faults={snap['faults_injected']}",
        extra={
            "tokens_per_s": round(tok_s2, 2),
            "p95_ms": round(float(np.percentile(resp2, 95)) * 1e3, 3),
            # chaos-pass numbers are deliberately NOT gate-matching keys:
            # the pass includes crashes, backoff and restarts by design
            "chaos_pass_tok_s": round(chaos_tok_s, 2),
            "chaos_pass_tail_ms": round(
                float(np.percentile(chaos_resp, 95)) * 1e3, 3),
            "clients": N_CLIENTS,
            "faults_injected": snap["faults_injected"],
            "engine_restarts": snap["engine_restarts"],
            "tickets_requeued": snap["tickets_requeued"],
            "cancellations": snap["cancellations"],
            "deadline_evictions": snap["deadline_evictions"],
            "alloc_retries": snap["alloc_retries"],
            "structured_errors": len(load["structured"]),
            "refused_backpressure": load["refused"] + load2["refused"],
            "compiles_recovery_phase": 0,
        },
    )]


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
