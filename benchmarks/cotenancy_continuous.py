"""Staggered-arrival co-tenancy: burst-drain vs CONTINUOUS batching.

The ragged benchmark submits one synchronized burst — the friendliest shape
for per-drain group merging.  Real traffic ("millions of users", ROADMAP) is
STAGGERED: a request that arrives one step after a group launches its decode
loop waits, under burst-drain, for the whole loop to finish.  Continuous
batching admits it into the RUNNING loop at the next step boundary instead.

Method: a deterministic Poisson-ish arrival schedule (fixed inter-arrival
pattern scaled to the measured decode-step time) is replayed against three
policies on a virtual clock that advances by MEASURED wall time of each
compute call — arrivals gate admission exactly as they would in a live
server, with no sleeping:

  sequential  — one request at a time (the paper's Appendix D.2 queue);
  burst-drain — parallel co-tenancy, groups formed per drain (PR 2);
  continuous  — slot-table decode loop with in-flight admission (this PR).

Reported: p50/p95 response time (submit -> finish on the virtual clock) and
mean slot occupancy.  Every policy serves IDENTICAL requests after an
untimed warmup pass that absorbs compiles.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, build
from repro.core.graph import InterventionGraph
from repro.models import registry as R
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request

N_USERS = 16
NUM_SLOTS = 4
PAD_SLACK = 7
SLOT_MAX_LEN = 48


def workload(cfg):
    """(tokens, max_new_tokens, arrival_slot) per user — deterministic
    'Poisson-ish' offsets: irregular inter-arrival gaps from a fixed
    pattern, measured in decode-step units."""
    rng = np.random.default_rng(7)
    gaps = [((3 * i) % 5 + (i % 3)) / 2.0 for i in range(N_USERS)]
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(N_USERS):
        seq = int(rng.integers(8, 16))       # one pad_slack=7 bucket
        n_new = int(rng.integers(4, 12))     # rows retire independently
        toks = rng.integers(0, cfg.vocab_size, (1, seq)).astype(np.int32)
        out.append((toks, n_new, float(arrivals[i])))
    return out


def _percentiles(resp):
    return (float(np.percentile(resp, 50)), float(np.percentile(resp, 95)))


# Each policy replays the SAME staggered schedule REPLAYS times and reports
# the last pass: the first passes absorb the compiles for exactly the group /
# admission shapes this arrival pattern produces, so the reported numbers are
# the steady state of a warm server, not trace time.
REPLAYS = 3


def run_sequential(model, params, jobs, step_unit):
    engine = InferenceEngine(model, params)

    def replay():
        clock, resp = 0.0, []
        for toks, n_new, arrive_slots in jobs:
            arrive = arrive_slots * step_unit
            start = max(clock, arrive)
            t0 = time.perf_counter()
            engine.generate_interleaved(
                InterventionGraph(), {"tokens": toks}, n_new)
            clock = start + (time.perf_counter() - t0)
            resp.append(clock - arrive)
        return resp

    for _ in range(REPLAYS - 1):
        replay()
    return replay(), engine


def run_burst(model, params, jobs, step_unit):
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel",
                              pad_slack=PAD_SLACK, max_batch_rows=NUM_SLOTS)

    def replay():
        clock, resp = 0.0, []
        pending = [(toks, n, a * step_unit) for toks, n, a in jobs]
        while pending:
            arrived = [j for j in pending if j[2] <= clock]
            if not arrived:
                clock = min(j[2] for j in pending)
                continue
            pending = [j for j in pending if j[2] > clock]
            for toks, n_new, _ in arrived:
                sched.submit(Request(graph=InterventionGraph(),
                                     batch={"tokens": toks},
                                     max_new_tokens=n_new))
            t0 = time.perf_counter()
            sched.drain()
            clock += time.perf_counter() - t0
            resp.extend(clock - a for _, _, a in arrived)
        return resp

    for _ in range(REPLAYS - 1):
        replay()
    return replay(), engine


def run_continuous(model, params, jobs, step_unit):
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="continuous",
                              pad_slack=PAD_SLACK, num_slots=NUM_SLOTS,
                              slot_max_len=SLOT_MAX_LEN)

    def replay():
        arrival_of = {}
        clock, resp = 0.0, []
        pending = [(toks, n, a * step_unit) for toks, n, a in jobs]
        inflight = 0
        while pending or inflight:
            for toks, n_new, arrive in [j for j in pending
                                        if j[2] <= clock]:
                req = Request(graph=InterventionGraph(),
                              batch={"tokens": toks}, max_new_tokens=n_new)
                sched.submit(req)
                arrival_of[req.request_id] = arrive
                inflight += 1
            pending = [j for j in pending if j[2] > clock]
            if not inflight:
                clock = min(j[2] for j in pending)
                continue
            t0 = time.perf_counter()
            finished = sched.pump()  # admit -> ONE step -> retirements
            clock += time.perf_counter() - t0
            for ticket in finished:
                resp.append(clock - arrival_of[ticket.request_id])
                inflight -= 1
        return resp

    for _ in range(REPLAYS - 1):
        replay()
    return replay(), engine


POLICIES = [
    ("sequential", run_sequential),
    ("burst_drain", run_burst),
    ("continuous", run_continuous),
]


def rows() -> list[Row]:
    cfg = R.get_config("paper-gpt-small")
    model, params = build(cfg)
    jobs = workload(cfg)

    # calibrate the arrival-slot unit to the measured decode-step time of a
    # warm slot loop, so "one slot late" means one decode step late
    engine = InferenceEngine(model, params)
    loop = engine.start_decode_loop(NUM_SLOTS, SLOT_MAX_LEN)
    loop.admit(InterventionGraph(), {"tokens": jobs[0][0]}, 4)
    loop.step()
    t0 = time.perf_counter()
    loop.step()
    step_unit = time.perf_counter() - t0
    loop.run_to_completion()

    out: list[Row] = []
    for attempt in range(2):
        out.clear()
        p95s = {}
        for name, fn in POLICIES:
            resp, eng = fn(model, params, jobs, step_unit)
            assert len(resp) == N_USERS
            p50, p95 = _percentiles(resp)
            p95s[name] = p95
            snap = eng.stats.snapshot()
            occ = snap["slot_occupancy"]
            out.append(Row(
                f"cotenancy_continuous/{name}/users_{N_USERS}",
                float(np.mean(resp)) * 1e6,
                f"p50_ms={p50 * 1e3:.2f};p95_ms={p95 * 1e3:.2f};"
                f"slot_occupancy={occ:.2f}",
                extra={
                    "p50_ms": round(p50 * 1e3, 3),
                    "p95_ms": round(p95 * 1e3, 3),
                    "mean_ms": round(float(np.mean(resp)) * 1e3, 3),
                    "response_ms": [round(r * 1e3, 3) for r in sorted(resp)],
                    "slot_occupancy": round(occ, 4),
                    "padding_waste": round(snap["padding_waste"], 4),
                    "admissions": snap["admissions"],
                    "slot_steps": snap["slot_steps"],
                    "step_unit_ms": round(step_unit * 1e3, 3),
                },
            ))
        if p95s["continuous"] < p95s["burst_drain"]:
            break
        # wall-clock noise (a co-tenant process mid-replay) can invert one
        # measurement; remeasure once before declaring the claim false
    # the tentpole claim, checked where the numbers are produced
    assert p95s["continuous"] < p95s["burst_drain"], (
        "continuous admission should beat burst-drain p95 under staggered "
        f"arrivals: {p95s}"
    )
    return out


if __name__ == "__main__":
    for r in rows():
        print(r.csv())
