"""Dev smoke for the core intervention-graph machinery (not a pytest file)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import taps
from repro.core.interleave import SiteSchedule
from repro.core.serialize import dumps, loads
from repro.core.tracer import TracedModel


def make_tiny(n_layers=3, d=4):
    params = {
        "w": [np.eye(d, dtype=np.float32) * (i + 1) for i in range(n_layers)],
    }

    def model_fn(params, x):
        h = taps.site("embed", x)
        for i in range(n_layers):
            h = taps.site("layers.input", h, layer=i)
            h = h @ params["w"][i]
            h = taps.site("layers.output", h, layer=i)
        return taps.site("logits", h)

    order = [("embed", None)]
    for i in range(n_layers):
        order += [("layers.input", i), ("layers.output", i)]
    order += [("logits", None)]
    return TracedModel(model_fn, params, SiteSchedule(order=order), name="tiny")


def main():
    lm = make_tiny()
    x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)

    # 1. plain read
    with lm.trace(x):
        h1 = lm.layers[1].output.save()
        out = lm.output.save()
    expect = np.asarray(x) @ np.eye(4) @ (np.eye(4) * 2)
    np.testing.assert_allclose(np.asarray(h1.value), expect)
    np.testing.assert_allclose(np.asarray(out.value), expect * 3)
    print("read ok")

    # 2. setter with indexing write-back
    with lm.trace(x):
        lm.layers[0].output[0, :] = 0.0
        out = lm.output.save()
    expect2 = np.asarray(x).copy()
    expect2 = expect2 @ np.eye(4)
    expect2[0, :] = 0
    expect2 = expect2 @ (np.eye(4) * 2) @ (np.eye(4) * 3)
    np.testing.assert_allclose(np.asarray(out.value), expect2)
    print("setter ok")

    # 3. activation patching idiom (row 1 <- row 0)
    with lm.trace(x):
        lm.layers[1].output[1, :] = lm.layers[1].output[0, :]
        out = lm.output.save()
    h = np.asarray(x) @ np.eye(4) @ (np.eye(4) * 2)
    h[1] = h[0]
    np.testing.assert_allclose(np.asarray(out.value), h @ (np.eye(4) * 3))
    print("patching ok")

    # 4. ops on proxies + save of derived value
    with lm.trace(x) as tr:
        m = (lm.layers[2].output * 2.0).mean().save("m")
    np.testing.assert_allclose(np.asarray(m.value), (expect * 3 * 2).mean())
    print("proxy-ops ok")

    # 5. serialization roundtrip mid-experiment
    with lm.trace(x) as tr:
        tr._deferred = True  # build only
        lm.layers[0].output[0, :] = 1.5
        lm.output.save("out")
    blob = dumps(tr.graph)
    g2 = loads(blob)
    assert len(g2) == len(tr.graph)
    from repro.core.interleave import run_interleaved

    _, saves, _ = run_interleaved(
        lm.wrapped_fn, g2, lm.schedule, (lm.params, x), {}
    )
    base = np.asarray(x).copy()
    base[0, :] = 1.5
    np.testing.assert_allclose(
        np.asarray(saves["out"]), base @ (np.eye(4) * 2) @ (np.eye(4) * 3)
    )
    print("serialize ok")

    # 6. grads
    with lm.trace(x) as tr:
        g = lm.layers[1].output.grad.save("g")
        loss = lm.output.save("o").sum().save("loss")
        tr.backward(loss)
    # dL/dh1 where out = h1 @ (3I); dL/dout = ones -> grad = ones @ (3I)^T = 3
    np.testing.assert_allclose(np.asarray(tr.result("g")), np.full((2, 4), 3.0))
    print("grad ok")

    # 7. jit the whole interleaved run
    from repro.core.interleave import run_interleaved

    with lm.trace(x) as tr:
        tr._deferred = True
        lm.layers[1].output[0, 0] = 7.0
        lm.output.save("out")

    @jax.jit
    def jitted(params, x):
        _, saves, _ = run_interleaved(
            lm.wrapped_fn, tr.graph, lm.schedule, (params, x), {}
        )
        return saves["out"]

    r = jitted(lm.params, x)
    h = np.asarray(x) @ np.eye(4) @ (np.eye(4) * 2)
    h[0, 0] = 7.0
    np.testing.assert_allclose(np.asarray(r), h @ (np.eye(4) * 3))
    print("jit ok")

    print("ALL CORE SMOKE PASSED")


if __name__ == "__main__":
    main()
