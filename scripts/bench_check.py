"""Benchmark regression gate: diff fresh BENCH_*.json against a baseline.

The benchmark harness (``python -m benchmarks.run``) writes machine-readable
``benchmarks/out/BENCH_<name>.json`` per module.  This script compares a
fresh run against the committed baseline (``benchmarks/baseline/``) and
fails when any row regresses past the tolerance — the perf counterpart of
the parity tests, so a PR cannot silently give back the wins earlier PRs
measured.

Comparison rules, per (benchmark, row name):
  * ``us_per_call`` must satisfy fresh <= baseline * (1 + tol);
  * any numeric ``extra`` key containing ``p95`` (the tail-latency stats the
    co-tenancy benchmarks attach) is held to the same tolerance;
  * any numeric ``extra`` key containing ``tokens_per_s`` (live-serving
    throughput) is gated HIGHER-better: fresh >= baseline * (1 - tol);
  * rows/benchmarks present only in one side are reported but never fail
    (new benchmarks land without a baseline; a partial --only run skips
    modules).

The default tolerance is deliberately loose (50%): these benchmarks run on
shared CPU containers where wall-clock noise is real (see the repo notes —
never gate on numbers taken while a test job is running).  The gate exists
to catch order-of-magnitude regressions (a lost cache, an accidental
retrace per call), not 10% drift.

Usage:
  python scripts/bench_check.py                       # compare out/ vs baseline/
  python scripts/bench_check.py --tol 0.25            # tighter gate
  python scripts/bench_check.py --only fused_decode   # one benchmark
  python scripts/bench_check.py --update              # bless fresh as baseline
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys

DEFAULT_FRESH = "benchmarks/out"
DEFAULT_BASELINE = "benchmarks/baseline"


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    return {row["name"]: row for row in payload.get("rows", [])}


def p95_keys(row: dict) -> dict[str, float]:
    """Numeric extra entries that look like tail-latency stats."""
    out = {}
    for k, v in (row.get("extra") or {}).items():
        if "p95" in k and isinstance(v, (int, float)):
            out[k] = float(v)
    return out


def throughput_keys(row: dict) -> dict[str, float]:
    """Numeric extra entries that are throughputs (HIGHER is better)."""
    out = {}
    for k, v in (row.get("extra") or {}).items():
        if "tokens_per_s" in k and isinstance(v, (int, float)):
            out[k] = float(v)
    return out


def compare_file(
    name: str, fresh: dict[str, dict], base: dict[str, dict], tol: float
) -> tuple[list[str], list[str]]:
    """Returns (regressions, notes) for one benchmark module."""
    regressions, notes = [], []
    for row_name, b in base.items():
        f = fresh.get(row_name)
        if f is None:
            notes.append(f"{name}:{row_name}: missing from fresh run")
            continue
        fv, bv = float(f["us_per_call"]), float(b["us_per_call"])
        if bv > 0 and fv > bv * (1.0 + tol):
            regressions.append(
                f"{name}:{row_name}: us_per_call {fv:.1f} vs baseline "
                f"{bv:.1f} (+{(fv / bv - 1) * 100:.0f}%, tol "
                f"{tol * 100:.0f}%)"
            )
        fp95, bp95 = p95_keys(f), p95_keys(b)
        for k, bval in bp95.items():
            fval = fp95.get(k)
            if fval is None or bval <= 0:
                continue
            if fval > bval * (1.0 + tol):
                regressions.append(
                    f"{name}:{row_name}: {k} {fval:.1f} vs baseline "
                    f"{bval:.1f} (+{(fval / bval - 1) * 100:.0f}%)"
                )
        fthr, bthr = throughput_keys(f), throughput_keys(b)
        for k, bval in bthr.items():
            fval = fthr.get(k)
            if fval is None or bval <= 0:
                continue
            if fval < bval * (1.0 - tol):
                regressions.append(
                    f"{name}:{row_name}: {k} {fval:.1f} vs baseline "
                    f"{bval:.1f} ({(fval / bval - 1) * 100:.0f}%, "
                    f"higher-better tol {tol * 100:.0f}%)"
                )
    for row_name in fresh:
        if row_name not in base:
            notes.append(f"{name}:{row_name}: new row (no baseline)")
    return regressions, notes


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fail when fresh benchmark JSON regresses past baseline"
    )
    ap.add_argument("--fresh", default=DEFAULT_FRESH,
                    help=f"fresh BENCH_*.json dir (default {DEFAULT_FRESH})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline dir (default {DEFAULT_BASELINE})")
    ap.add_argument("--tol", type=float, default=0.5,
                    help="allowed fractional regression (default 0.5 = 50%%)")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh JSONs over the baseline and exit")
    args = ap.parse_args()

    fresh_files = {
        os.path.basename(p): p
        for p in sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json")))
    }
    if args.only:
        fresh_files = {n: p for n, p in fresh_files.items()
                       if args.only in n}
    if args.update:
        os.makedirs(args.baseline, exist_ok=True)
        for name, path in fresh_files.items():
            shutil.copy2(path, os.path.join(args.baseline, name))
            print(f"blessed {name}")
        return 0

    base_files = {
        os.path.basename(p): p
        for p in sorted(glob.glob(os.path.join(args.baseline,
                                               "BENCH_*.json")))
    }
    if args.only:
        base_files = {n: p for n, p in base_files.items() if args.only in n}
    if not base_files:
        print(f"no baseline JSONs under {args.baseline}; run the "
              "benchmarks and bless them with --update", file=sys.stderr)
        return 2

    all_regressions, all_notes = [], []
    for name, bpath in base_files.items():
        fpath = fresh_files.get(name)
        if fpath is None:
            all_notes.append(f"{name}: not present in fresh run — skipped")
            continue
        regs, notes = compare_file(
            name.removeprefix("BENCH_").removesuffix(".json"),
            load_rows(fpath), load_rows(bpath), args.tol,
        )
        all_regressions.extend(regs)
        all_notes.extend(notes)
    for name in fresh_files:
        if name not in base_files:
            all_notes.append(f"{name}: new benchmark (no baseline)")

    for note in all_notes:
        print(f"note: {note}")
    if all_regressions:
        print(f"\n{len(all_regressions)} regression(s) past "
              f"{args.tol * 100:.0f}% tolerance:", file=sys.stderr)
        for reg in all_regressions:
            print(f"  REGRESSION {reg}", file=sys.stderr)
        return 1
    print(f"OK: {len(base_files)} benchmark file(s) within "
          f"{args.tol * 100:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
