#!/usr/bin/env python
"""Lint intervention graphs statically — zero model forwards.

    PYTHONPATH=src python scripts/lint_graph.py trace.json [more.json ...]
    PYTHONPATH=src python scripts/lint_graph.py --steps 8 decode_trace.json
    PYTHONPATH=src python scripts/lint_graph.py --model paper-gpt-small t.json
    PYTHONPATH=src python scripts/lint_graph.py --all-examples
    PYTHONPATH=src python scripts/lint_graph.py --all-examples --summary

Positional arguments are serialized wire graphs (the ``graph_to_json``
payload an NDIF client ships).  Without ``--model`` the lint is purely
structural — op registry, step flow, dead nodes; with ``--model NAME``
the named architecture is built ABSTRACTLY (``jax.eval_shape`` init, no
weights materialized) so shape/dtype inference runs too.

``--all-examples`` lints the graph each ``examples/`` script builds
(plus the ``benchmarks/compiled_islands.py`` island workloads), with
full shape facts, and exits nonzero if any is broken.  The graphs are
reconstructed here rather than imported (several examples execute
full-size models at import time); each builder mirrors its example's
trace body node-for-node.  ``--summary`` appends one machine-readable
JSON line tabulating FusionVerdict reasons per generation graph.

Exit status: 0 all graphs clean, 1 any error diagnostic, 2 bad input.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.core import analysis
from repro.core.graph import ALL_STEPS, InterventionGraph, Ref
from repro.core.serialize import graph_from_json


# --------------------------------------------------------------------------
# example graphs — each mirrors the trace body of one examples/ script
# --------------------------------------------------------------------------

def _quickstart_graph() -> InterventionGraph:
    # examples/quickstart.py: boost three MLP neurons at layer 4, read the
    # (post-intervention) logits and a mid-stack residual stream.
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.mlp.output", layer=4)
    path = ((slice(None), slice(None), slice(0, 3)),)
    cur = g.add("apply_path", Ref(t.id), path)
    up = g.add("add", Ref(cur.id), 10.0)
    boosted = g.add("update_path", Ref(t.id), path, Ref(up.id))
    g.add("tap_set", Ref(boosted.id), site="layers.mlp.output", layer=4)
    h = g.add("tap_get", site="layers.output", layer=4)
    g.mark_saved("hidden", g.add("save", Ref(h.id)))
    o = g.add("tap_get", site="logits")
    g.mark_saved("logits", g.add("save", Ref(o.id)))
    return g


def _activation_patching_graph(layer: int = 4) -> InterventionGraph:
    # examples/activation_patching.py: copy the edit prompt's residual
    # stream (row 0) into the base prompt (row 1) at one layer, read the
    # answer logit-diff of the patched base row.
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=layer)
    src = g.add("getitem", Ref(t.id), (0, slice(None), slice(None)))
    upd = g.add(
        "update_path", Ref(t.id), ((1, slice(None), slice(None)),),
        Ref(src.id),
    )
    g.add("tap_set", Ref(upd.id), site="layers.output", layer=layer)
    o = g.add("tap_get", site="logits")
    a = g.add("getitem", Ref(o.id), (1, -1, 7))
    b = g.add("getitem", Ref(o.id), (1, -1, 11))
    d = g.add("sub", Ref(a.id), Ref(b.id))
    g.mark_saved("d", g.add("save", Ref(d.id)))
    return g


def _multi_invoke_graph() -> InterventionGraph:
    # examples/multi_invoke.py (early-stop trace): read layer 2 and stop —
    # the analyzer should infer a stop site so layers 3.. never execute.
    g = InterventionGraph()
    h = g.add("tap_get", site="layers.output", layer=2)
    g.mark_saved("h", g.add("save", Ref(h.id)))
    return g


def _steered_generation_graph(n_steps: int = 8) -> InterventionGraph:
    # examples/steered_generation.py: steer layer-2 MLP output at decode
    # steps 3..5 only, save every step's logits under one stacked name,
    # and log() each step's max logit (lowered to jax.debug.callback
    # inside the fused scan — no eager island).
    g = InterventionGraph()
    for s in range(3, 6):
        t = g.add("tap_get", site="layers.mlp.output", layer=2, step=s)
        up = g.add("add", Ref(t.id), 25.0, step=s)
        g.add("tap_set", Ref(up.id), site="layers.mlp.output", layer=2,
              step=s)
    for s in range(n_steps):
        o = g.add("tap_get", site="logits", step=s)
        g.mark_saved("logits", g.add("save", Ref(o.id), step=s))
        m = g.add("jnp.max", Ref(o.id), step=s)
        g.add("log", Ref(m.id), step=s)
    return g


def _attention_steering_graph() -> InterventionGraph:
    # attention-pattern readout + uniform steering vector on one head's
    # value stream (the remote-training examples' probe readout shape).
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.attn.output", layer=3)
    vec = g.add("constant", 0.05)
    up = g.add("add", Ref(t.id), Ref(vec.id))
    g.add("tap_set", Ref(up.id), site="layers.attn.output", layer=3)
    o = g.add("tap_get", site="logits")
    g.mark_saved("out", g.add("save", Ref(o.id)))
    return g


def _broadcast_steering_graph() -> InterventionGraph:
    # steering applied at EVERY decode step (ALL_STEPS broadcast setter)
    # with a final-step logits read — the serving co-tenancy examples'
    # per-request shape.
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=1, step=ALL_STEPS)
    up = g.add("mul", Ref(t.id), 1.01, step=ALL_STEPS)
    g.add("tap_set", Ref(up.id), site="layers.output", layer=1,
          step=ALL_STEPS)
    o = g.add("tap_get", site="logits", step=0)
    g.mark_saved("first", g.add("save", Ref(o.id), step=0))
    return g


def _islands_log_graph(n_steps: int = 8) -> InterventionGraph:
    # benchmarks/compiled_islands.py (log workload): a scalar log() tap on
    # every decode step.  Pre-harvest this forced the whole stretch eager
    # (FusionVerdict reason "log"); now it lowers to jax.debug.callback
    # inside the fused scan and the verdict is clean.
    g = InterventionGraph()
    for s in range(n_steps):
        t = g.add("tap_get", site="logits", step=s)
        m = g.add("jnp.mean", Ref(t.id), step=s)
        g.add("log", Ref(m.id), step=s)
        g.mark_saved("logits", g.add("save", Ref(t.id), step=s))
    return g


def _islands_grad_graph(n_steps: int = 8) -> InterventionGraph:
    # benchmarks/compiled_islands.py (grad workload): a backward loss on
    # one decode step with the gradient read at an MLP site.  Pre-harvest
    # this was an eager island (reason "grad"); now the perturbation
    # driver differentiates the step inside the fused scan body.
    g = InterventionGraph()
    gg = g.add("grad_get", site="layers.mlp.output", layer=1, step=1)
    g.mark_saved("g", g.add("save", Ref(gg.id), step=1))
    t = g.add("tap_get", site="logits", step=1)
    sq = g.add("mul", Ref(t.id), Ref(t.id), step=1)
    loss = g.add("jnp.sum", Ref(sq.id), step=1)
    g.backward_loss = loss.id
    return g


def _islands_cross_layer_graph() -> InterventionGraph:
    # benchmarks/compiled_islands.py (cross-layer workload): FORWARD
    # cross-layer flow — read layer 0, steer layer 3 with it, every decode
    # step.  Pre-harvest scan mode rejected any cross-layer setter flow
    # ("scan-cross-layer"); the carry-threaded env lifts the forward case
    # (backward flow stays rejected — the value does not exist yet).
    g = InterventionGraph()
    src = g.add("tap_get", site="layers.output", layer=0, step=ALL_STEPS)
    scaled = g.add("mul", Ref(src.id), 0.1, step=ALL_STEPS)
    dst = g.add("tap_get", site="layers.output", layer=3, step=ALL_STEPS)
    new = g.add("add", Ref(dst.id), Ref(scaled.id), step=ALL_STEPS)
    g.add("tap_set", Ref(new.id), site="layers.output", layer=3,
          step=ALL_STEPS)
    o = g.add("tap_get", site="logits", step=0)
    g.mark_saved("first", g.add("save", Ref(o.id), step=0))
    return g


def _continuous_serving_merge_plan():
    # examples/continuous_serving.py, the boundary after Bob retires:
    # Alice holds row 0 and Carol row 2, so the free rows {1, 3} are
    # NON-CONTIGUOUS — Dana's 2-row request is placed through the paged
    # allocator's index-array starts.  The plan lint must prove the row
    # sets pairwise disjoint (the write-write safety proof) exactly as it
    # does for contiguous spans.
    alice = InterventionGraph()
    t = alice.add("tap_get", site="logits", step=0)
    alice.mark_saved("lg", alice.add("save", Ref(t.id), step=0))
    graphs = [alice, InterventionGraph(), InterventionGraph()]
    sizes = [1, 1, 2]
    starts = [0, (2,), (1, 3)]
    return graphs, sizes, starts, 4


# name -> builder returning (graphs, sizes, starts, num_rows); these mirror
# admission boundaries the examples produce, with index-array starts where
# the paged allocator lands requests on scattered free rows
EXAMPLE_MERGE_PLANS: dict[str, object] = {
    "continuous_serving": _continuous_serving_merge_plan,
}


# label -> (builder, n_steps or None); n_steps marks generation graphs.
# The "islands" entries mirror benchmarks/compiled_islands.py — workloads
# that pre-harvest forced out of the fused path (log / grad / cross-layer).
EXAMPLE_GRAPHS: dict[str, tuple] = {
    "examples/quickstart": (_quickstart_graph, None),
    "examples/activation_patching": (_activation_patching_graph, None),
    "examples/multi_invoke": (_multi_invoke_graph, None),
    "examples/steered_generation": (_steered_generation_graph, 8),
    "examples/attention_steering": (_attention_steering_graph, None),
    "examples/broadcast_steering": (_broadcast_steering_graph, 8),
    "benchmarks/islands:log": (_islands_log_graph, 8),
    "benchmarks/islands:grad": (_islands_grad_graph, 8),
    "benchmarks/islands:cross_layer": (_islands_cross_layer_graph, 8),
}


# --------------------------------------------------------------------------
# model facts — abstract build, no weights
# --------------------------------------------------------------------------

class ModelFacts:
    """Site schedules + avals of one architecture, captured abstractly."""

    def __init__(self, name: str, *, batch=(2, 12), n_steps: int = 8):
        from repro.core.generation import _step_order
        from repro.models import registry as R

        cfg = R.get_config(name)
        self.model = R.build_model(name, cfg)
        # abstract params: shapes/dtypes only, nothing materialized
        self.params = jax.eval_shape(self.model.init, jax.random.key(0))
        B, S = batch
        tokens = jax.ShapeDtypeStruct((B, S), "int32")
        self.schedule = self.model.site_schedule("unrolled")
        self.site_avals = analysis.capture_forward_avals(
            lambda p, b: self.model.forward(p, b, mode="unrolled"),
            (self.params, {"tokens": tokens}),
        )
        self.step_schedule = _step_order(self.model.site_schedule("scan"))
        pre, dec = analysis.capture_generation_avals(
            self.model, self.params, {"tokens": tokens},
            max_len=S + n_steps, mode="scan",
        )
        self.gen_prefill_avals, self.decode_avals = pre, dec


# --------------------------------------------------------------------------
# lint driver
# --------------------------------------------------------------------------

def lint_graph(graph: InterventionGraph, label: str, *,
               facts: ModelFacts | None = None,
               n_steps: int | None = None) -> analysis.AnalysisReport:
    kwargs: dict = {"n_steps": n_steps}
    if facts is not None:
        if n_steps is None:
            kwargs.update(
                site_order=list(facts.schedule.order),
                site_avals=facts.site_avals,
            )
        else:
            kwargs.update(
                site_order=list(facts.step_schedule.order),
                decode_order=list(facts.step_schedule.order),
                site_avals=facts.gen_prefill_avals,
                decode_avals=facts.decode_avals,
                schedule=facts.step_schedule,
            )
    report = analysis.analyze(graph, **kwargs)
    verdict = "clean" if report.ok() else "FAILED"
    n = len(graph.nodes)
    print(f"{label}: {n} node{'s' if n != 1 else ''} — {verdict}")
    for d in report.diagnostics:
        print(f"  {d.format()}")
    if n_steps is not None and report.fusion:
        fused = sum(1 for v in report.fusion if v.fusable)
        print(f"  fusion: {fused}/{len(report.fusion)} steps fusable")
    return report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="statically lint serialized intervention graphs",
    )
    ap.add_argument("paths", nargs="*", help="wire-graph JSON files")
    ap.add_argument("--steps", type=int, default=None,
                    help="treat graphs as decode graphs with N steps")
    ap.add_argument("--model", default=None,
                    help="architecture name for shape-aware linting "
                         "(built abstractly; no weights)")
    ap.add_argument("--all-examples", action="store_true",
                    help="lint the graph every examples/ script builds")
    ap.add_argument("--summary", action="store_true",
                    help="print a machine-readable JSON fusion-verdict "
                         "reason table as the last line of output")
    args = ap.parse_args(argv)

    if not args.paths and not args.all_examples:
        ap.print_usage()
        return 2

    failed = 0
    facts = None
    if args.all_examples or args.model:
        facts = ModelFacts(args.model or "paper-gpt-small")

    # label -> {reason: count} over fusion verdicts (generation graphs)
    reason_table: dict[str, dict[str, int]] = {}

    def tally(label: str, report: analysis.AnalysisReport) -> None:
        if not report.fusion:
            return
        counts: dict[str, int] = {}
        for v in report.fusion:
            counts[v.reason] = counts.get(v.reason, 0) + 1
        reason_table[label] = counts

    for path in args.paths:
        try:
            payload = json.loads(Path(path).read_text())
            graph = graph_from_json(payload)
        except (OSError, ValueError, KeyError) as e:
            print(f"{path}: unreadable wire graph ({e})")
            return 2
        report = lint_graph(graph, path, facts=facts if args.model else None,
                            n_steps=args.steps)
        tally(path, report)
        if not report.ok():
            failed += 1

    if args.all_examples:
        for label, (build, n_steps) in EXAMPLE_GRAPHS.items():
            report = lint_graph(build(), label, facts=facts,
                                n_steps=n_steps)
            tally(label, report)
            if not report.ok():
                failed += 1
        for name, build_plan in EXAMPLE_MERGE_PLANS.items():
            graphs, sizes, starts, num_rows = build_plan()
            diags = analysis.check_merge_plan(graphs, sizes, starts,
                                              num_rows=num_rows)
            errs = [d for d in diags if d.severity == analysis.ERROR]
            verdict = "clean" if not errs else "FAILED"
            print(f"examples/{name} (merge plan): {len(graphs)} tenants, "
                  f"starts {starts} — {verdict}")
            for d in diags:
                print(f"  {d.format()}")
            if errs:
                failed += 1

    if args.summary:
        # one JSON object, last line: per-graph fusion-verdict reason
        # counts plus the aggregate.  Drive-to-zero metric for the
        # harvest-mold interpreter: "log"/"grad" must never appear.
        total: dict[str, int] = {}
        for counts in reason_table.values():
            for r, c in counts.items():
                total[r] = total.get(r, 0) + c
        print(json.dumps({"graphs": reason_table, "total": total},
                         sort_keys=True))

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
