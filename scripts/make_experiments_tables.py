"""Render EXPERIMENTS.md tables from the dry-run JSONL records.

  PYTHONPATH=src python scripts/make_experiments_tables.py \
      results/dryrun_single.jsonl  > results/table_single_baseline.md
"""
import json
import sys


def fmt(x, digits=3):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) >= 100:
        return f"{x:.0f}"
    if abs(x) >= 1:
        return f"{x:.{digits}g}"
    return f"{x:.2e}"


def main(path: str) -> None:
    recs = [json.loads(l) for l in open(path)]
    by = {}
    for r in recs:
        by[(r["arch"], r["shape"])] = r  # last record wins
    print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
          "| dominant | useful ratio | MFU bound | temps (GiB/dev) | fits 16G |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(by.items()):
        rl = r["roofline"]
        ma = r.get("memory_analysis", {})
        t = ma.get("temp_size_in_bytes", 0) / 2**30
        args = ma.get("argument_size_in_bytes", 0) / 2**30
        fits = "yes" if (t + args) <= 16.0 else f"NO ({t+args:.0f}G)"
        print(
            f"| {a} | {s} | {fmt(rl['t_compute_s'])} | {fmt(rl['t_memory_s'])}"
            f" | {fmt(rl['t_collective_s'])} | {rl['dominant']}"
            f" | {fmt(rl['useful_flops_ratio'], 2)}"
            f" | {fmt(rl.get('mfu_bound'), 2)} | {t:.1f} | {fits} |"
        )
    print()
    # dry-run summary block
    print("| arch | shape | mesh | per-dev FLOPs | per-dev bytes "
          "| collective bytes | compile (s) |")
    print("|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(by.items()):
        print(
            f"| {a} | {s} | {r['mesh']} | {fmt(r['flops'],3)} "
            f"| {fmt(r['bytes_accessed'],3)} | {fmt(r['collective_bytes'],3)} "
            f"| {r['compile_s']} |"
        )


if __name__ == "__main__":
    main(sys.argv[1])
