"""End-to-end training driver.

Local (CPU, reduced configs) it actually trains; on a real cluster the same
code path shards state/batches against the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_lm_data
from repro.models import registry as R
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mode", default="scan", choices=["scan", "unrolled"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = R.get_config(args.arch, reduced=args.reduced)
    model = R.build_model(args.arch, cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} mode={args.mode}")

    extras = {}
    if cfg.arch_type == "vlm":
        extras["image_embeds"] = {
            "shape": (args.batch, cfg.n_image_tokens, cfg.d_model)}
    if cfg.arch_type == "audio":
        extras["src_embeds"] = {
            "shape": (args.batch, cfg.n_source_frames, cfg.d_model)}
    data = synthetic_lm_data(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   batch_size=args.batch),
        extras=extras,
    )

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    init_state, step_fn = make_train_step(model, opt_cfg, mode=args.mode)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))
    state = init_state(params)

    t0 = time.time()
    for i in range(args.steps):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(json.dumps({
                "step": i,
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics["grad_norm"]),
                "lr": float(metrics["lr"]),
                "wall_s": round(time.time() - t0, 1),
            }))
    if args.checkpoint_dir:
        from repro.training.checkpoint import save_checkpoint

        path = save_checkpoint(args.checkpoint_dir, state["params"], args.steps)
        print(f"saved checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
