import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

For each combination this builds the production mesh (16×16 single-pod or
2×16×16 multi-pod), abstract params (``jax.eval_shape`` — zero allocation),
ShapeDtypeStruct inputs, explicit in/out shardings, then::

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(...)
    compiled = lowered.compile()
    print(compiled.memory_analysis(), compiled.cost_analysis())

Sharding mismatches, compile-time OOM, or unsupported collectives here are
bugs in the system.  Results (FLOPs, bytes, per-collective bytes) are dumped
as JSON for the roofline analysis (EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]
"""
__doc__ = _DOC

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import named_sharding, use_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import registry as R
from repro.models.registry import (SHAPES, batch_pspecs, fsdp_pspecs,
                                   input_specs, param_pspecs)
from repro.roofline.analysis import roofline_report
from repro.roofline.hlo_cost import analyze_hlo
from repro.training.optimizer import AdamWConfig, adamw
from repro.training.train_loop import loss_fn


def _shardings_like(tree_specs, tree_vals, mesh):
    return jax.tree.map(
        lambda spec, val: named_sharding(mesh, spec, tuple(val.shape)),
        tree_specs,
        tree_vals,
    )


def build_step(arch: str, shape_name: str, mesh, mode: str = "scan",
               microbatch: int = 1, sharding: str = "fsdp"):
    """Returns (step_fn, example_args (SDS), in_shardings, out_shardings).

    sharding: "fsdp" (weights over data+model; default — required for the
    90–110B archs to fit) or "tp" (weights over model only; §Perf H2 — kills
    the per-token weight all-gathers in decode for archs that fit).
    """
    cfg = R.get_config(arch)
    shape = SHAPES[shape_name]
    model = R.build_model(arch, cfg)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    data_size = mesh.devices.shape[-2]
    if sharding == "tp":
        pspecs = param_pspecs(params_sds)
    else:
        pspecs = fsdp_pspecs(params_sds, data_size)
    p_shard = _shardings_like(pspecs, params_sds, mesh)

    specs = input_specs(cfg, shape, model=model)

    if shape.kind == "train":
        opt_init, opt_update = adamw(AdamWConfig())
        opt_sds = jax.eval_shape(opt_init, params_sds)
        # moments mirror the FSDP param shardings (ZeRO falls out for free)
        opt_shard = {
            "step": named_sharding(mesh, jax.sharding.PartitionSpec()),
            "mu": _shardings_like(fsdp_pspecs(opt_sds["mu"], data_size),
                                  opt_sds["mu"], mesh),
            "nu": _shardings_like(fsdp_pspecs(opt_sds["nu"], data_size),
                                  opt_sds["nu"], mesh),
        }

        def step(state, batch):
            if microbatch <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(model, p, batch, mode=mode, remat=True),
                    has_aux=True,
                )(state["params"])
            else:
                # gradient accumulation: peak activation memory / microbatch
                mbs = jax.tree.map(
                    lambda a: a.reshape((microbatch, a.shape[0] // microbatch)
                                        + a.shape[1:]),
                    batch,
                )

                def mb_step(acc, mb):
                    g_acc, l_acc = acc
                    (l, _m), g = jax.value_and_grad(
                        lambda p: loss_fn(model, p, mb, mode=mode, remat=True),
                        has_aux=True,
                    )(state["params"])
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
                )
                (grads, loss), _ = jax.lax.scan(
                    mb_step, (zeros, jnp.zeros((), jnp.float32)), mbs
                )
                grads = jax.tree.map(lambda g: g / microbatch, grads)
                loss = loss / microbatch
            new_params, new_opt, om = opt_update(grads, state["opt"], state["params"])
            return {"params": new_params, "opt": new_opt}, {"loss": loss, **om}

        state_sds = {"params": params_sds, "opt": opt_sds}
        state_shard = {"params": p_shard, "opt": opt_shard}
        batch_shard = _shardings_like(batch_pspecs(specs), specs, mesh)
        args = (state_sds, specs)
        in_sh = (state_shard, batch_shard)
        out_sh = (state_shard, None)
        return step, args, in_sh, out_sh

    if shape.kind == "prefill":
        def step(params, batch):
            out, cache = model.prefill(params, batch, mode=mode)
            return out["logits"][:, -1, :], cache

        batch_shard = _shardings_like(batch_pspecs(specs), specs, mesh)
        args = (params_sds, specs)
        in_sh = (p_shard, batch_shard)
        return step, args, in_sh, None

    # decode: ONE token against a seq-length cache.
    kind = R.decode_cache_kind(cfg, shape)

    def step(params, cache, token, pos):
        out, new_cache = model.decode_step(
            params, cache, {"token": token, "pos": pos}, mode=mode
        )
        return out["logits"], new_cache

    cache_sds = specs["cache"]
    cache_shard = _shardings_like(batch_pspecs(cache_sds), cache_sds, mesh)
    tok_shard = named_sharding(
        mesh, jax.sharding.PartitionSpec(("pod", "data"), None),
        tuple(specs["token"].shape))
    pos_shard = named_sharding(
        mesh, jax.sharding.PartitionSpec(("pod", "data")),
        tuple(specs["pos"].shape))
    args = (params_sds, cache_sds, specs["token"], specs["pos"])
    in_sh = (p_shard, cache_shard, tok_shard, pos_shard)
    out_sh = (None, cache_shard)
    return step, args, in_sh, out_sh


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mode: str = "scan",
    verbose: bool = True,
    microbatch: int = 1,
    sharding: str = "fsdp",
) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with use_mesh(mesh):
        step, args, in_sh, out_sh = build_step(arch, shape_name, mesh, mode,
                                               microbatch=microbatch,
                                               sharding=sharding)
        # decode: donate the KV cache (in-place update, as serving would)
        donate = (1,) if SHAPES[shape_name].kind == "decode" else ()
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # XLA's cost_analysis counts while (scan) bodies ONCE; analyze_hlo
    # multiplies by known_trip_count (and catches collectives inside scans).
    hc = analyze_hlo(hlo)
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "microbatch": microbatch,
        "sharding": sharding,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod,
        "n_chips": n_chips,
        # per-device program costs (SPMD: compiled module is one partition)
        "flops": float(hc.flops),
        "bytes_accessed": float(hc.bytes_accessed),
        "collective_bytes": float(hc.collective_bytes),
        "collectives": {k: float(v) for k, v in hc.collective_by_kind.items()},
        "unknown_trip_whiles": hc.unknown_trip_whiles,
        "xla_raw_flops": float(cost.get("flops", 0.0)),
        "xla_raw_bytes": float(cost.get("bytes accessed", 0.0)),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_dict(mem),
    }
    rec["roofline"] = roofline_report(rec, R.get_config(arch), SHAPES[shape_name])
    if verbose:
        print(json.dumps(rec, indent=2, default=str))
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        if hasattr(mem, k):
            out[k] = int(getattr(mem, k))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="scan", choices=["scan", "unrolled"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--sharding", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for arch in R.list_archs():
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        print(f"=== dry-run {arch} × {shape} "
              f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'}) ===",
              flush=True)
        try:
            rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             mode=args.mode, microbatch=args.microbatch,
                             sharding=args.sharding)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec, default=str) + "\n")
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape))
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        return 1
    print(f"all {len(combos)} dry-runs compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
