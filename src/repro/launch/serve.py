"""NDIF serving driver: preload models, accept intervention requests.

  PYTHONPATH=src python -m repro.launch.serve --arch paper-gpt-small --demo

Hosts the model on an in-process NDIF server behind the loopback transport
(the wire format is real; sockets are incidental) and — with --demo — runs a
mixed co-tenant workload: N simulated users submitting random-layer
activation requests, reporting response-time stats like the paper's Fig. 9.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer


def random_layer_request(cfg, rng, batch_rows=1, seq=24):
    """Paper Code Example 9: save a uniformly-random layer's output."""
    g = InterventionGraph()
    layer = int(rng.integers(0, cfg.n_layers))
    t = g.add("tap_get", site="layers.output", layer=layer)
    s = g.add("save", Ref(t.id))
    g.mark_saved("acts", s)
    tokens = rng.integers(0, cfg.vocab_size, size=(batch_rows, seq)).astype(
        np.int32
    )
    return g, tokens


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="parallel",
                    choices=["sequential", "parallel"])
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--users", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = R.get_config(args.arch, reduced=args.reduced)
    model = R.build_model(args.arch, cfg)
    t0 = time.time()
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy=args.policy)
    print(f"hosted {cfg.name} in {time.time() - t0:.2f}s "
          f"(policy={args.policy})")
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, cfg.name)

    if not args.demo:
        print("server ready (in-process). Use NDIFClient against "
              "server.handle for requests.")
        return 0

    # Fig. 9-style demo: N users, random-layer activation saves.
    from repro.core.serialize import graph_to_json
    rng = np.random.default_rng(0)
    sched = server.schedulers[cfg.name]
    from repro.serving.scheduler import Request

    tickets = []
    for _ in range(args.users):
        g, tokens = random_layer_request(cfg, rng)
        tickets.append(sched.submit(Request(graph=g, batch={"tokens": tokens})))
    t0 = time.time()
    sched.drain()
    wall = time.time() - t0
    times = [t.response_time for t in tickets]
    print(json.dumps({
        "users": args.users,
        "policy": args.policy,
        "wall_s": round(wall, 3),
        "median_response_s": round(float(np.median(times)), 4),
        "p90_response_s": round(float(np.percentile(times, 90)), 4),
        "executions": server.engines[cfg.name].stats.executions,
        "compiles": server.engines[cfg.name].stats.compiles,
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
