"""Production mesh factory.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everyone else sees
the real single CPU device).

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod
configuration is 2 pods = 512 chips with a leading "pod" axis (DCN between
pods, ICI within).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


class HW:
    """TPU v5e per-chip constants used by the roofline (§Roofline)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # bytes/s
    ICI_BW = 50e9  # bytes/s per link
    HBM_BYTES = 16 * 1024**3


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """A 1x1 mesh over the real local device (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
