"""Expert-parallel MoE via shard_map + explicit all-to-all (§Perf H1).

The baseline ragged-dot MoE is correct but its global argsort/gather defeats
the SPMD partitioner: XLA replicates the dispatch (and therefore the expert
FLOPs) on every device — measured useful-FLOPs ratio 0.004 on
qwen3-moe-30b × train_4k.  This module maps the canonical expert-parallel
communication pattern onto jax-native constructs:

  per device (data-shard tokens × model-shard experts):
    1. route locally: top-k over ALL experts for the local token block;
    2. pack tokens into a capacity-bounded (E, C, d) dispatch buffer with a
       LOCAL sort (no cross-device gather);
    3. ``all_to_all`` over the model axis: experts' inboxes converge on the
       shard that owns them — (E, C, d) -> (E/M, M·C, d);
    4. dense per-expert matmuls (MXU-friendly einsums);
    5. ``all_to_all`` back; combine with routing weights locally.

Capacity: C = ceil(k·T_local / E · capacity_factor); overflow tokens drop
(standard Switch-style).  With no mesh active the baseline ragged path runs
instead (exact, dropless) — serving/tests on CPU use that.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["moe_apply_ep", "EP_CAPACITY_FACTOR"]

EP_CAPACITY_FACTOR = 1.25


def _local_dispatch(xt, logits, e, k, capacity):
    """Pack local tokens into (E, C, d) by expert. All-local (no comms).

    Returns (dispatched (E,C,d), combine info: ids (T,k), weights (T,k),
    pos (T,k), keep (T,k))."""
    T, d = xt.shape
    weights, ids = jax.lax.top_k(logits, k)  # (T, k)
    weights = jax.nn.softmax(weights, axis=-1)
    flat_ids = ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    # position of each routed slot within its expert
    start = jnp.searchsorted(sorted_ids, jnp.arange(e))
    pos_sorted = jnp.arange(T * k) - start[sorted_ids]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    pos = pos.reshape(T, k)
    keep = pos < capacity

    token_of = order // k
    slot_expert = sorted_ids
    slot_keep = pos_sorted < capacity
    # scatter local tokens into the dispatch buffer
    buf = jnp.zeros((e, capacity, d), xt.dtype)
    safe_pos = jnp.where(slot_keep, pos_sorted, 0).astype(jnp.int32)
    buf = buf.at[slot_expert, safe_pos].add(
        jnp.where(slot_keep[:, None], xt[token_of], 0.0)
    )
    return buf, ids, weights, pos, keep


def moe_apply_ep(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    mesh,
    *,
    router_tap=None,
    capacity_factor: float = EP_CAPACITY_FACTOR,
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE. x: (B, S, d) sharded (batch over data)."""
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    f = cfg.expert_d_ff
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    M = axis_sizes.get("model", 1)
    data_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    n_data = math.prod(axis_sizes[a] for a in data_axes) if data_axes else 1
    assert e % M == 0, (e, M)
    e_loc = e // M
    # Tokens shard over data AND (via the sequence dim) over the model axis —
    # otherwise the M model shards carry IDENTICAL token copies and the
    # all-to-all ships M duplicate inboxes (measured: 15.7× redundant expert
    # FLOPs).  Decode (S == 1) can't split the sequence; the duplication is
    # one token per row there and irrelevant.
    seq_shard = S % M == 0 and S >= M
    # decode with tiny batches (long_500k: B=1) cannot shard rows over data
    batch_shardable = B % n_data == 0 and B >= n_data
    T_loc = max(
        (B * S)
        // (n_data if batch_shardable else 1)
        // (M if seq_shard else 1),
        1,
    )
    capacity = int(math.ceil(k * T_loc / e * capacity_factor))

    def shard_fn(x_loc, logits_loc, wg, wu, wd):
        # x_loc: (B_loc, S, d); logits_loc: (B_loc, S, e); experts (e_loc,d,f)
        Bl, Sl, _ = x_loc.shape
        xt = x_loc.reshape(Bl * Sl, d)
        logits = logits_loc.reshape(Bl * Sl, e).astype(jnp.float32)
        buf, ids, weights, pos, keep = _local_dispatch(
            xt, logits, e, k, capacity
        )
        # aux load-balance loss (local stats; averaged over data shards)
        probs = jax.nn.softmax(logits, axis=-1)
        density = probs.mean(axis=0)
        hard = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (
            xt.shape[0] * k
        )
        aux = e * jnp.sum(density * hard)
        aux = jax.lax.pmean(aux, axis_name="model")
        if data_axes:
            aux = jax.lax.pmean(aux, axis_name=data_axes)

        # --- all-to-all over the model axis: (e, C, d) -> (e_loc, M*C, d)
        if M > 1:
            inbox = jax.lax.all_to_all(
                buf.reshape(M, e_loc, capacity, d), "model",
                split_axis=0, concat_axis=0, tiled=False,
            )  # (M, e_loc, C, d): slice m came from model-shard m
            inbox = inbox.transpose(1, 0, 2, 3).reshape(e_loc, M * capacity, d)
        else:
            inbox = buf.reshape(e_loc, capacity, d)

        # --- dense per-expert FFN on the MXU
        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", inbox, wg)
        ) * jnp.einsum("ecd,edf->ecf", inbox, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)  # (e_loc, M*C, d)

        # --- all-to-all back: every shard recovers ITS tokens' outputs
        if M > 1:
            y = y.reshape(e_loc, M, capacity, d).transpose(1, 0, 2, 3)
            y = jax.lax.all_to_all(
                y, "model", split_axis=0, concat_axis=0, tiled=False
            )  # (M, e_loc, C, d) for local tokens, experts re-spread
            y = y.reshape(e, capacity, d)
        else:
            y = y.reshape(e, capacity, d)

        # --- combine: gather each token's k slots, weight, sum
        safe_pos = jnp.where(keep, pos, 0)
        slots = y[ids.reshape(-1), safe_pos.reshape(-1)]  # (T*k, d)
        slots = jnp.where(keep.reshape(-1)[:, None], slots, 0.0)
        out = jnp.einsum(
            "tkd,tk->td",
            slots.reshape(-1, k, d).astype(jnp.float32),
            weights,
        ).astype(x_loc.dtype)
        return out.reshape(Bl, Sl, d), aux

    # Router logits computed OUTSIDE the shard_map so the intervention graph
    # can both read and OVERRIDE them (load-balance interventions, routing
    # analysis) — the tapped value is what the dispatch actually uses.
    logits = (x.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    if router_tap is not None:
        logits = router_tap(logits)

    batch_spec = P(
        data_axes if (data_axes and batch_shardable) else None,
        "model" if seq_shard else None,
        None,
    )
    out, aux = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            batch_spec,
            batch_spec,               # router logits, token-sharded
            P("model", None, None),   # experts sharded over model
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(batch_spec, P()),
        check_vma=False,
    )(x, logits, p["wg"], p["wu"], p["wd"])
    return out, aux
