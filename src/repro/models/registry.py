"""Architecture registry: ``--arch <id>`` → config + model + specs.

Also home of the assigned input-shape suite and the ShapeDtypeStruct
factories the multi-pod dry-run lowers against (no device allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, reduce_config

__all__ = [
    "ARCHS",
    "SHAPES",
    "InputShape",
    "get_config",
    "build_model",
    "input_specs",
    "param_pspecs",
    "batch_pspecs",
    "list_archs",
]

# arch id -> (config module, model class path)
ARCHS: dict[str, tuple[str, str]] = {
    "minicpm3-4b": ("repro.configs.minicpm3_4b", "transformer.TransformerModel"),
    "phi3.5-moe-42b-a6.6b": ("repro.configs.phi35_moe", "transformer.TransformerModel"),
    "internlm2-20b": ("repro.configs.internlm2_20b", "transformer.TransformerModel"),
    "zamba2-2.7b": ("repro.configs.zamba2_2_7b", "hybrid.Zamba2Model"),
    "qwen1.5-110b": ("repro.configs.qwen15_110b", "transformer.TransformerModel"),
    "mamba2-1.3b": ("repro.configs.mamba2_1_3b", "ssm.Mamba2Model"),
    "seamless-m4t-large-v2": ("repro.configs.seamless_m4t", "encdec.EncDecModel"),
    "qwen3-moe-30b-a3b": ("repro.configs.qwen3_moe_30b", "transformer.TransformerModel"),
    "llama-3.2-vision-90b": ("repro.configs.llama32_vision_90b", "transformer.TransformerModel"),
    "qwen3-8b": ("repro.configs.qwen3_8b", "transformer.TransformerModel"),
    "paper-gpt-small": ("repro.configs.paper_gpt", "transformer.TransformerModel"),
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int
    window: bool = False  # decode with sliding-window cache


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1, window=True),
}


def list_archs() -> list[str]:
    return [a for a in ARCHS if a != "paper-gpt-small"]


def get_config(arch: str, reduced: bool = False, **overrides) -> ModelConfig:
    mod_name, _ = ARCHS[arch]
    cfg = importlib.import_module(mod_name).CONFIG
    if reduced:
        cfg = reduce_config(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def build_model(arch: str, cfg: ModelConfig | None = None, reduced=False):
    mod_name, cls_path = ARCHS[arch]
    cfg = cfg or get_config(arch, reduced=reduced)
    pkg, cls_name = cls_path.split(".")
    mod = importlib.import_module(f"repro.models.{pkg}")
    return getattr(mod, cls_name)(cfg)


# ---------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(
    cfg: ModelConfig, shape: InputShape, model: Any = None
) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run pattern).

    train:   {tokens, labels [, image_embeds | src_embeds]}
    prefill: {tokens [, extras]}
    decode:  {token, pos, cache} — cache abstracted via model.init_cache.
    """
    B, S = shape.batch, shape.seq
    extras: dict[str, Any] = {}
    if cfg.arch_type == "vlm":
        extras["image_embeds"] = _sds((B, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    if cfg.arch_type == "audio":
        extras["src_embeds"] = _sds((B, cfg.n_source_frames, cfg.d_model), cfg.dtype)

    if shape.kind == "train":
        return {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
            **extras,
        }
    if shape.kind == "prefill":
        return {"tokens": _sds((B, S), jnp.int32), **extras}
    # decode: ONE new token with a seq-long cache.
    if model is None:
        model = build_model(cfg.name, cfg)
    kind = "window" if (shape.window and _needs_window(cfg)) else "full"
    cache = jax.eval_shape(lambda: model.init_cache(B, S, kind=kind))
    return {
        "token": _sds((B, 1), jnp.int32),
        "pos": _sds((B,), jnp.int32),
        "cache": cache,
    }


def _needs_window(cfg: ModelConfig) -> bool:
    """SSM is attention-free; hybrid attends at shared blocks only — the
    long-context policy (DESIGN.md §4): dense/MoE/VLM use the sliding-window
    decode path for long_500k; SSM runs natively; hybrid windows its shared
    attention blocks."""
    return cfg.arch_type != "ssm"


def decode_cache_kind(cfg: ModelConfig, shape: InputShape) -> str:
    if shape.window and _needs_window(cfg) and cfg.arch_type != "ssm":
        return "window"
    return "full"


# ------------------------------------------------------------ sharding specs
_RULES: list[tuple[tuple[str, ...], P]] = [
    # (path substring match, spec for the *trailing* dims)
    (("embed",), P("model", None)),
    (("lm_head", "w"), P(None, "model")),
    (("lm_head", "b"), P("model")),
    (("router", "w"), P(None, None)),
    (("wq", "w"), P(None, "model")),
    (("wk", "w"), P(None, "model")),
    (("wv", "w"), P(None, "model")),
    (("wq_a", "w"), P(None, None)),
    (("wq_b", "w"), P(None, "model")),
    (("wkv_a", "w"), P(None, None)),
    (("wkv_b", "w"), P(None, "model")),
    (("wo", "w"), P("model", None)),
    (("wg", "w"), P(None, "model")),
    (("wu", "w"), P(None, "model")),
    (("wd", "w"), P("model", None)),
    (("moe", "wg"), P("model", None, None)),  # expert-parallel
    (("moe", "wu"), P("model", None, None)),
    (("moe", "wd"), P("model", None, None)),
    (("in_proj", "w"), P(None, "model")),
    (("out_proj", "w"), P("model", None)),
    (("conv_w",), P(None, "model")),
    (("conv_b",), P("model")),
    (("A_log",), P("model")),
    (("D",), P("model")),
    (("dt_bias",), P("model")),
    (("shared_out", "w"), P(None, None)),
]


def _path_names(path) -> tuple[str, ...]:
    out = []
    for entry in path:
        if hasattr(entry, "key"):
            out.append(str(entry.key))
        elif hasattr(entry, "name"):
            out.append(str(entry.name))
        else:
            out.append(str(entry))
    return tuple(out)


def _spec_for(path_names: tuple[str, ...], ndim: int) -> P:
    best: P | None = None
    best_len = -1
    for pat, spec in _RULES:
        if len(pat) > len(path_names):
            continue
        # match pattern as a subsequence anchored at the end
        tail = path_names[-len(pat):] if len(pat) > 1 else None
        if len(pat) == 1:
            hit = pat[0] in path_names
        else:
            hit = all(p in path_names for p in pat) and path_names[-1] == pat[-1]
        if hit and len(pat) > best_len:
            best, best_len = spec, len(pat)
    if best is None:
        return P(*([None] * ndim))
    spec = list(best)
    while len(spec) < ndim:
        spec.insert(0, None)  # stacked-layer leading dims replicate
    return P(*spec[:ndim] if len(spec) > ndim else spec)


def param_pspecs(params: Any) -> Any:
    """PartitionSpec pytree mirroring ``params`` (rule-based, path-matched)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_names(path), np.ndim(leaf)
                                     if not hasattr(leaf, "ndim") else leaf.ndim),
        params,
    )


def fsdp_pspecs(params: Any, data_axis_size: int, axis: str = "data") -> Any:
    """Tensor-parallel rules + FSDP: additionally shard the first unsharded,
    divisible dim of every weight over the data axis.  This is the baseline
    policy — the 90–110B assigned archs do not fit 16 GB/chip under pure TP
    (weights/16 > HBM), so weight FSDP over the full 256-chip pod is the
    production-sane default; XLA inserts the per-layer all-gather /
    grad reduce-scatter.
    """
    base = param_pspecs(params)

    def widen(spec, leaf):
        ndim = leaf.ndim
        entries = list(spec) + [None] * (ndim - len(spec))
        if ndim == 0:
            return jax.sharding.PartitionSpec()
        for i, e in enumerate(entries):
            if e is None and leaf.shape[i] % data_axis_size == 0 and leaf.shape[i] >= data_axis_size:
                entries[i] = axis
                break
        return jax.sharding.PartitionSpec(*entries)

    return jax.tree.map(
        widen, base, params,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def batch_pspecs(specs: Any) -> Any:
    """Inputs shard on the batch axis; caches shard batch + KV heads."""

    def leaf_spec(path, leaf):
        names = _path_names(path)
        ndim = leaf.ndim
        kv_names = ("k", "v", "latent", "k_rope", "cross_k", "cross_v")
        if any(n in kv_names for n in names):
            if ndim >= 3:
                # (L, B, T, ...) KV caches: batch over data, SEQUENCE over
                # model (flash-decoding: every chip reads cache/T_model per
                # token; softmax/PV partial-combine via tiny all-reduces —
                # §Perf H2.4.  Head-sharding was rejected: GQA head counts
                # (4–8) don't divide the 16-way model axis and XLA fell back
                # to whole-cache re-shard gathers).
                spec = [None] * ndim
                spec[1] = ("pod", "data")
                spec[2] = "model"
                return P(*spec)
        if "cache" in names or any(n in ("ssm", "conv") for n in names):
            if ndim >= 2:
                spec = [None] * ndim
                spec[1] = ("pod", "data")
                if ndim >= 4:
                    spec[3] = "model"
                return P(*spec)
        if names and names[-1] == "positions":
            return P(("pod", "data"), "model")
        if names and names[-1] == "length":
            return P(("pod", "data"))
        if ndim == 0:
            return P()
        spec = [None] * ndim
        spec[0] = ("pod", "data")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, specs)
