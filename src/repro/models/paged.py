"""Paged KV cache: block-table indirection over a fixed pool of pages.

The contiguous slot-table cache reserves ``slot_max_len`` positions per
row — memory scales with the WORST-case request, which caps slot count
and therefore occupancy (the NDIF serving bottleneck; vLLM's PagedAttention
is the canonical fix).  This module replaces per-row reservation with a
pool of fixed-size pages:

  * paged data leaves live in a pool ``(A0, num_pages, page_size, *tail)``
    (A0 = layers or app-blocks — every per-layer leaf keeps batch at
    axis 1 and time at axis 2, so one gather shape rule covers all
    families);
  * each slot row owns a block table ``(num_blocks,)`` of page ids mapping
    logical positions ``[blk*ps, (blk+1)*ps)`` to pool pages; page 0 is
    the NULL page (always zero, the read target of unallocated blocks)
    and page 1 is the TRASH page (the write sink for shape-stable
    scatters; no block table ever references it), so usable pages start
    at 2;
  * pages are allocated by a request's ACTUAL length and returned to the
    pool at retirement — the allocator lives host-side in
    :class:`repro.core.generation.DecodeLoop`; block-table updates are
    value-only uploads (fixed shape), so paged decode never retraces.

Decode strategy: gather the pool into the logical dense view, run the
family's EXISTING dense ``decode_step`` unchanged, then absorb the one
written token back into its page.  Bit-exactness vs the contiguous path
holds by construction: the gathered view differs from a contiguous cache
only at masked slots (sentinel positions → ``NEG_INF`` bias → the
softmax contribution underflows to exactly 0.0), so logits, taps and
saves are bitwise identical.  The pallas block-gather kernel
(:func:`repro.kernels.flash_attention.paged_flash_attention_kernel_call`)
is the TPU fast path that skips the materialized gather; it walks pages
in block-table order so its accumulation order — and therefore its
output — is bit-identical to the dense kernel on the gathered view.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import KVCache, _SENTINEL_POS, _take_rows

__all__ = [
    "PagedKVCache",
    "NULL_PAGE",
    "TRASH_PAGE",
    "FIRST_PAGE",
    "build_paged_cache",
    "dense_view",
    "paged_decode_step",
    "paged_write_rows",
    "paged_clear_rows",
    "with_block_tables",
]

NULL_PAGE = 0   # always-zero page: read target for unallocated blocks
TRASH_PAGE = 1  # write sink for shape-stable scatters; never referenced
FIRST_PAGE = 2  # first allocatable page id


@dataclasses.dataclass
class PagedKVCache:
    """Pytree paged cache.  Static (aux) fields pin the layout so jitted
    programs key on them; array fields thread through scan carries — the
    block table rides the fused decode carry like any other leaf."""

    kind: str                    # full | window | mla (aux)
    page_size: int               # positions per page (aux)
    t_logical: int               # logical per-row cache length T (aux)
    paged_keys: tuple            # data keys stored in the pool (aux)
    axis0_keys: tuple            # dense keys with batch at axis 0 (aux)
    pool: dict                   # paged leaves (A0, P, ps, *tail)
    dense: dict                  # unpaged leaves, dense slot-table layout
    block_tables: jax.Array      # (B, num_blocks) int32 page ids, 0 = null
    positions: jax.Array         # (B, T) original position of each slot
    length: jax.Array            # (B,) tokens written so far

    @property
    def num_pages(self) -> int:
        return next(iter(self.pool.values())).shape[1]


jax.tree_util.register_pytree_node(
    PagedKVCache,
    lambda c: (
        (c.pool, c.dense, c.block_tables, c.positions, c.length),
        (c.kind, c.page_size, c.t_logical, c.paged_keys, c.axis0_keys),
    ),
    lambda aux, xs: PagedKVCache(*aux, *xs),
)


def with_block_tables(pc: PagedKVCache, block_tables) -> PagedKVCache:
    """Value-only block-table refresh (host allocator → device).  The
    shape is fixed at construction, so this never invalidates a trace."""
    return dataclasses.replace(
        pc, block_tables=jnp.asarray(block_tables, jnp.int32)
    )


def build_paged_cache(
    model, batch_size: int, max_len: int, kind: str,
    page_size: int, num_pages: int,
):
    """An all-empty paged slot table for ``model``, or None when the
    family has nothing to page (fixed-size recurrent state)."""
    seed = model.init_cache(batch_size, max_len, kind=kind)
    if not isinstance(seed, KVCache):
        return None  # ssm-family dict cache: state is O(1) per row
    if num_pages < FIRST_PAGE + 1:
        raise ValueError(
            f"paged cache needs at least {FIRST_PAGE + 1} pages "
            f"(null + trash + 1 usable), got {num_pages}"
        )
    exclude = tuple(getattr(model, "paged_exclude_keys", ()))
    axis0 = tuple(getattr(model, "cache_axis0_keys", ()))
    T = seed.positions.shape[1]
    num_blocks = -(-T // page_size)
    paged_keys = tuple(sorted(
        k for k in seed.data
        if not any(k.startswith(p) for p in exclude)
    ))
    pool = {
        k: jnp.zeros(
            (seed.data[k].shape[0], num_pages, page_size)
            + seed.data[k].shape[3:],
            seed.data[k].dtype,
        )
        for k in paged_keys
    }
    dense = {k: v for k, v in seed.data.items() if k not in paged_keys}
    return PagedKVCache(
        seed.kind, page_size, T, paged_keys, axis0,
        pool, dense,
        jnp.zeros((batch_size, num_blocks), jnp.int32),
        seed.positions, seed.length,
    )


def dense_view(pc: PagedKVCache) -> KVCache:
    """Gather the pool into the logical ``(B, T, ...)`` dense view.

    Unallocated blocks read the null page (zeros) and carry sentinel
    positions, so whatever they contain is provably inert to attention."""
    B, nb = pc.block_tables.shape
    ps = pc.page_size
    data = {}
    for k in pc.paged_keys:
        v = pc.pool[k]  # (A0, P, ps, *tail)
        g = v[:, pc.block_tables]  # (A0, B, nb, ps, *tail)
        g = g.reshape((v.shape[0], B, nb * ps) + v.shape[3:])
        data[k] = g[:, :, : pc.t_logical]
    data.update(pc.dense)
    return KVCache(pc.kind, data, pc.positions, pc.length)


def _decode_slot(pc: PagedKVCache, pos):
    return pos % pc.t_logical if pc.kind == "window" else pos


def absorb_decode(pc: PagedKVCache, new_dense: KVCache, pos) -> PagedKVCache:
    """Fold one dense decode step back into the pool: the single written
    token per row lands in its page; every other gathered column is
    unchanged by construction.  Rows without a valid target (free rows at
    sentinel positions, unallocated blocks) write to the trash page, so
    the scatter stays shape-stable and the null page is never dirtied."""
    B, nb = pc.block_tables.shape
    ps = pc.page_size
    slot = _decode_slot(pc, pos)
    blk = jnp.clip(slot // ps, 0, nb - 1)
    page = pc.block_tables[jnp.arange(B), blk]
    valid = (slot >= 0) & (slot < pc.t_logical) & (page >= FIRST_PAGE)
    page_w = jnp.where(valid, page, TRASH_PAGE)
    off = jnp.where(valid, slot % ps, jnp.arange(B) % ps)
    slot_r = jnp.clip(slot, 0, pc.t_logical - 1)
    pool = dict(pc.pool)
    for k in pc.paged_keys:
        new_tok = new_dense.data[k][:, jnp.arange(B), slot_r]
        pool[k] = pool[k].at[:, page_w, off].set(new_tok)
    dense = {k: new_dense.data[k] for k in pc.dense}
    return dataclasses.replace(
        pc, pool=pool, dense=dense,
        positions=new_dense.positions, length=new_dense.length,
    )


def paged_decode_step(model, params, pc: PagedKVCache, batch, *,
                      mode: str = "scan"):
    """One-token decode against a paged cache: gather → the family's
    dense ``decode_step`` (taps, interventions and logits run UNCHANGED
    on the dense view) → absorb the written token into its page."""
    out, new_dense = model.decode_step(
        params, dense_view(pc), batch, mode=mode
    )
    return out, absorb_decode(pc, new_dense, batch["pos"])


def paged_write_rows(pc: PagedKVCache, rows, src: KVCache,
                     src_rows=None) -> PagedKVCache:
    """Admission: scatter a freshly prefilled dense cache into the rows'
    pages.  Every block of every row is written (unallocated blocks
    redirect to the trash page) so the scatter compiles once per
    row-count signature regardless of how many pages a request owns —
    and stale pool content from a prior tenant is overwritten wholesale."""
    rows = jnp.asarray(rows)
    B, nb = pc.block_tables.shape
    ps = pc.page_size
    bt_rows = pc.block_tables[rows]  # (R, nb)
    page_w = jnp.where(bt_rows >= FIRST_PAGE, bt_rows, TRASH_PAGE)
    pool = dict(pc.pool)
    for k in pc.paged_keys:
        sv = _take_rows(src.data[k], src_rows, 1)  # (A0, R, T, *tail)
        pad = nb * ps - sv.shape[2]
        if pad:
            sv = jnp.pad(
                sv, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (sv.ndim - 3)
            )
        blocks = sv.reshape(
            (sv.shape[0], sv.shape[1], nb, ps) + sv.shape[3:]
        )
        pool[k] = pool[k].at[:, page_w].set(blocks)
    dense = {}
    for k, v in pc.dense.items():
        if k in pc.axis0_keys:
            dense[k] = v.at[rows].set(_take_rows(src.data[k], src_rows, 0))
        else:
            dense[k] = v.at[:, rows].set(_take_rows(src.data[k], src_rows, 1))
    return dataclasses.replace(
        pc, pool=pool, dense=dense,
        positions=pc.positions.at[rows].set(
            _take_rows(src.positions, src_rows, 0)
        ),
        length=pc.length.at[rows].set(
            _take_rows(src.length, src_rows, 0)
        ),
    )


def paged_clear_rows(pc: PagedKVCache, rows) -> PagedKVCache:
    """Retire rows: sentinel positions + zero length make every slot of
    the row masked, and the host allocator drops its block table — the
    pages themselves are left as-is (unreachable, overwritten wholesale
    by the next tenant that receives them)."""
    rows = jnp.asarray(rows)
    dense = {}
    for k, v in pc.dense.items():
        if k in pc.axis0_keys:
            dense[k] = v.at[rows].set(
                _SENTINEL_POS if v.dtype == jnp.int32 else 0
            )
        else:
            dense[k] = v.at[:, rows].set(0)
    return dataclasses.replace(
        pc, dense=dense,
        positions=pc.positions.at[rows].set(_SENTINEL_POS),
        length=pc.length.at[rows].set(0),
    )
