"""Seamless-M4T-style encoder-decoder for speech-to-text [arXiv:2308.11596].

Per the assignment carve-out, the audio frontend (mel-spectrogram + conv
feature extractor) is STUBBED: ``src_embeds`` arrives as precomputed frame
embeddings of shape (B, n_source_frames, d_model).  This module implements
the transformer backbone: a bidirectional encoder and a causal decoder with
per-layer cross-attention.

Tap sites: ``encoder.{input,attn.output,mlp.output,output}`` and
``decoder.{input,attn.output,cross.output,mlp.output,output}`` per layer,
plus ``src_embed``/``embed``/``final_norm``/``logits``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import taps
from repro.core.interleave import SiteSchedule
from repro.distributed import shard_hint
from repro.models import common as C
from repro.models.config import ModelConfig
from repro.models.transformer import KVCache, _write_rows

__all__ = ["EncDecModel"]

ENC_SITES = ["encoder.input", "encoder.attn.output", "encoder.mlp.output",
             "encoder.output"]
DEC_SITES = ["decoder.input", "decoder.attn.output", "decoder.cross.output",
             "decoder.mlp.output", "decoder.output"]


class EncDecModel:
    # prefill() runs a Python decoder-layer loop — generation traces tapping
    # it must be scheduled unrolled (repro.core.generation forces this).
    scan_prefill = False
    # cross K/V (+ source positions) are fixed-size per row — dense under
    # paging; only self-attention K/V grow with decode
    paged_exclude_keys = ("cross",)
    cache_axis0_keys = ("cross_pos",)

    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        self.cfg = cfg

    def site_length_key(self, site: str) -> str | None:
        """Encoder sites follow the source-frame axis, decoder sites the
        target-token axis — ragged merging pads/unpads each independently."""
        if site in ("src_embed", "enc_output") or site.startswith("encoder."):
            return "src_embeds"
        return "tokens"

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k_emb, k_enc, k_dec, k_out = jax.random.split(key, 4)

        def enc_layer(k):
            ka, kf = jax.random.split(k)
            return {
                "attn_norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "attn": C.gqa_init(ka, cfg),
                "mlp_norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "mlp": C.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype),
            }

        def dec_layer(k):
            ka, kc, kf = jax.random.split(k, 3)
            return {
                "attn_norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "attn": C.gqa_init(ka, cfg),
                "cross_norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "cross": C.gqa_init(kc, cfg),
                "mlp_norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "mlp": C.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype),
            }

        return {
            "embed": (
                jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02
            ).astype(cfg.dtype),
            "encoder": jax.vmap(enc_layer)(
                jax.random.split(k_enc, cfg.encoder_layers)
            ),
            "enc_norm": jnp.ones((cfg.d_model,), cfg.dtype),
            "decoder": jax.vmap(dec_layer)(
                jax.random.split(k_dec, cfg.n_layers)
            ),
            "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
            "lm_head": C.init_linear(k_out, cfg.d_model, cfg.vocab_size, cfg.dtype),
        }

    def site_schedule(self, mode: str = "unrolled") -> SiteSchedule:
        cfg = self.cfg
        order: list[tuple[str, int | None]] = [("src_embed", None)]
        for i in range(cfg.encoder_layers):
            order += [(n, i) for n in ENC_SITES]
        order += [("enc_output", None), ("embed", None)]
        for i in range(cfg.n_layers):
            order += [(n, i) for n in DEC_SITES]
        order += [("final_norm", None), ("logits", None)]
        return SiteSchedule(
            order=order,
            scan_sites=tuple(ENC_SITES + DEC_SITES) if mode == "scan" else (),
            n_layers=cfg.n_layers,
        )

    # --------------------------------------------------------------- encoder
    def encode(self, params: dict, src_embeds: jax.Array, *, mode="scan",
               remat: bool = False, src_lengths: jax.Array | None = None):
        """Bidirectional encoder.  ``src_lengths`` (B,) marks per-row valid
        frames: padded frames get sentinel positions, which ``_mask_bias``
        excludes for every (non-causal) query — without this, right-padding
        would leak into every real frame."""
        cfg = self.cfg
        B, T, _ = src_embeds.shape
        positions = C.valid_positions(src_lengths, B, T)
        h = taps.site("src_embed", src_embeds.astype(cfg.dtype))
        h = shard_hint(h, P(("pod", "data"), None, None))

        def layer(p, h, idx):
            h = taps.site("encoder.input", h, layer=idx)
            h = shard_hint(h, P(("pod", "data"), "model", None))
            x = C.rms_norm(h, p["attn_norm"], cfg.norm_eps)
            a = C.gqa_apply(p["attn"], x, cfg, positions, causal=False)
            a = taps.site("encoder.attn.output", a, layer=idx)
            h = h + a
            x = C.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
            mo = C.swiglu_apply(p["mlp"], x)
            mo = taps.site("encoder.mlp.output", mo, layer=idx)
            h = h + mo
            return taps.site("encoder.output", h, layer=idx)

        if mode == "unrolled":
            for i in range(cfg.encoder_layers):
                p = jax.tree.map(lambda a: a[i], params["encoder"])
                h = layer(p, h, i)
        else:
            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                p, idx = inp
                h = layer(p, h, idx)
                return (h, taps.scan_env_update(env_c)), taps.scan_outputs()

            if remat:
                body = jax.checkpoint(body)
            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["encoder"], jnp.arange(cfg.encoder_layers)),
            )
            taps.deliver_scan(ys)
        h = C.rms_norm(h, params["enc_norm"], cfg.norm_eps)
        return taps.site("enc_output", h)

    # --------------------------------------------------------------- decoder
    def _project_cross_kv(self, p, enc_out):
        """One decoder layer's cross-attention K/V from encoder output."""
        cfg = self.cfg
        B, T, _ = enc_out.shape
        ck = C.linear(p["cross"]["wk"], enc_out).reshape(
            B, T, cfg.n_kv_heads, cfg.hd)
        cv = C.linear(p["cross"]["wv"], enc_out).reshape(
            B, T, cfg.n_kv_heads, cfg.hd)
        return ck, cv

    def _dec_layer(self, p, h, positions, enc_out, enc_pos, idx, *,
                   cache_l=None, kv_positions=None, slot=None,
                   cross_kv=None, window=None, decode=False,
                   collect=False):
        cfg = self.cfg
        hd = cfg.hd
        h = taps.site("decoder.input", h, layer=idx)
        h = shard_hint(h, P(("pod", "data"), "model", None))
        x = C.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        B, S, _ = x.shape
        new_l = None
        if decode:
            q, k_new, v_new = C.gqa_project_qkv(p["attn"], x, cfg, positions)
            k = _write_rows(cache_l["k"], slot, k_new)
            v = _write_rows(cache_l["v"], slot, v_new)
            o = C.attention(q, k, v, q_pos=positions, k_pos=kv_positions,
                            causal=True, window=window, impl="dense")
            a = C.linear(p["attn"]["wo"], o.reshape(B, S, -1))
            new_l = {"k": k, "v": v}
        elif collect:
            # prefill: same math as gqa_apply, but the fresh K/V are kept
            # so the cache reflects any intervention on decoder.input
            q, k_new, v_new = C.gqa_project_qkv(p["attn"], x, cfg, positions)
            o = C.attention(q, k_new, v_new, q_pos=positions, k_pos=positions,
                            causal=True, window=window)
            a = C.linear(p["attn"]["wo"], o.reshape(B, S, -1))
            new_l = {"k": k_new, "v": v_new}
        else:
            a = C.gqa_apply(p["attn"], x, cfg, positions, window=window)
        a = taps.site("decoder.attn.output", a, layer=idx)
        h = h + a

        x = C.rms_norm(h, p["cross_norm"], cfg.norm_eps)
        q = C.linear(p["cross"]["wq"], x).reshape(B, S, cfg.n_heads, hd)
        if cross_kv is None:
            ck, cv = self._project_cross_kv(p, enc_out)
            if collect:
                new_l = dict(new_l or {}, cross_k=ck, cross_v=cv)
        else:
            ck, cv = cross_kv
        co = C.attention(q, ck, cv, q_pos=positions, k_pos=enc_pos,
                         causal=False, impl="dense" if decode else None)
        co = C.linear(p["cross"]["wo"], co.reshape(B, S, -1))
        co = taps.site("decoder.cross.output", co, layer=idx)
        h = h + co

        x = C.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        mo = C.swiglu_apply(p["mlp"], x)
        mo = taps.site("decoder.mlp.output", mo, layer=idx)
        h = h + mo
        return taps.site("decoder.output", h, layer=idx), new_l

    def forward(self, params: dict, batch: dict, *, mode: str = "scan",
                remat: bool = False) -> dict:
        """batch: src_embeds (B,T,d) + tokens (B,S)
        [+ lengths (B,) / src_lengths (B,) valid prefixes for padded rows]."""
        cfg = self.cfg
        src_lengths = batch.get("src_lengths")
        enc_out = self.encode(params, batch["src_embeds"], mode=mode,
                              remat=remat, src_lengths=src_lengths)
        tokens = batch["tokens"]
        B, S = tokens.shape
        T = enc_out.shape[1]
        positions = C.valid_positions(batch.get("lengths"), B, S)
        # padded source frames are sentinel-masked in cross-attention too
        enc_pos = C.valid_positions(src_lengths, B, T)
        h = params["embed"][tokens].astype(cfg.dtype)
        h = taps.site("embed", h)

        if mode == "unrolled":
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["decoder"])
                h, _ = self._dec_layer(p, h, positions, enc_out, enc_pos, i)
        else:
            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                p, idx = inp
                h, _ = self._dec_layer(p, h, positions, enc_out, enc_pos, idx)
                return (h, taps.scan_env_update(env_c)), taps.scan_outputs()

            if remat:
                body = jax.checkpoint(body)
            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["decoder"], jnp.arange(cfg.n_layers)),
            )
            taps.deliver_scan(ys)
        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = shard_hint(logits, P(("pod", "data"), None, "model"))
        logits = taps.site("logits", logits)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int, kind: str = "full"):
        cfg = self.cfg
        hd = cfg.hd
        T = min(max_len, cfg.sliding_window) if kind == "window" else max_len
        Ts = cfg.n_source_frames
        data = {
            "k": jnp.zeros((cfg.n_layers, batch_size, T, cfg.n_kv_heads, hd),
                           cfg.dtype),
            "v": jnp.zeros((cfg.n_layers, batch_size, T, cfg.n_kv_heads, hd),
                           cfg.dtype),
            "cross_k": jnp.zeros(
                (cfg.n_layers, batch_size, Ts, cfg.n_kv_heads, hd), cfg.dtype),
            "cross_v": jnp.zeros(
                (cfg.n_layers, batch_size, Ts, cfg.n_kv_heads, hd), cfg.dtype),
            # per-row source positions (sentinel where padded) so decode
            # cross-attention masks ragged source lengths
            "cross_pos": jnp.broadcast_to(
                jnp.arange(Ts, dtype=jnp.int32), (batch_size, Ts)),
        }
        big = jnp.iinfo(jnp.int32).max // 2
        return KVCache(kind, data, jnp.full((batch_size, T), big, jnp.int32),
                       jnp.zeros((batch_size,), jnp.int32))

    def prefill(self, params, batch, *, mode="scan", kind="full", max_len=None):
        """Encode source + teacher-force target prefix, filling caches."""
        cfg = self.cfg
        lengths = batch.get("lengths")
        src_lengths = batch.get("src_lengths")
        enc_out = self.encode(params, batch["src_embeds"], mode=mode,
                              src_lengths=src_lengths)
        tokens = batch["tokens"]
        B, S = tokens.shape
        max_len = max_len or S
        cache = self.init_cache(B, max_len, kind=kind)
        T = cache.positions.shape[1]
        Tsrc = enc_out.shape[1]
        positions = C.valid_positions(lengths, B, S)
        enc_pos = C.valid_positions(src_lengths, B, Tsrc)
        h = params["embed"][tokens].astype(cfg.dtype)
        h = taps.site("embed", h)

        ks, vs, cks, cvs = [], [], [], []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["decoder"])
            h, new_l = self._dec_layer(
                p, h, positions, enc_out, enc_pos, i, collect=True
            )
            ks.append(new_l["k"])
            vs.append(new_l["v"])
            cks.append(new_l["cross_k"])
            cvs.append(new_l["cross_v"])
        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = taps.site("logits", logits)

        k_arr, v_arr = jnp.stack(ks), jnp.stack(vs)
        if kind == "window" and S > T and lengths is not None:
            # see TransformerModel._assemble_cache: a uniform column crop
            # would evict a short row's still-in-window keys — per-row gather
            aligned, kept = C.ring_align_ragged(
                {"k": k_arr, "v": v_arr}, positions, lengths, T
            )
            k_arr, v_arr = aligned["k"], aligned["v"]
        elif kind == "window" and S > T:
            k_arr = jnp.roll(k_arr[:, :, -T:], S % T, axis=2)
            v_arr = jnp.roll(v_arr[:, :, -T:], S % T, axis=2)
            kept = jnp.roll(positions[:, -T:], S % T, axis=1)
        else:
            kept = positions
        if kept.shape[1] < T:
            pad = T - kept.shape[1]
            k_arr = jnp.pad(k_arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v_arr = jnp.pad(v_arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            kept = jnp.pad(kept, ((0, 0), (0, pad)),
                           constant_values=jnp.iinfo(jnp.int32).max // 2)
        data = {"k": k_arr, "v": v_arr,
                "cross_k": jnp.stack(cks), "cross_v": jnp.stack(cvs),
                "cross_pos": enc_pos}
        written = (jnp.full((B,), S, jnp.int32) if lengths is None
                   else jnp.asarray(lengths, jnp.int32))
        new_cache = KVCache(kind, data, kept, written)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}, new_cache

    def empty_cache(self, params, batch, batch_size, max_len, kind="full"):
        """Decode-ready cache with no target tokens written: the encoder
        still runs (cross K/V must exist before the first decode step)."""
        cfg = self.cfg
        src_lengths = batch.get("src_lengths")
        enc_out = self.encode(params, batch["src_embeds"], mode="unrolled",
                              src_lengths=src_lengths)
        Tsrc = enc_out.shape[1]
        cache = self.init_cache(batch_size, max_len, kind=kind)
        cks, cvs = [], []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["decoder"])
            ck, cv = self._project_cross_kv(p, enc_out)
            cks.append(ck)
            cvs.append(cv)
        cache.data["cross_k"] = jnp.stack(cks)
        cache.data["cross_v"] = jnp.stack(cvs)
        cache.data["cross_pos"] = C.valid_positions(
            src_lengths, batch_size, Tsrc)
        return cache

    def cache_write_rows(self, table, rows, src, src_rows=None):
        """Scatter prefilled rows into the slot table (continuous batching).
        ``cross_pos`` carries batch at axis 0; everything else at axis 1."""
        from repro.models.paged import PagedKVCache, paged_write_rows
        from repro.models.transformer import scatter_kv_rows

        if isinstance(table, PagedKVCache):
            return paged_write_rows(table, rows, src, src_rows)
        return scatter_kv_rows(table, rows, src, src_rows,
                               axis0_keys=("cross_pos",))

    def cache_clear_rows(self, table, rows):
        from repro.models.paged import PagedKVCache, paged_clear_rows
        from repro.models.transformer import clear_kv_rows

        if isinstance(table, PagedKVCache):
            return paged_clear_rows(table, rows)
        return clear_kv_rows(table, rows, axis0_keys=("cross_pos",))

    def decode_step(self, params, cache, batch, *, mode: str = "scan"):
        from repro.models.paged import PagedKVCache, paged_decode_step

        if isinstance(cache, PagedKVCache):
            return paged_decode_step(self, params, cache, batch, mode=mode)
        cfg = self.cfg
        token, pos = batch["token"], batch["pos"]
        B = token.shape[0]
        positions = pos[:, None]
        window = cfg.sliding_window if cache.kind == "window" else None
        T = cache.positions.shape[1]
        slot = pos % T if cache.kind == "window" else pos
        new_positions = _write_rows(cache.positions, slot, pos[:, None])
        Ts = cache.data["cross_k"].shape[2]
        enc_pos = cache.data.get("cross_pos")
        if enc_pos is None:
            enc_pos = jnp.broadcast_to(jnp.arange(Ts), (B, Ts))
        h = params["embed"][token].astype(cfg.dtype)
        h = taps.site("embed", h)

        if mode == "unrolled":
            new_k, new_v = list(cache.data["k"]), list(cache.data["v"])
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["decoder"])
                h, new_l = self._dec_layer(
                    p, h, positions, None, enc_pos, i,
                    cache_l={"k": cache.data["k"][i], "v": cache.data["v"][i]},
                    kv_positions=new_positions, slot=slot,
                    cross_kv=(cache.data["cross_k"][i], cache.data["cross_v"][i]),
                    window=window, decode=True,
                )
                new_k[i], new_v[i] = new_l["k"], new_l["v"]
            data = {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                    "cross_k": cache.data["cross_k"],
                    "cross_v": cache.data["cross_v"],
                    "cross_pos": enc_pos}
        else:
            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                p, kc, vc, ck, cv, idx = inp
                h, new_l = self._dec_layer(
                    p, h, positions, None, enc_pos, idx,
                    cache_l={"k": kc, "v": vc}, kv_positions=new_positions,
                    slot=slot, cross_kv=(ck, cv), window=window, decode=True,
                )
                ys = {**taps.scan_outputs(), "__k__": new_l["k"],
                      "__v__": new_l["v"]}
                return (h, taps.scan_env_update(env_c)), ys

            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["decoder"], cache.data["k"], cache.data["v"],
                 cache.data["cross_k"], cache.data["cross_v"],
                 jnp.arange(cfg.n_layers)),
            )
            data = {"k": ys.pop("__k__"), "v": ys.pop("__v__"),
                    "cross_k": cache.data["cross_k"],
                    "cross_v": cache.data["cross_v"],
                    "cross_pos": enc_pos}
            taps.deliver_scan(ys)

        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = taps.site("logits", logits)
        new_cache = KVCache(cache.kind, data, new_positions, cache.length + 1)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}, new_cache
