"""Model configuration dataclass shared by every architecture family."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig", "MLAConfig", "reduce_config"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention dims (MiniCPM3 / DeepSeek-V2 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None  # default: d_model // n_heads
    attn_kind: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    mla: MLAConfig | None = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> use d_ff)

    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # hybrid (Zamba2): one shared attention block applied every k layers
    shared_attn_every: int = 0

    # VLM: a cross-attention (image) layer every k layers
    cross_attn_every: int = 0
    n_image_tokens: int = 1601  # ViT patch-embedding count (stubbed frontend)

    # encoder-decoder (audio)
    encoder_layers: int = 0
    n_source_frames: int = 3750  # mel-frontend output length (stubbed)

    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False

    # serving
    sliding_window: int = 8192  # used by the long-context decode path

    # citations ([hf:...] / [arXiv:...] per the assignment table)
    source: str = ""

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads == 0:  # attention-free (SSM)
            return 0
        return self.d_model // self.n_heads

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def active_params(self) -> int:
        """Parameter count actually touched per token (MoE: top_k experts)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    per_layer = 0
    if cfg.attn_kind == "gqa":
        qkv = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd)
        o = cfg.n_heads * hd * d
        per_layer += qkv + o
    elif cfg.attn_kind == "mla":
        m = cfg.mla or MLAConfig()
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        per_layer += d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk_dim
        per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        per_layer += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
        per_layer += cfg.n_heads * m.v_head_dim * d
    if cfg.arch_type in ("ssm", "hybrid"):
        di, n = cfg.d_inner, cfg.ssm_state
        g = cfg.ssm_heads
        in_proj = d * (2 * di + 2 * n + g)
        per_layer = in_proj + di * d + cfg.ssm_conv_width * (di + 2 * n)
    if cfg.is_moe:
        k = cfg.top_k if active_only else cfg.n_experts
        per_layer += d * cfg.n_experts  # router
        per_layer += k * 3 * d * cfg.expert_d_ff
    elif cfg.d_ff:
        per_layer += 3 * d * cfg.d_ff
    n_layers = cfg.n_layers + cfg.encoder_layers
    total = emb + n_layers * per_layer
    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        qkv = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd)
        total += n_cross * (qkv + cfg.n_heads * hd * d)
    if cfg.shared_attn_every:
        qkv = 4 * d * (cfg.n_heads * cfg.hd)
        total += qkv + 3 * (2 * d) * cfg.d_ff  # one shared block (2d wide)
    return total


def reduce_config(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
    small: dict[str, Any] = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, max(1, min(cfg.n_heads, 4) // 2)),
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=32 if cfg.head_dim else None,
        dtype=jnp.float32,
        n_source_frames=16,
        n_image_tokens=8,
    )
    if cfg.is_moe:
        small.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
                     moe_d_ff=min(cfg.expert_d_ff, 64))
    if cfg.arch_type in ("ssm", "hybrid"):
        small.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32,
                     ssm_chunk=8)
    if cfg.encoder_layers:
        small.update(encoder_layers=2)
    if cfg.cross_attn_every:
        small.update(cross_attn_every=2)
    if cfg.shared_attn_every:
        small.update(shared_attn_every=2)
    if cfg.mla is not None:
        small.update(
            mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                          qk_nope_head_dim=16, qk_rope_head_dim=8,
                          v_head_dim=16)
        )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
