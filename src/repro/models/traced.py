"""Bind a zoo model to the NNsight-style tracing API.

``traced_lm(model, params)`` gives the paper's Figure-3b UX::

    lm = traced_lm(build_model("qwen3-8b", cfg), params)
    with lm.trace(tokens) as tr:
        lm.layers[16].mlp.output[:, -1, neurons] = 10.0
        out = lm.output.save()

The multi-invoke form (paper Fig. 3a) declares several prompts — ragged
lengths welcome — inside one trace; they lower into ONE merged, padded
forward and each invoke's saves come back at its solo shape::

    with lm.trace() as tr:
        with tr.invoke(tokens_a):
            a = lm.layers[4].output.save("acts")
        with tr.invoke(tokens_b):          # different prompt length is fine
            b = lm.output.save("out")

Because the zoo model carries ``prefill``/``decode_step``, the binding also
enables generation tracing (multi-token decode with per-step
interventions); the multi-invoke form rides one continuous slot-table
decode loop with per-invoke ``max_new_tokens``::

    with lm.generate(tokens, max_new_tokens=8) as tr:
        for s in tr.steps():
            lm.layers[4].mlp.output += steer   # write at this decode step
            lm.logits.save("logits")           # stacked to (B, 8, V)
    tr.output_tokens                           # (B, 8) greedy ids

    with lm.generate() as tr:                  # multi-invoke generation
        with tr.invoke(toks_a, max_new_tokens=4) as ia:
            for s in tr.steps():
                lm.logits.save("logits")
        with tr.invoke(toks_b, max_new_tokens=9) as ib:
            ...

See :class:`repro.core.tracer.GenerateTracer` and
:mod:`repro.core.generation` for semantics and the execution model.
"""
from __future__ import annotations

from typing import Any

from repro.core.tracer import TracedModel

__all__ = ["traced_lm"]


def traced_lm(
    model: Any,
    params: Any,
    *,
    mode: str = "unrolled",
    backend: Any | None = None,
    name: str | None = None,
) -> TracedModel:
    def model_fn(params_, tokens, **extras):
        batch = {"tokens": tokens, **extras}
        return model.forward(params_, batch, mode=mode)["logits"]

    tm = TracedModel(
        model_fn,
        params,
        model.site_schedule(mode),
        name=name or model.cfg.name,
        default_mode=mode,
        backend=backend,
    )
    tm.zoo_model = model
    return tm
