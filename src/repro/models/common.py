"""Shared model blocks: norms, RoPE, attention variants, SwiGLU, MoE, Mamba2.

Everything is a pure function of (params, inputs).  Attention has three
execution strategies, all numerically equivalent:

  * ``dense``   — materializes (S, T) scores; fine for short sequences.
  * ``chunked`` — ``lax.scan`` over key blocks with online softmax; memory is
    O(S · block) instead of O(S²).  This is the XLA-expressible flash
    attention used for long-prefill dry-runs on any backend.
  * Pallas flash kernel (``repro.kernels``) — the TPU target, selected via
    ``set_attention_impl("pallas")``; validated against ``dense`` in tests.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import shard_hint
from repro.models.config import MLAConfig, ModelConfig

__all__ = [
    "rms_norm",
    "rope",
    "init_linear",
    "linear",
    "attention",
    "gqa_init",
    "gqa_apply",
    "mla_init",
    "mla_apply",
    "swiglu_init",
    "swiglu_apply",
    "moe_init",
    "moe_apply",
    "mamba2_init",
    "mamba2_apply",
    "mamba2_decode_step",
    "set_attention_impl",
    "get_attention_impl",
    "length_mask",
    "valid_positions",
    "PAD_POS",
    "PAD_LIMIT",
]

_ATTN_IMPL = ["auto"]  # auto | dense | chunked | pallas
# §Perf H1.3–H1.5 (see EXPERIMENTS.md): three XLA attention strategies.
#   dense     S<=2048:      one-shot scores.
#   q-chunked 2048<S<=8192: scan over QUERY blocks against dense keys —
#             each accumulator written once (no online-softmax rewrites),
#             block scores ~0.5 GB f32 fit HBM.  KV-chunking at this size
#             was tried both ways and rejected: 512-blocks pay 8x acc
#             rewrites (10 TB traffic), 2048-blocks trigger pathological
#             SPMD re-sharding (5.2 TB all-gather).
#   kv-chunked S>8192:      online softmax over 512-key blocks (memory
#             bound otherwise).  On TPU the Pallas flash kernel replaces
#             all of this (accumulators never leave VMEM).
_CHUNKED_THRESHOLD = 8192
_DENSE_THRESHOLD = 2048
_Q_BLOCK = 1024
_KV_BLOCK = 512
# Finite mask value: -inf would produce NaN via (-inf) - (-inf) in the
# online-softmax update when a whole KV block is masked.
NEG_INF = -1e30
# Sentinel position for padded / unwritten slots.  Any key whose position is
# >= PAD_LIMIT is masked by _mask_bias for EVERY query — causal or not — so
# right-padded batch rows and unwritten cache slots are provably inert.
PAD_POS = jnp.iinfo(jnp.int32).max // 2
PAD_LIMIT = jnp.iinfo(jnp.int32).max // 4


def length_mask(lengths: jax.Array, seq_len: int) -> jax.Array:
    """(B, S) bool: True where the position index is < the row's length."""
    return jnp.arange(seq_len)[None, :] < lengths[:, None]


def ring_align_ragged(data, positions, lengths, T: int):
    """Per-ROW ring alignment of ragged prompts into a window cache.

    A uniform last-``T`` crop + roll (the ``lengths is None`` path of the
    families' ``_assemble_cache``) would evict a SHORT row's real keys
    that are still inside ITS window.  Instead gather per row: ring slot
    ``s`` must hold the newest position ``p < L_r`` with ``p ≡ s (mod
    T)`` — that is ``p = s + floor((L_r-1-s)/T)*T``, valid iff ``p >= 0``
    (exactly the row's last ``min(L_r, T)`` positions).  Decode then
    continues the ring bit-exactly: writing ``pos % T`` evicts precisely
    the key that just left the row's own window.

    ``data`` is a pytree of ``(A0, B, S, *tail)`` leaves; returns the
    ``(A0, B, T, *tail)`` aligned pytree and the ``(B, T)`` kept-position
    array (sentinel where no position maps to the slot).
    """
    B, S = positions.shape
    Lr = jnp.asarray(lengths, jnp.int32)[:, None]          # (B, 1)
    s = jnp.arange(T, dtype=jnp.int32)[None, :]            # (1, T)
    p = s + ((Lr - 1 - s) // T) * T
    valid = p >= 0
    p_safe = jnp.clip(p, 0, S - 1)

    def gather(a):
        idx = p_safe.reshape((1, B, T) + (1,) * (a.ndim - 3))
        idx = jnp.broadcast_to(idx, (a.shape[0], B, T) + a.shape[3:])
        return jnp.take_along_axis(a, idx, axis=2)

    kept = jnp.where(valid, p, PAD_POS)
    return jax.tree.map(gather, data), kept


def valid_positions(lengths: jax.Array | None, batch: int, seq_len: int):
    """(B, S) positions with padded slots set to the PAD sentinel.

    With ``lengths=None`` this is the plain broadcast ``arange`` every model
    used before ragged co-tenancy existed — bit-identical fast path.  Every
    attention impl honours the sentinels, including the pallas flash kernel
    (per-row positions thread into its mask — see
    ``repro.kernels.flash_attention``).
    """
    pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                           (batch, seq_len))
    if lengths is None:
        return pos
    return jnp.where(length_mask(lengths, seq_len), pos, PAD_POS)


def set_attention_impl(impl: str) -> None:
    assert impl in ("auto", "dense", "chunked", "pallas"), impl
    _ATTN_IMPL[0] = impl


def get_attention_impl() -> str:
    return _ATTN_IMPL[0]


# ------------------------------------------------------------------- basics
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 1_000_000.0
) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [
            x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin,
            x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin,
        ],
        axis=-1,
    )
    return out.astype(x.dtype)


def init_linear(
    key: jax.Array, d_in: int, d_out: int, dtype: Any, bias: bool = False
) -> dict:
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------- attention
def _mask_bias(
    q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int | None
) -> jax.Array:
    """(..., S, T) additive bias: 0 allowed / -inf masked.

    Keys carrying a sentinel position (>= PAD_LIMIT: padded batch rows,
    unwritten cache slots) are masked for every query, including non-causal
    attention — this is what makes ragged-length batch merging inert.
    """
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.broadcast_to((k_pos < PAD_LIMIT)[..., None, :], d.shape)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _dense_attention(q, k, v, q_pos, k_pos, causal, window, scale):
    # q: (B,S,K,G,hd)  k,v: (B,T,K,hd)
    # Operands stay in their native dtype (bf16 on TPU) with fp32 MXU
    # accumulation — upcasting K/V wholesale doubles the KV-cache HBM
    # traffic and forced whole-cache convert+gather chains (§Perf H2.3).
    logits = jnp.einsum(
        "bskgh,btkh->bkgst", q, k, preferred_element_type=jnp.float32
    ) * scale
    bias = _mask_bias(q_pos, k_pos, causal, window)  # (B,S,T) or (S,T)
    while bias.ndim < logits.ndim:
        bias = bias[..., None, :, :] if bias.ndim >= 2 else bias
    logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgst,btkh->bskgh", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(v.dtype)


def _qchunked_attention(q, k, v, q_pos, k_pos, causal, window, scale):
    """Scan over QUERY blocks against dense keys: accumulators written once
    per block (unlike online softmax), scores bounded to (bq, T)."""
    B, S, K, G, hd = q.shape
    bq = min(_Q_BLOCK, S)
    pad = (-S) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, [(0, 0)] * (q_pos.ndim - 1) + [(0, pad)],
                        constant_values=jnp.iinfo(jnp.int32).max // 2)
    n_blocks = q.shape[1] // bq
    qb = jnp.moveaxis(
        q.reshape(B, n_blocks, bq, K, G, hd), 1, 0
    )  # (n, B, bq, K, G, hd)
    qpb = jnp.moveaxis(q_pos.reshape(q_pos.shape[:-1] + (n_blocks, bq)), -2, 0)

    def step(_, blk):
        qc, qpc = blk
        out = _dense_attention(qc, k, v, qpc, k_pos, causal, window, scale)
        return None, out

    _, outs = jax.lax.scan(step, None, (qb, qpb))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_blocks * bq, K, G, v.shape[-1])
    return out[:, :S]


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window, scale):
    """Online-softmax over key blocks (XLA flash attention)."""
    B, S, K, G, hd = q.shape
    hd_k, hd_v = k.shape[-1], v.shape[-1]  # MLA: qk and v head dims differ
    T = k.shape[1]
    block = min(_KV_BLOCK, T)
    pad = (-T) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, [(0, 0)] * (k_pos.ndim - 1) + [(0, pad)],
                        constant_values=jnp.iinfo(jnp.int32).max // 2)
    n_blocks = k.shape[1] // block
    kb = k.reshape(B, n_blocks, block, K, hd_k).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block, K, hd_v).transpose(1, 0, 2, 3, 4)
    kpb = k_pos.reshape(k_pos.shape[:-1] + (n_blocks, block))
    kpb = jnp.moveaxis(kpb, -2, 0)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, kpc = blk
        logits = jnp.einsum(
            "bskgh,btkh->bkgst", q, kc, preferred_element_type=jnp.float32
        ) * scale
        bias = _mask_bias(q_pos, kpc, causal, window)
        while bias.ndim < logits.ndim:
            bias = bias[..., None, :, :]
        logits = logits + bias
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    acc0 = jnp.zeros((B, K, G, S, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B,S,K,G,hd)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: int | None = None,
    impl: str | None = None,
) -> jax.Array:
    """Grouped-query attention core.

    q: (B, S, H, hd) with H = K·G; k, v: (B, T, K, hd).
    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, K, G, hd)
    impl = impl or get_attention_impl()
    if impl == "auto":
        T = k.shape[1]
        if T <= _DENSE_THRESHOLD:
            impl = "dense"
        elif T <= _CHUNKED_THRESHOLD:
            impl = "qchunked"
        else:
            impl = "chunked"
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        out = kernel_ops.flash_attention(
            qg, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal, window=window
        )
    elif impl == "chunked":
        out = _chunked_attention(qg, k, v, q_pos, k_pos, causal, window, scale)
    elif impl == "qchunked":
        out = _qchunked_attention(qg, k, v, q_pos, k_pos, causal, window, scale)
    else:
        out = _dense_attention(qg, k, v, q_pos, k_pos, causal, window, scale)
    # Output head dim follows V (MLA has asymmetric qk/v head dims).
    return out.reshape(B, S, H, v.shape[-1])


# --------------------------------------------------------------------- GQA
def gqa_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, cfg.dtype, cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, cfg.dtype, cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, cfg.dtype, cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.dtype)
    return p


def gqa_project_qkv(
    p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    hd = cfg.hd
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, P(("pod", "data"), None, "model", None))
    k = shard_hint(k, P(("pod", "data"), None, "model", None))
    return q, k, v


def gqa_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    kv: tuple[jax.Array, jax.Array] | None = None,
    kv_positions: jax.Array | None = None,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Self-attention (kv=None) or attention against provided K/V (decode)."""
    B, S, _ = x.shape
    q, k_new, v_new = gqa_project_qkv(p, x, cfg, positions)
    if kv is None:
        k, v, k_pos = k_new, v_new, positions
    else:
        k, v = kv
        k_pos = kv_positions
    out = attention(
        q, k, v, q_pos=positions, k_pos=k_pos, causal=causal, window=window
    )
    out = linear(p["wo"], out.reshape(B, S, -1))
    return shard_hint(out, P(("pod", "data"), None, None))


# --------------------------------------------------------------------- MLA
def mla_init(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.mla or MLAConfig()
    d = cfg.d_model
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": init_linear(ks[0], d, m.q_lora_rank, cfg.dtype),
        "q_a_norm": jnp.ones((m.q_lora_rank,), cfg.dtype),
        "wq_b": init_linear(ks[1], m.q_lora_rank, cfg.n_heads * qk_dim, cfg.dtype),
        "wkv_a": init_linear(
            ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, cfg.dtype
        ),
        "kv_a_norm": jnp.ones((m.kv_lora_rank,), cfg.dtype),
        "wkv_b": init_linear(
            ks[3],
            m.kv_lora_rank,
            cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim),
            cfg.dtype,
        ),
        "wo": init_linear(ks[4], cfg.n_heads * m.v_head_dim, d, cfg.dtype),
    }


def mla_latent(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Compressed KV: returns (latent (B,S,r), k_rope (B,S,1,rope_dim))."""
    m = cfg.mla or MLAConfig()
    kv_a = linear(p["wkv_a"], x)
    latent = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank :][:, :, None, :]
    k_rope = rope(k_rope, positions, cfg.rope_theta)
    return latent, k_rope


def mla_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cached: tuple[jax.Array, jax.Array] | None = None,
    kv_positions: jax.Array | None = None,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Multi-head latent attention (MiniCPM3/DeepSeek-V2 family).

    ``cached`` carries (latent, k_rope) — the MLA cache is the *compressed*
    latent, the family's reason to exist.  Models that tap the latent as an
    intervention site project it themselves (``mla_latent``) and pass it in,
    so the intervened value is the one attended over.
    """
    m = cfg.mla or MLAConfig()
    B, S, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(p["wq_b"], rms_norm(linear(p["wq_a"], x), p["q_a_norm"], cfg.norm_eps))
    q = q.reshape(B, S, cfg.n_heads, qk_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    if cached is None:
        latent, k_rope = mla_latent(p, x, cfg, positions)
        k_pos = positions
    else:
        # caller already projected (and possibly tapped) the latent —
        # recomputing it here would double the projection work on
        # eager/interleaved paths where XLA DCE can't remove it
        latent, k_rope = cached
        k_pos = kv_positions

    kv = linear(p["wkv_b"], latent).reshape(
        B, -1, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))],
        axis=-1,
    )
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(
        qq, k, v, q_pos=positions, k_pos=k_pos, causal=causal, window=window
    )
    out = linear(p["wo"], out.reshape(B, S, -1))
    return shard_hint(out, P(("pod", "data"), None, None))


def mla_apply_absorbed(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    latent: jax.Array,   # (B, T, r) cached compressed KV
    k_rope: jax.Array,   # (B, T, 1, rope_dim) cached
    kv_positions: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """MLA decode with ABSORBED projections (§Perf H3; DeepSeek-V2 §2.1.2).

    The naive decode re-expands the whole latent cache through W_UK/W_UV
    every step — O(T·r·H·(d_nope+d_v)) FLOPs per token.  Folding W_UK into
    the query and W_UV after the probs keeps attention entirely in the
    compressed latent space: scores = (W_UKᵀ q_nope)·latent + q_rope·k_rope,
    ctx = probs·latent — O(T·r·H), an (d_nope+d_v)/1 ≈ 128x FLOP cut, and
    the cache is read exactly once.
    """
    m = cfg.mla or MLAConfig()
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = linear(p["wq_b"], rms_norm(linear(p["wq_a"], x), p["q_a_norm"], cfg.norm_eps))
    q = q.reshape(B, S, H, qk_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    wkv = p["wkv_b"]["w"].reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim
    )
    wk = wkv[..., : m.qk_nope_head_dim]   # (r, H, nope)
    wv = wkv[..., m.qk_nope_head_dim :]   # (r, H, v)

    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk,
                       preferred_element_type=jnp.float32).astype(latent.dtype)
    scale = 1.0 / math.sqrt(qk_dim)
    scores = jnp.einsum("bshr,btr->bhst", q_lat, latent,
                        preferred_element_type=jnp.float32)
    scores = scores + jnp.einsum(
        "bshd,btd->bhst", q_rope, k_rope[:, :, 0, :],
        preferred_element_type=jnp.float32,
    )
    scores = scores * scale
    bias = _mask_bias(positions, kv_positions, True, window)  # (B,S,T)
    scores = scores + bias[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs.astype(latent.dtype), latent,
                     preferred_element_type=jnp.float32).astype(latent.dtype)
    out = jnp.einsum("bshr,rhv->bshv", ctx, wv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = linear(p["wo"], out.reshape(B, S, H * m.v_head_dim))
    return shard_hint(out, P(("pod", "data"), None, None))


# ------------------------------------------------------------------- SwiGLU
def swiglu_init(key: jax.Array, d: int, d_ff: int, dtype: Any) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "wg": init_linear(ks[0], d, d_ff, dtype),
        "wu": init_linear(ks[1], d, d_ff, dtype),
        "wd": init_linear(ks[2], d_ff, d, dtype),
    }


def swiglu_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(linear(p["wg"], x)) * linear(p["wu"], x)
    h = shard_hint(h, P(("pod", "data"), None, "model"))
    return linear(p["wd"], h)


# ---------------------------------------------------------------------- MoE
def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    return {
        "router": init_linear(ks[0], d, e, jnp.float32),
        "wg": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(cfg.dtype),
        "wu": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale_in).astype(cfg.dtype),
        "wd": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_out).astype(cfg.dtype),
    }


def moe_apply(
    p: dict, x: jax.Array, cfg: ModelConfig, router_tap=None
) -> tuple[jax.Array, jax.Array]:
    """Dropless top-k MoE via sort + ``lax.ragged_dot``.

    Returns (output, router aux loss).  ``router_tap`` exposes router logits
    as an intervention site (load-balance interventions, routing analysis).
    """
    B, S, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = linear(p["router"], xt.astype(jnp.float32))  # (T, E)
    if router_tap is not None:
        logits = router_tap(logits.reshape(B, S, e)).reshape(T, e)
    weights, ids = jax.lax.top_k(logits, k)  # (T, k)
    weights = jax.nn.softmax(weights, axis=-1)

    # Aux load-balance loss (Switch-style).
    probs = jax.nn.softmax(logits, axis=-1)
    density = probs.mean(axis=0)
    hard = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (T * k)
    aux = e * jnp.sum(density * hard)

    flat_ids = ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_ids)
    token_of = order // k
    xs = xt[token_of]  # (T*k, d) sorted by expert
    group_sizes = jnp.bincount(flat_ids, length=e).astype(jnp.int32)

    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["wg"], group_sizes)) * jax.lax.ragged_dot(
        xs, p["wu"], group_sizes
    )
    h = shard_hint(h, P(None, "model"))
    ys = jax.lax.ragged_dot(h, p["wd"], group_sizes)  # (T*k, d)

    # Un-sort and combine with routing weights.
    inv = jnp.argsort(order)
    y = ys[inv].reshape(T, k, d)
    out = jnp.einsum("tkd,tk->td", y.astype(jnp.float32), weights)
    return out.reshape(B, S, d).astype(x.dtype), aux


# ------------------------------------------------------------------- Mamba2
def mamba2_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(ks[0], d, 2 * di + 2 * n + h, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch), jnp.float32)
                   / math.sqrt(cfg.ssm_conv_width)).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), cfg.dtype),
        "out_proj": init_linear(ks[2], di, d, cfg.dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out + b


def _ssd_chunked(x, dt, A, B_, C, D, chunk):
    """Mamba2 SSD, chunked (state-space duality form, arXiv:2405.21060 §6).

    x: (B,S,H,P)  dt: (B,S,H)  A: (H,) >0 decay rate  B_,C: (B,S,N)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, Pd = x.shape
    N = B_.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S2 = x.shape[1]
    nc = S2 // chunk
    xc = x.reshape(Bb, nc, chunk, H, Pd)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = B_.reshape(Bb, nc, chunk, N)
    Cc = C.reshape(Bb, nc, chunk, N)

    dA = dtc * A[None, None, None, :]  # (B,nc,L,H) decay exponents (>=0)
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative decay
    # intra-chunk: y[s] = sum_{t<=s} C[s]·B[t] exp(-(cum[s]-cum[t])) dt[t] x[t]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # Mask BEFORE exp: upper-triangle seg is negative, exp(-seg) would be
    # inf — masked in the forward but 0·inf = NaN in the backward pass.
    seg = jnp.where(tri[None, None, :, :, None], seg, 0.0)
    decay = jnp.exp(-seg) * tri[None, None, :, :, None]
    cb = jnp.einsum("bcln,bctn->bclt", Cc, Bc)  # (B,nc,L,L)
    y_diag = jnp.einsum(
        "bclt,bclth,bcth,bcthp->bclhp",
        cb.astype(jnp.float32),
        decay,
        dtc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )

    # chunk states: state_c = sum_t B[t] exp(-(cum[L-1]-cum[t])) dt[t] x[t]
    total = cum[:, :, -1, :]  # (B,nc,H)
    tail = jnp.exp(-(total[:, :, None, :] - cum))  # (B,nc,L,H)
    states = jnp.einsum(
        "bctn,bcth,bcth,bcthp->bchpn",
        Bc.astype(jnp.float32),
        tail,
        dtc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )  # (B,nc,H,P,N)

    # inter-chunk recurrence over nc chunks.
    def step(prev, inp):
        st, tot = inp  # (B,H,P,N), (B,H)
        new = prev * jnp.exp(-tot)[:, :, None, None] + st
        return new, prev

    init = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    final, prevs = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    prevs = prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering chunk

    # off-diagonal: y_off[s] = C[s] · (exp(-cum[s]) * prev_state)
    y_off = jnp.einsum(
        "bcln,bclh,bchpn->bclhp",
        Cc.astype(jnp.float32),
        jnp.exp(-cum),
        prevs,
    )
    y = (y_diag + y_off).reshape(Bb, S2, H, Pd)[:, :S]
    y = y + x[:, :S].astype(jnp.float32) * D[None, None, :, None]
    return y, final


def mamba2_apply(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state_tap=None,
    impl: str | None = None,
    lengths: jax.Array | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence Mamba2 block. Returns (out, (ssm_state, conv_tail)).

    ``lengths`` (B,) marks per-row valid prefixes for ragged batch merging:
    padded positions get ``dt = 0`` (decay 1, update 0 — the state passes
    through them unchanged, exactly like the chunk padding the SSD scan
    already does), so the final state and every real position's output are
    bit-identical to an unpadded run.  The conv tail is gathered per row
    from the last ``W-1`` REAL positions.
    """
    B, S, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = linear(p["in_proj"], x)
    z, xin, B_, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, B_, C], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xin, B_, C = jnp.split(conv, [di, di + n], axis=-1)
    xin = shard_hint(xin, P(("pod", "data"), None, "model"))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if lengths is not None:
        dt = jnp.where(length_mask(lengths, S)[..., None], dt, 0.0)
    A = jnp.exp(p["A_log"])
    xh = xin.reshape(B, S, h, cfg.ssm_head_dim)

    impl = impl or ("pallas" if get_attention_impl() == "pallas" else "jnp")
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        y, final = kernel_ops.ssd_scan(xh, dt, A, B_, C, p["D"], cfg.ssm_chunk)
    else:
        y, final = _ssd_chunked(xh, dt, A, B_, C, p["D"], cfg.ssm_chunk)
    if state_tap is not None:
        final = state_tap(final)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(p["out_proj"], y)
    W = cfg.ssm_conv_width
    if lengths is None:
        conv_tail = conv_in[:, -(W - 1):, :]
    else:
        # per-row window of the last W-1 REAL conv inputs (zero-filled when
        # the row is shorter than the window, matching a fresh cache)
        idx = lengths[:, None] - (W - 1) + jnp.arange(W - 1)[None, :]
        tail = jnp.take_along_axis(
            conv_in, jnp.clip(idx, 0, S - 1)[:, :, None], axis=1
        )
        conv_tail = jnp.where((idx >= 0)[:, :, None], tail, 0.0).astype(
            conv_in.dtype
        )
    return shard_hint(out, P(("pod", "data"), None, None)), (
        final.astype(jnp.float32),
        conv_tail,
    )


def mamba2_decode_step(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: tuple[jax.Array, jax.Array],
    *,
    state_tap=None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token recurrent update. x: (B,1,d); state: (ssm (B,H,P,N), conv)."""
    B, _, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ssm_state, conv_tail = state  # conv_tail: (B, W-1, C)
    zxbcdt = linear(p["in_proj"], x)
    z, xin, B_, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, B_, C], axis=-1)  # (B,1,C)
    window = jnp.concatenate([conv_tail, conv_in], axis=1)  # (B,W,C)
    conv = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    ).astype(x.dtype)[:, None, :]
    xin, B_, C = jnp.split(conv, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = jnp.exp(p["A_log"])
    xh = xin.reshape(B, h, cfg.ssm_head_dim).astype(jnp.float32)
    decay = jnp.exp(-dt * A[None, :])  # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, B_[:, 0].astype(jnp.float32))
    new_state = ssm_state * decay[..., None, None] + upd
    if state_tap is not None:
        new_state = state_tap(new_state)
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), new_state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(p["out_proj"], y)
    return out, (new_state, window[:, 1:, :])
