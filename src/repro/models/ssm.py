"""Mamba2 (SSD) language model — attention-free [arXiv:2405.21060].

Tap sites expose the *recurrent state* (``layers.ssm_state``) — a capability
the paper never demonstrates (PyTorch hooks see module boundaries, not fused
scan internals); here the state is a first-class intervention target.
Decode is O(1) in context length, so this family runs ``long_500k`` natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import taps
from repro.core.interleave import SiteSchedule
from repro.distributed import shard_hint
from repro.models import common as C
from repro.models.config import ModelConfig

__all__ = ["Mamba2Model"]


class Mamba2Model:
    scan_prefill = True
    # Recurrent state is O(1) per row (no KV growth), so there is nothing
    # to page: ``build_paged_cache`` returns None for this family and the
    # paged decode loop falls back to the dense slot table — row scatter
    # already accepts arbitrary (non-contiguous) row arrays.

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def site_length_key(self, site: str) -> str | None:
        # the recurrent state (B,H,P,N) has no sequence axis
        return None if site == "layers.ssm_state" else "tokens"

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_out = jax.random.split(key, 3)

        def layer_init(k):
            return {
                "norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "mixer": C.mamba2_init(k, cfg),
            }

        layers = jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers))
        return {
            "embed": (
                jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02
            ).astype(cfg.dtype),
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
            "lm_head": C.init_linear(k_out, cfg.d_model, cfg.vocab_size, cfg.dtype),
        }

    def site_names(self) -> list[str]:
        return ["layers.input", "layers.ssm_state", "layers.mixer.output",
                "layers.output"]

    def site_schedule(self, mode: str = "unrolled") -> SiteSchedule:
        body = self.site_names()
        order: list[tuple[str, int | None]] = [("embed", None)]
        for i in range(self.cfg.n_layers):
            order += [(n, i) for n in body]
        order += [("final_norm", None), ("logits", None)]
        return SiteSchedule(
            order=order,
            scan_sites=tuple(body) if mode == "scan" else (),
            n_layers=self.cfg.n_layers,
        )

    # ---------------------------------------------------------------- layers
    def _layer(self, p, h, layer, lengths=None):
        cfg = self.cfg
        h = taps.site("layers.input", h, layer=layer)
        h = shard_hint(h, P(("pod", "data"), "model", None))
        x = C.rms_norm(h, p["norm"], cfg.norm_eps)
        state_tap = lambda v: taps.site("layers.ssm_state", v, layer=layer)
        out, state = C.mamba2_apply(p["mixer"], x, cfg, state_tap=state_tap,
                                    lengths=lengths)
        out = taps.site("layers.mixer.output", out, layer=layer)
        h = h + out
        h = taps.site("layers.output", h, layer=layer)
        return h, state

    def forward(self, params: dict, batch: dict, *, mode: str = "scan",
                remat: bool = False) -> dict:
        cfg = self.cfg
        tokens = batch["tokens"]
        lengths = batch.get("lengths")
        h = params["embed"][tokens].astype(cfg.dtype)
        h = shard_hint(h, P(("pod", "data"), None, None))
        h = taps.site("embed", h)
        if mode == "unrolled":
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                h, _ = self._layer(p, h, i, lengths)
        else:
            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                p, idx = inp
                h, _ = self._layer(p, h, idx, lengths)
                return (h, taps.scan_env_update(env_c)), taps.scan_outputs()

            if remat:
                body = jax.checkpoint(body)
            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["layers"], jnp.arange(cfg.n_layers)),
            )
            taps.deliver_scan(ys)
        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = shard_hint(logits, P(("pod", "data"), None, "model"))
        logits = taps.site("logits", logits)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int = 0, kind: str = "full"):
        cfg = self.cfg
        L, H, Pd, N = cfg.n_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "ssm": jnp.zeros((L, batch_size, H, Pd, N), jnp.float32),
            "conv": jnp.zeros(
                (L, batch_size, cfg.ssm_conv_width - 1, conv_ch), cfg.dtype
            ),
        }

    def empty_cache(self, params, batch, batch_size, max_len, kind="full"):
        return self.init_cache(batch_size, max_len, kind=kind)

    def cache_write_rows(self, table, rows, src, src_rows=None):
        """Scatter a prefilled request's recurrent state rows into the
        slot-table cache (continuous batching).  Both leaves carry batch at
        axis 1 (``(L, B, ...)``)."""
        rows = jnp.asarray(rows)
        take = (lambda a: a) if src_rows is None else (
            lambda a: jnp.take(a, jnp.asarray(src_rows), axis=1))
        return {k: table[k].at[:, rows].set(take(src[k])) for k in table}

    def cache_clear_rows(self, table, rows):
        """Zero retired slot rows (a fresh Mamba2 state IS the zero state)."""
        rows = jnp.asarray(rows)
        return {k: v.at[:, rows].set(0) for k, v in table.items()}

    def prefill(self, params, batch, *, mode: str = "scan", kind="full",
                max_len=None):
        """Forward + per-layer final states (O(1)-size cache).

        Fires the same tap sites as ``forward`` so generation traces can
        intervene on (or collect from) the prompt prefill.  With
        ``batch["lengths"]``, padded rows' states stop at their last real
        token (dt-masked in the SSD scan) and the conv tail is gathered from
        real positions, so ragged prompts share one prefill.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        lengths = batch.get("lengths")
        h = params["embed"][tokens].astype(cfg.dtype)
        h = taps.site("embed", h)

        if mode == "unrolled":
            ssm_states, conv_states = [], []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                h, (s, c) = self._layer(p, h, i, lengths)
                ssm_states.append(s)
                conv_states.append(c)
            states = (jnp.stack(ssm_states), jnp.stack(conv_states))
        else:
            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                p, idx = inp
                h, state = self._layer(p, h, idx, lengths)
                ys = {**taps.scan_outputs(), "__state__": state}
                return (h, taps.scan_env_update(env_c)), ys

            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["layers"], jnp.arange(cfg.n_layers)),
            )
            states = ys.pop("__state__")
            taps.deliver_scan(ys)
        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = taps.site("logits", logits)
        cache = {"ssm": states[0], "conv": states[1]}
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}, cache

    def decode_step(self, params, cache, batch, *, mode: str = "scan"):
        cfg = self.cfg
        token = batch["token"]  # (B, 1)
        h = params["embed"][token].astype(cfg.dtype)
        h = taps.site("embed", h)

        def layer_step(p, h, st, idx):
            h = taps.site("layers.input", h, layer=idx)
            x = C.rms_norm(h, p["norm"], cfg.norm_eps)
            state_tap = lambda v: taps.site("layers.ssm_state", v, layer=idx)
            out, new_st = C.mamba2_decode_step(
                p["mixer"], x, cfg, st, state_tap=state_tap
            )
            out = taps.site("layers.mixer.output", out, layer=idx)
            h = h + out
            h = taps.site("layers.output", h, layer=idx)
            return h, new_st

        if mode == "unrolled":
            new_ssm, new_conv = [], []
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                st = (cache["ssm"][i], cache["conv"][i])
                h, (s, c) = layer_step(p, h, st, i)
                new_ssm.append(s)
                new_conv.append(c)
            new_cache = {"ssm": jnp.stack(new_ssm), "conv": jnp.stack(new_conv)}
        else:
            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                p, s, c, idx = inp
                h, (s2, c2) = layer_step(p, h, (s, c), idx)
                ys = {**taps.scan_outputs(), "__s__": s2, "__c__": c2}
                return (h, taps.scan_env_update(env_c)), ys

            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["layers"], cache["ssm"], cache["conv"],
                 jnp.arange(cfg.n_layers)),
            )
            new_cache = {"ssm": ys.pop("__s__"), "conv": ys.pop("__c__")}
            taps.deliver_scan(ys)
        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = taps.site("logits", logits)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}, new_cache
