"""Decoder-only transformer stack: dense (GQA/MLA/qk_norm/bias), MoE, VLM.

Covers 8 of the 10 assigned architectures (everything except the SSM/hybrid
and encoder-decoder families).  One parameterized implementation with:

  * unrolled mode — Python loop, every tap site distinct, fully general
    interventions (CPU smoke tests, small research models);
  * scan mode — ``lax.scan`` over stacked layer params, O(1) compile time in
    depth (the 62–100 layer production configs), taps via the scan-site
    mechanism of :mod:`repro.core.interleave`;
  * prefill / decode with full, ring-buffer (sliding window), and MLA-latent
    KV caches.

Tap sites (per layer): ``layers.input``, ``layers.attn.output``,
``layers.mlp.output`` (+ ``layers.mlp.router`` for MoE,
``layers.attn.kv_latent`` for MLA, ``layers.cross.output`` for VLM),
``layers.output``; global: ``embed``, ``final_norm``, ``logits``, ``output``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import taps
from repro.core.interleave import SiteSchedule
from repro.distributed import shard_hint
from repro.models import common as C
from repro.models.config import ModelConfig

__all__ = ["TransformerModel", "KVCache"]


@dataclasses.dataclass
class KVCache:
    """Pytree KV cache. kind: full | window | mla."""

    kind: str
    # full/window: k, v (L, B, T, K, hd); mla: latent (L, B, T, r), k_rope.
    data: dict
    positions: jax.Array  # (B, T) original position of each slot
    length: jax.Array  # (B,) tokens written so far


jax.tree_util.register_pytree_node(
    KVCache,
    lambda c: ((c.data, c.positions, c.length), c.kind),
    lambda kind, xs: KVCache(kind, xs[0], xs[1], xs[2]),
)


class TransformerModel:
    # prefill() honours mode="scan" (taps fire inside lax.scan and are
    # delivered); families whose prefill runs a Python layer loop set False
    # and generation traces force unrolled scheduling for the prefill slice.
    scan_prefill = True
    # cache data keys with these prefixes stay DENSE under paging (fixed
    # per-row size — cross-attention K/V never grow with decode)
    paged_exclude_keys = ("cross",)
    # dense cache keys whose batch axis is 0 (none for this family)
    cache_axis0_keys = ()

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_vlm = cfg.cross_attn_every > 0

    def site_length_key(self, site: str) -> str | None:
        """Which batch input's axis-1 length a tap value's axis 1 follows.

        Used by ragged batch merging to slice saves back to each request's
        true length; ``None`` marks sites with no sequence axis."""
        return "tokens"

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_out = jax.random.split(key, 3)

        def layer_init(k):
            ka, kf, kc = jax.random.split(k, 3)
            p: dict[str, Any] = {
                "attn_norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "mlp_norm": jnp.ones((cfg.d_model,), cfg.dtype),
            }
            if cfg.attn_kind == "mla":
                p["attn"] = C.mla_init(ka, cfg)
            else:
                p["attn"] = C.gqa_init(ka, cfg)
            if cfg.is_moe:
                p["moe"] = C.moe_init(kf, cfg)
            else:
                p["mlp"] = C.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
            return p

        keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(layer_init)(keys)  # stacked (L, ...)
        params = {
            "embed": (
                jax.random.normal(
                    k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32
                )
                * 0.02
            ).astype(cfg.dtype),
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = C.init_linear(
                k_out, cfg.d_model, cfg.vocab_size, cfg.dtype
            )
        if self.is_vlm:
            n_cross = cfg.n_layers // cfg.cross_attn_every
            ck = jax.random.split(k_out, n_cross)

            def cross_init(k):
                return {
                    "norm": jnp.ones((cfg.d_model,), cfg.dtype),
                    "attn": C.gqa_init(k, cfg),
                    "gate": jnp.zeros((), jnp.float32),
                }

            params["cross"] = jax.vmap(cross_init)(ck)
        return params

    # -------------------------------------------------------------- schedule
    def site_names(self) -> list[str]:
        cfg = self.cfg
        names = ["layers.input", "layers.attn.output"]
        if cfg.attn_kind == "mla":
            names.insert(1, "layers.attn.kv_latent")
        if self.is_vlm:
            names.append("layers.cross.output")
        if cfg.is_moe:
            names.append("layers.mlp.router")
        names += ["layers.mlp.output", "layers.output"]
        return names

    def site_schedule(self, mode: str = "unrolled") -> SiteSchedule:
        cfg = self.cfg
        order: list[tuple[str, int | None]] = [("embed", None)]
        body = self.site_names()
        for i in range(cfg.n_layers):
            for n in body:
                if (n == "layers.cross.output"
                        and (i + 1) % cfg.cross_attn_every != 0):
                    continue  # cross-attention exists every k-th layer only
                order.append((n, i))
        order += [("final_norm", None), ("logits", None)]
        return SiteSchedule(
            order=order,
            scan_sites=tuple(body) if mode == "scan" else (),
            n_layers=cfg.n_layers,
        )

    # --------------------------------------------------------------- layers
    def _layer(
        self,
        p: dict,
        h: jax.Array,
        positions: jax.Array,
        layer: Any,
        *,
        cross_kv=None,
        window: int | None = None,
        cross_p: dict | None = None,
        collect: bool = False,
    ) -> tuple[jax.Array, jax.Array, dict | None]:
        """One block (full-sequence). Returns (h, aux_loss, kv_entry)."""
        cfg = self.cfg
        h = taps.site("layers.input", h, layer=layer)
        # Sequence-parallel residual: between blocks the stream shards over
        # (batch, seq); XLA inserts the Megatron-SP all-gather/reduce-scatter
        # pairs around attention/MLP automatically.
        h = shard_hint(h, P(("pod", "data"), "model", None))
        x = C.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        kv_entry = None
        if cfg.attn_kind == "mla":
            latent_tap = lambda v: taps.site("layers.attn.kv_latent", v, layer=layer)
            latent, k_rope = C.mla_latent(p["attn"], x, cfg, positions)
            latent = latent_tap(latent)
            attn_out = C.mla_apply(
                p["attn"], x, cfg, positions,
                cached=(latent, k_rope), kv_positions=positions, window=window,
            )
            if collect:
                kv_entry = {"latent": latent, "k_rope": k_rope}
        else:
            q, k_new, v_new = C.gqa_project_qkv(p["attn"], x, cfg, positions)
            B_, S_, _ = x.shape
            o = C.attention(q, k_new, v_new, q_pos=positions, k_pos=positions,
                            causal=True, window=window)
            attn_out = C.linear(p["attn"]["wo"], o.reshape(B_, S_, -1))
            attn_out = shard_hint(attn_out, P(("pod", "data"), None, None))
            if collect:
                kv_entry = {"k": k_new, "v": v_new}
        attn_out = taps.site("layers.attn.output", attn_out, layer=layer)
        h = h + attn_out

        if cross_p is not None and cross_kv is not None:
            xc = C.rms_norm(h, cross_p["norm"], cfg.norm_eps)
            B, S, _ = xc.shape
            hd = cfg.hd
            q = C.linear(cross_p["attn"]["wq"], xc).reshape(B, S, cfg.n_heads, hd)
            ck, cv, cpos = cross_kv
            cout = C.attention(
                q, ck, cv,
                q_pos=positions, k_pos=cpos, causal=False, window=None,
            )
            cout = C.linear(cross_p["attn"]["wo"], cout.reshape(B, S, -1))
            cout = jnp.tanh(cross_p["gate"]).astype(cout.dtype) * cout
            cout = taps.site("layers.cross.output", cout, layer=layer)
            h = h + cout

        x = C.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.is_moe:
            router_tap = lambda v: taps.site("layers.mlp.router", v, layer=layer)
            mlp_out, aux = _moe(p["moe"], x, cfg, router_tap)
        else:
            mlp_out = C.swiglu_apply(p["mlp"], x)
        mlp_out = taps.site("layers.mlp.output", mlp_out, layer=layer)
        h = h + mlp_out
        h = taps.site("layers.output", h, layer=layer)
        return h, aux, kv_entry

    def _cross_kv(self, params: dict, image_embeds: jax.Array, idx) -> tuple:
        """Precompute cross-attention K/V from (stub-frontend) embeddings."""
        cfg = self.cfg
        cp = jax.tree.map(lambda a: a[idx], params["cross"])
        B, T, _ = image_embeds.shape
        hd = cfg.hd
        ck = C.linear(cp["attn"]["wk"], image_embeds).reshape(B, T, cfg.n_kv_heads, hd)
        cv = C.linear(cp["attn"]["wv"], image_embeds).reshape(B, T, cfg.n_kv_heads, hd)
        cpos = jnp.broadcast_to(jnp.arange(T), (B, T))
        return cp, (ck, cv, cpos)

    # -------------------------------------------------------------- forward
    def forward(
        self,
        params: dict,
        batch: dict,
        *,
        mode: str = "unrolled",
        window: int | None = None,
        remat: bool = False,
    ) -> dict:
        """Teacher-forcing forward. batch: tokens (B,S) [+ image_embeds;
        + lengths (B,) per-row valid prefixes for right-padded rows]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = C.valid_positions(batch.get("lengths"), B, S)
        h = params["embed"][tokens].astype(cfg.dtype)
        h = shard_hint(h, P(("pod", "data"), None, None))
        h = taps.site("embed", h)
        image_embeds = batch.get("image_embeds")

        aux_total = jnp.zeros((), jnp.float32)
        if mode == "unrolled":
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                cross_p, cross_kv = None, None
                if self.is_vlm and (i + 1) % cfg.cross_attn_every == 0:
                    cross_p, cross_kv = self._cross_kv(
                        params, image_embeds, (i + 1) // cfg.cross_attn_every - 1
                    )
                h, aux, _ = self._layer(
                    p, h, positions, i, window=window,
                    cross_p=cross_p, cross_kv=cross_kv,
                )
                aux_total = aux_total + aux
        else:
            h, aux_total, _, _ = self._scan_layers(
                params, h, positions, window=window,
                image_embeds=image_embeds, remat=remat,
            )

        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = self._lm_head(params, h)
        logits = taps.site("logits", logits)
        return {"logits": logits, "aux_loss": aux_total}

    def _lm_head(self, params: dict, h: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            logits = h @ params["embed"].T.astype(h.dtype)
        else:
            logits = C.linear(params["lm_head"], h)
        return shard_hint(logits, P(("pod", "data"), None, "model"))

    def _scan_layers(self, params, h, positions, *, window, image_embeds,
                     remat=False, collect=False):
        cfg = self.cfg
        if not self.is_vlm:
            def body(carry, inp):
                (h, aux), env_c = carry
                taps.scan_env_provide(env_c)
                p, idx = inp
                h, a, kv = self._layer(p, h, positions, idx, window=window,
                                       collect=collect)
                ys = dict(taps.scan_outputs())
                if collect:
                    ys["__kv__"] = kv
                return ((h, aux + a), taps.scan_env_update(env_c)), ys

            if remat:
                body = jax.checkpoint(body)
            ((h, aux), _), ys = jax.lax.scan(
                body,
                ((h, jnp.zeros((), jnp.float32)), taps.scan_env_init()),
                (params["layers"], jnp.arange(cfg.n_layers)),
            )
            kv = ys.pop("__kv__", None)
            taps.deliver_scan(ys)
            return h, aux, kv, None

        # VLM: scan over super-layers of `cross_attn_every` blocks; the last
        # block of each group carries a cross-attention layer.
        k = cfg.cross_attn_every
        n_groups = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, k) + a.shape[1:]), params["layers"]
        )

        def body(carry, inp):
            (h, aux), env_c = carry
            taps.scan_env_provide(env_c)
            pg, cp_leaf, g = inp
            kvs = []
            cross_kv_entry = None
            for j in range(k):
                idx = g * k + j
                p = jax.tree.map(lambda a: a[j], pg)
                if j == k - 1:
                    B, T, _ = image_embeds.shape
                    hd = cfg.hd
                    ck = C.linear(cp_leaf["attn"]["wk"], image_embeds).reshape(
                        B, T, cfg.n_kv_heads, hd
                    )
                    cv = C.linear(cp_leaf["attn"]["wv"], image_embeds).reshape(
                        B, T, cfg.n_kv_heads, hd
                    )
                    cpos = jnp.broadcast_to(jnp.arange(T), (B, T))
                    h, a, kv = self._layer(
                        p, h, positions, idx, window=window,
                        cross_p=cp_leaf, cross_kv=(ck, cv, cpos),
                        collect=collect,
                    )
                    cross_kv_entry = (ck, cv)
                else:
                    h, a, kv = self._layer(p, h, positions, idx, window=window,
                                           collect=collect)
                kvs.append(kv)
                aux = aux + a
            ys = dict(taps.scan_outputs())
            if collect:
                ys["__kv__"] = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
                ys["__cross__"] = cross_kv_entry
            return ((h, aux), taps.scan_env_update(env_c)), ys

        if remat:
            body = jax.checkpoint(body)
        ((h, aux), _), ys = jax.lax.scan(
            body,
            ((h, jnp.zeros((), jnp.float32)), taps.scan_env_init()),
            (grouped, params["cross"], jnp.arange(n_groups)),
        )
        kv = ys.pop("__kv__", None)
        cross = ys.pop("__cross__", None)
        if kv is not None:
            # (n_groups, k, B, S, ...) -> (L, B, S, ...)
            kv = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), kv
            )
        taps.deliver_scan(ys)
        return h, aux, kv, cross

    # ---------------------------------------------------------------- cache
    def init_cache(
        self, batch_size: int, max_len: int, kind: str = "full"
    ) -> KVCache:
        cfg = self.cfg
        L, hd = cfg.n_layers, cfg.hd
        T = min(max_len, cfg.sliding_window) if kind == "window" else max_len
        if cfg.attn_kind == "mla":
            m = cfg.mla
            data = {
                "latent": jnp.zeros((L, batch_size, T, m.kv_lora_rank), cfg.dtype),
                "k_rope": jnp.zeros((L, batch_size, T, 1, m.qk_rope_head_dim), cfg.dtype),
            }
            kind = "mla" if kind == "full" else kind
        else:
            data = {
                "k": jnp.zeros((L, batch_size, T, cfg.n_kv_heads, hd), cfg.dtype),
                "v": jnp.zeros((L, batch_size, T, cfg.n_kv_heads, hd), cfg.dtype),
            }
        if self.is_vlm:
            n_cross = L // cfg.cross_attn_every
            Ti = cfg.n_image_tokens
            data["cross_k"] = jnp.zeros(
                (n_cross, batch_size, Ti, cfg.n_kv_heads, hd), cfg.dtype
            )
            data["cross_v"] = jnp.zeros_like(data["cross_k"])
        # Unwritten slots carry position +BIG so both the causal mask
        # (q_pos - BIG < 0) and the window mask exclude them.
        positions = jnp.full(
            (batch_size, T), jnp.iinfo(jnp.int32).max // 2, jnp.int32
        )
        return KVCache(kind, data, positions, jnp.zeros((batch_size,), jnp.int32))

    def decode_step(
        self, params: dict, cache: KVCache, batch: dict, *, mode: str = "scan"
    ) -> tuple[dict, KVCache]:
        """One-token decode against the cache. batch: token (B,1), pos (B,)."""
        from repro.models.paged import PagedKVCache, paged_decode_step

        if isinstance(cache, PagedKVCache):
            return paged_decode_step(self, params, cache, batch, mode=mode)
        cfg = self.cfg
        token, pos = batch["token"], batch["pos"]
        B = token.shape[0]
        positions = pos[:, None]
        h = params["embed"][token].astype(cfg.dtype)
        h = taps.site("embed", h)
        window = cfg.sliding_window if cache.kind == "window" else None
        T = cache.positions.shape[1]
        slot = pos % T if cache.kind == "window" else pos
        new_positions = _write_rows(cache.positions, slot, pos[:, None])
        kv_positions = new_positions

        def one_layer(p, h, cache_l, idx, cross=None):
            return self._layer_decode(
                p, h, positions, idx, cache_l, kv_positions, window, slot,
                cross=cross,
            )

        aux_total = jnp.zeros((), jnp.float32)
        per_layer = {k: v for k, v in cache.data.items() if not k.startswith("cross")}
        if mode == "unrolled":
            new_data = jax.tree.map(lambda a: a, per_layer)
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                cache_l = jax.tree.map(lambda a: a[i], per_layer)
                cross = self._decode_cross(params, cache, i)
                h, aux, new_l = one_layer(p, h, cache_l, i, cross)
                aux_total = aux_total + aux
                new_data = jax.tree.map(
                    lambda full, nl, i=i: full.at[i].set(nl), new_data, new_l
                )
        else:
            def body(carry, inp):
                (h, aux), env_c = carry
                taps.scan_env_provide(env_c)
                p, cache_l, idx = inp
                cross = None
                if self.is_vlm:
                    is_cross = (idx + 1) % cfg.cross_attn_every == 0
                    ci = jnp.maximum((idx + 1) // cfg.cross_attn_every - 1, 0)
                    ck = cache.data["cross_k"][ci]
                    cv = cache.data["cross_v"][ci]
                    cp = jax.tree.map(lambda a: a[ci], params["cross"])
                    cross = (cp, ck, cv, is_cross)
                h, a, new_l = one_layer(p, h, cache_l, idx, cross)
                ys = {**taps.scan_outputs(), "__cache__": new_l}
                return ((h, aux + a), taps.scan_env_update(env_c)), ys

            ((h, aux_total), _), ys = jax.lax.scan(
                body,
                ((h, jnp.zeros((), jnp.float32)), taps.scan_env_init()),
                (params["layers"], per_layer, jnp.arange(cfg.n_layers)),
            )
            new_data = ys.pop("__cache__")
            taps.deliver_scan(ys)

        for k in cache.data:
            if k.startswith("cross"):
                new_data[k] = cache.data[k]
        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = self._lm_head(params, h)
        logits = taps.site("logits", logits)
        new_cache = KVCache(cache.kind, new_data, new_positions, cache.length + 1)
        return {"logits": logits, "aux_loss": aux_total}, new_cache

    def _decode_cross(self, params, cache, i):
        cfg = self.cfg
        if not (self.is_vlm and (i + 1) % cfg.cross_attn_every == 0):
            return None
        ci = (i + 1) // cfg.cross_attn_every - 1
        cp = jax.tree.map(lambda a: a[ci], params["cross"])
        return (cp, cache.data["cross_k"][ci], cache.data["cross_v"][ci], True)

    def _layer_decode(
        self, p, h, positions, layer, cache_l, kv_positions, window, slot,
        cross=None,
    ):
        """Decode layer: write this token's K/V at `slot`, attend to cache."""
        cfg = self.cfg
        h = taps.site("layers.input", h, layer=layer)
        x = C.rms_norm(h, p["attn_norm"], cfg.norm_eps)
        if cfg.attn_kind == "mla":
            latent_tap = lambda v: taps.site("layers.attn.kv_latent", v, layer=layer)
            latent_new, k_rope_new = C.mla_latent(p["attn"], x, cfg, positions)
            latent_new = latent_tap(latent_new)
            latent = _write_rows(cache_l["latent"], slot, latent_new)
            k_rope = _write_rows(cache_l["k_rope"], slot, k_rope_new)
            # Absorbed-projection decode: attention runs in the compressed
            # latent space (§Perf H3) — the cache is never re-expanded.
            attn_out = C.mla_apply_absorbed(
                p["attn"], x, cfg, positions, latent, k_rope,
                kv_positions, window=window,
            )
            new_l = {"latent": latent, "k_rope": k_rope}
        else:
            q, k_new, v_new = C.gqa_project_qkv(p["attn"], x, cfg, positions)
            k = _write_rows(cache_l["k"], slot, k_new)
            v = _write_rows(cache_l["v"], slot, v_new)
            B = x.shape[0]
            out = C.attention(
                q, k, v, q_pos=positions, k_pos=kv_positions,
                causal=True, window=window, impl="dense",
            )
            attn_out = C.linear(p["attn"]["wo"], out.reshape(B, 1, -1))
            new_l = {"k": k, "v": v}
        attn_out = taps.site("layers.attn.output", attn_out, layer=layer)
        h = h + attn_out

        if cross is not None:
            cp, ck, cv, is_cross = cross
            xc = C.rms_norm(h, cp["norm"], cfg.norm_eps)
            B = xc.shape[0]
            q = C.linear(cp["attn"]["wq"], xc).reshape(B, 1, cfg.n_heads, cfg.hd)
            cpos = jnp.broadcast_to(jnp.arange(ck.shape[1]), (B, ck.shape[1]))
            cout = C.attention(
                q, ck, cv, q_pos=positions, k_pos=cpos, causal=False,
                impl="dense",
            )
            cout = C.linear(cp["attn"]["wo"], cout.reshape(B, 1, -1))
            cout = jnp.tanh(cp["gate"]).astype(cout.dtype) * cout
            cout = cout * jnp.asarray(is_cross, cout.dtype)
            cout = taps.site("layers.cross.output", cout, layer=layer)
            h = h + cout

        x = C.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.is_moe:
            router_tap = lambda v: taps.site("layers.mlp.router", v, layer=layer)
            mlp_out, aux = _moe(p["moe"], x, cfg, router_tap)
        else:
            mlp_out = C.swiglu_apply(p["mlp"], x)
        mlp_out = taps.site("layers.mlp.output", mlp_out, layer=layer)
        h = h + mlp_out
        h = taps.site("layers.output", h, layer=layer)
        return h, aux, new_l

    # ---------------------------------------------------------------- prefill
    def prefill(
        self, params: dict, batch: dict, *, mode: str = "scan",
        kind: str = "full", max_len: int | None = None,
    ) -> tuple[dict, KVCache]:
        """Full-sequence forward that also fills the KV cache.

        ``max_len`` reserves headroom for subsequent decode steps.
        ``batch["lengths"]`` (B,) marks per-row valid prefixes: padded slots
        get sentinel positions, so the cache they fill is never attended.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        lengths = batch.get("lengths")
        max_len = max_len or S
        cache = self.init_cache(B, max_len, kind=kind)
        # Build the cache by re-projecting K/V per layer (single pass).
        positions = C.valid_positions(lengths, B, S)
        h = params["embed"][tokens].astype(cfg.dtype)
        h = taps.site("embed", h)
        window = cfg.sliding_window if kind == "window" else None
        image_embeds = batch.get("image_embeds")

        if mode == "scan":
            # O(1)-compile path: reuse the scanned forward with KV collection.
            h, aux_total, data, cross = self._scan_layers(
                params, h, positions, window=window,
                image_embeds=image_embeds, collect=True,
            )
            h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
            h = taps.site("final_norm", h)
            logits = self._lm_head(params, h)
            logits = taps.site("logits", logits)
            data = dict(data)
            if self.is_vlm and cross is not None:
                data["cross_k"], data["cross_v"] = cross
            return {"logits": logits, "aux_loss": aux_total}, \
                self._assemble_cache(cache, data, positions, kind, B, S,
                                     lengths)

        aux_total = jnp.zeros((), jnp.float32)
        new_layers = []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            h = taps.site("layers.input", h, layer=i)
            x = C.rms_norm(h, p["attn_norm"], cfg.norm_eps)
            if cfg.attn_kind == "mla":
                latent, k_rope = C.mla_latent(p["attn"], x, cfg, positions)
                latent = taps.site("layers.attn.kv_latent", latent, layer=i)
                new_layers.append({"latent": latent, "k_rope": k_rope})
                attn_out = C.mla_apply(
                    p["attn"], x, cfg, positions,
                    cached=(latent, k_rope), kv_positions=positions,
                    window=window,
                )
            else:
                q, k_new, v_new = C.gqa_project_qkv(p["attn"], x, cfg, positions)
                new_layers.append({"k": k_new, "v": v_new})
                o = C.attention(
                    q, k_new, v_new, q_pos=positions, k_pos=positions,
                    causal=True, window=window,
                )
                attn_out = C.linear(p["attn"]["wo"], o.reshape(B, S, -1))
            attn_out = taps.site("layers.attn.output", attn_out, layer=i)
            h = h + attn_out
            cross_p = None
            if self.is_vlm and (i + 1) % cfg.cross_attn_every == 0:
                cross_p, cross_kv = self._cross_kv(
                    params, image_embeds, (i + 1) // cfg.cross_attn_every - 1
                )
                xc = C.rms_norm(h, cross_p["norm"], cfg.norm_eps)
                q = C.linear(cross_p["attn"]["wq"], xc).reshape(
                    B, S, cfg.n_heads, cfg.hd
                )
                ck, cv, cpos = cross_kv
                cout = C.attention(
                    q, ck, cv, q_pos=positions, k_pos=cpos, causal=False
                )
                cout = C.linear(cross_p["attn"]["wo"], cout.reshape(B, S, -1))
                cout = jnp.tanh(cross_p["gate"]).astype(cout.dtype) * cout
                cout = taps.site("layers.cross.output", cout, layer=i)
                h = h + cout
            x = C.rms_norm(h, p["mlp_norm"], cfg.norm_eps)
            if cfg.is_moe:
                router_tap = lambda v, i=i: taps.site(
                    "layers.mlp.router", v, layer=i
                )
                mlp_out, aux = _moe(p["moe"], x, cfg, router_tap)
                aux_total += aux
            else:
                mlp_out = C.swiglu_apply(p["mlp"], x)
            h = h + taps.site("layers.mlp.output", mlp_out, layer=i)
            h = taps.site("layers.output", h, layer=i)

        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = self._lm_head(params, h)
        logits = taps.site("logits", logits)

        data = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
        if self.is_vlm:
            n_cross = cfg.n_layers // cfg.cross_attn_every
            cks, cvs = [], []
            for ci in range(n_cross):
                cp = jax.tree.map(lambda a: a[ci], params["cross"])
                Ti = image_embeds.shape[1]
                cks.append(C.linear(cp["attn"]["wk"], image_embeds).reshape(
                    B, Ti, cfg.n_kv_heads, cfg.hd))
                cvs.append(C.linear(cp["attn"]["wv"], image_embeds).reshape(
                    B, Ti, cfg.n_kv_heads, cfg.hd))
            data["cross_k"] = jnp.stack(cks)
            data["cross_v"] = jnp.stack(cvs)
        return {"logits": logits, "aux_loss": aux_total}, \
            self._assemble_cache(cache, data, positions, kind, B, S, lengths)

    def _assemble_cache(self, cache, data, positions, kind, B, S,
                        lengths=None) -> KVCache:
        """Ring-align / pad freshly-collected K/V into the decode cache."""
        T = cache.positions.shape[1]
        cross = {k: v for k, v in data.items() if k.startswith("cross")}
        data = {k: v for k, v in data.items() if not k.startswith("cross")}
        if kind == "window" and S > T and lengths is not None:
            # the uniform last-T column crop would evict a SHORT row's real
            # keys that are still inside ITS window — gather per row instead
            data, kept = C.ring_align_ragged(data, positions, lengths, T)
        elif kind == "window" and S > T:
            # Ring alignment: position p must live at slot p % T so decode
            # writes (slot = pos % T) evict exactly the out-of-window key.
            data = jax.tree.map(
                lambda a: jnp.roll(a[:, :, -T:], S % T, axis=2), data
            )
            kept = jnp.roll(positions[:, -T:], S % T, axis=1)
        else:
            kept = positions
        if kept.shape[1] < T:
            pad = T - kept.shape[1]
            data = jax.tree.map(
                lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3)),
                data,
            )
            kept = jnp.pad(
                kept, ((0, 0), (0, pad)),
                constant_values=jnp.iinfo(jnp.int32).max // 2,
            )
        data.update(cross)
        written = (jnp.full((B,), S, jnp.int32) if lengths is None
                   else jnp.asarray(lengths, jnp.int32))
        return KVCache(cache.kind, data, kept, written)

    def cache_write_rows(self, table, rows, src: KVCache,
                         src_rows=None):
        """Scatter a freshly prefilled request's cache rows into the
        slot-table cache (continuous batching; see ``scatter_kv_rows``).
        Paged tables route through the page-granular scatter."""
        from repro.models.paged import PagedKVCache, paged_write_rows

        if isinstance(table, PagedKVCache):
            return paged_write_rows(table, rows, src, src_rows)
        return scatter_kv_rows(table, rows, src, src_rows)

    def cache_clear_rows(self, table, rows):
        """Reset retired slot rows so they can be reused with no recompile."""
        from repro.models.paged import PagedKVCache, paged_clear_rows

        if isinstance(table, PagedKVCache):
            return paged_clear_rows(table, rows)
        return clear_kv_rows(table, rows)

    def empty_cache(
        self, params: dict, batch: dict, batch_size: int, max_len: int,
        kind: str = "full",
    ) -> KVCache:
        """A decode-ready cache with NO prompt tokens written (the S == 1
        generation path decodes the whole prompt as step 0).  VLM cross K/V
        still come from the image embeddings."""
        cache = self.init_cache(batch_size, max_len, kind=kind)
        if self.is_vlm:
            n_cross = self.cfg.n_layers // self.cfg.cross_attn_every
            cks, cvs = [], []
            for ci in range(n_cross):
                _cp, (ck, cv, _pos) = self._cross_kv(
                    params, batch["image_embeds"], ci
                )
                cks.append(ck)
                cvs.append(cv)
            cache.data["cross_k"] = jnp.stack(cks)
            cache.data["cross_v"] = jnp.stack(cvs)
        return cache


def _moe(p, x, cfg, router_tap):
    """MoE dispatch selection: expert-parallel shard_map path under a mesh
    (§Perf H1 — the ragged/sort path replicates on SPMD), exact ragged-dot
    path otherwise (CPU tests, serving without a mesh).

    Tiny token counts (single-row decode) skip EP: the all-to-all round
    trips cost more than the (negligible) replicated compute — measured
    0.2x REGRESSION on long_500k before this guard (§Perf H1.8)."""
    from repro.distributed import active_mesh

    mesh = active_mesh()
    n_tokens = x.shape[0] * x.shape[1]
    if (mesh is not None and mesh.devices.size > 1
            and n_tokens >= cfg.n_experts):
        from repro.models.moe_ep import moe_apply_ep

        return moe_apply_ep(p, x, cfg, mesh, router_tap=router_tap)
    return C.moe_apply(p, x, cfg, router_tap=router_tap)


def _write_rows(arr: jax.Array, slot: jax.Array, new: jax.Array) -> jax.Array:
    """Write per-batch rows at per-batch slots. arr: (B, T, ...); new: (B, 1, ...)."""
    B = arr.shape[0]
    idx = (jnp.arange(B), slot)
    return arr.at[idx].set(new[:, 0] if new.ndim == arr.ndim else new)


# --------------------------------------------------- slot-table row helpers
# Continuous batching keeps ONE fixed-shape cache of `num_slots` batch rows
# alive across requests: a newly prefilled request's rows are scattered in
# (`cache_write_rows`), and a finished request's rows are reset
# (`cache_clear_rows`) so the slot can be reused without any shape change —
# and therefore without recompiling the decode step.

_SENTINEL_POS = jnp.iinfo(jnp.int32).max // 2


def _take_rows(a, rows, axis):
    return a if rows is None else jnp.take(a, jnp.asarray(rows), axis=axis)


def scatter_kv_rows(
    table: KVCache, rows, src: KVCache, src_rows=None,
    axis0_keys: tuple[str, ...] = (),
) -> KVCache:
    """Write ``src``'s batch rows (``src_rows``, default all) into ``table``
    at batch rows ``rows``.  Per-layer data leaves carry batch at axis 1;
    ``axis0_keys`` names data entries whose batch axis is 0 (e.g. the
    enc-dec ``cross_pos``).  Shapes outside the batch axis must match —
    the engine prefills admissions at the slot table's ``max_len``."""
    rows = jnp.asarray(rows)
    data = {}
    for k, v in table.data.items():
        if k in axis0_keys:
            data[k] = v.at[rows].set(_take_rows(src.data[k], src_rows, 0))
        else:
            data[k] = v.at[:, rows].set(_take_rows(src.data[k], src_rows, 1))
    return KVCache(
        table.kind,
        data,
        table.positions.at[rows].set(_take_rows(src.positions, src_rows, 0)),
        table.length.at[rows].set(_take_rows(src.length, src_rows, 0)),
    )


def clear_kv_rows(
    table: KVCache, rows, axis0_keys: tuple[str, ...] = ()
) -> KVCache:
    """Reset batch rows to the empty-slot state: zero data, sentinel
    positions (masked for every query), zero written length."""
    rows = jnp.asarray(rows)
    data = {}
    for k, v in table.data.items():
        if k in axis0_keys:
            data[k] = v.at[rows].set(
                _SENTINEL_POS if v.dtype == jnp.int32 else 0
            )
        else:
            data[k] = v.at[:, rows].set(0)
    return KVCache(
        table.kind,
        data,
        table.positions.at[rows].set(_SENTINEL_POS),
        table.length.at[rows].set(0),
    )
