"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block
[arXiv:2411.15242].

Every ``shared_attn_every`` Mamba2 layers, a *single shared* transformer
block (attention + MLP over the concat of the residual stream and the
original embedding, width 2·d) runs, with a distinct output projection per
application point — the Zamba parameter-sharing trick.  Interventions can
tap both the recurrent state (``layers.ssm_state``) and each shared-block
application (``shared.attn.output`` with layer = application index).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import taps
from repro.core.interleave import SiteSchedule
from repro.distributed import shard_hint
from repro.models import common as C
from repro.models.config import ModelConfig
from repro.models.transformer import KVCache, _write_rows

__all__ = ["Zamba2Model"]


class Zamba2Model:
    # prefill() runs a Python layer loop — generation traces tapping it must
    # be scheduled unrolled (repro.core.generation forces this).
    scan_prefill = False
    # ssm/conv state is fixed-size per row — dense under paging; only the
    # shared-attention-block K/V grow with decode and live in the pool
    paged_exclude_keys = ("ssm", "conv")
    cache_axis0_keys = ()

    def __init__(self, cfg: ModelConfig):
        assert cfg.shared_attn_every > 0
        self.cfg = cfg
        self.n_apps = cfg.n_layers // cfg.shared_attn_every

    def site_length_key(self, site: str) -> str | None:
        return None if site == "layers.ssm_state" else "tokens"

    @property
    def _d2(self) -> int:
        return 2 * self.cfg.d_model

    @property
    def _hd2(self) -> int:
        return self._d2 // self.cfg.n_heads

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        d2, hd2 = self._d2, self._hd2
        k_emb, k_layers, k_shared, k_out, k_proj = jax.random.split(key, 5)

        def layer_init(k):
            return {
                "norm": jnp.ones((cfg.d_model,), cfg.dtype),
                "mixer": C.mamba2_init(k, cfg),
            }

        layers = jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers))
        ks = jax.random.split(k_shared, 6)
        shared = {
            "attn_norm": jnp.ones((d2,), cfg.dtype),
            "wq": C.init_linear(ks[0], d2, cfg.n_heads * hd2, cfg.dtype),
            "wk": C.init_linear(ks[1], d2, cfg.n_kv_heads * hd2, cfg.dtype),
            "wv": C.init_linear(ks[2], d2, cfg.n_kv_heads * hd2, cfg.dtype),
            "wo": C.init_linear(ks[3], cfg.n_heads * hd2, d2, cfg.dtype),
            "mlp_norm": jnp.ones((d2,), cfg.dtype),
            "mlp": C.swiglu_init(ks[4], d2, cfg.d_ff, cfg.dtype),
        }
        out_proj = jax.vmap(
            lambda k: C.init_linear(k, d2, cfg.d_model, cfg.dtype)
        )(jax.random.split(k_proj, self.n_apps))
        return {
            "embed": (
                jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                * 0.02
            ).astype(cfg.dtype),
            "layers": layers,
            "shared": shared,
            "shared_out": out_proj,
            "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
            "lm_head": C.init_linear(k_out, cfg.d_model, cfg.vocab_size, cfg.dtype),
        }

    # -------------------------------------------------------------- schedule
    def site_schedule(self, mode: str = "unrolled") -> SiteSchedule:
        cfg = self.cfg
        mamba_sites = ["layers.input", "layers.ssm_state",
                       "layers.mixer.output", "layers.output"]
        shared_sites = ["shared.input", "shared.attn.output", "shared.output"]
        order: list[tuple[str, int | None]] = [("embed", None)]
        for i in range(cfg.n_layers):
            order += [(n, i) for n in mamba_sites]
            if (i + 1) % cfg.shared_attn_every == 0:
                g = (i + 1) // cfg.shared_attn_every - 1
                order += [(n, g) for n in shared_sites]
        order += [("final_norm", None), ("logits", None)]
        return SiteSchedule(
            order=order,
            scan_sites=tuple(mamba_sites + shared_sites) if mode == "scan" else (),
            n_layers=cfg.n_layers // cfg.shared_attn_every,
        )

    # ---------------------------------------------------------------- blocks
    def _mamba_layer(self, p, h, layer, lengths=None):
        cfg = self.cfg
        h = taps.site("layers.input", h, layer=layer)
        h = shard_hint(h, P(("pod", "data"), "model", None))
        x = C.rms_norm(h, p["norm"], cfg.norm_eps)
        state_tap = lambda v: taps.site("layers.ssm_state", v, layer=layer)
        out, state = C.mamba2_apply(p["mixer"], x, cfg, state_tap=state_tap,
                                    lengths=lengths)
        out = taps.site("layers.mixer.output", out, layer=layer)
        h = h + out
        return taps.site("layers.output", h, layer=layer), state

    def _shared_block(
        self, params, h, h0, g, positions, *,
        kv=None, kv_positions=None, window=None, slot=None, decode=False,
    ):
        """One application of the shared attention block.

        kv: cache (k, v) arrays (B,T,K,hd2) to update at `slot` (decode) or
        None (full-sequence self-attention).
        Returns (h, new_kv).
        """
        cfg = self.cfg
        d2, hd2 = self._d2, self._hd2
        sp = params["shared"]
        xcat = jnp.concatenate([h0, h], axis=-1)
        xcat = taps.site("shared.input", xcat, layer=g)
        x = C.rms_norm(xcat, sp["attn_norm"], cfg.norm_eps)
        B, S, _ = x.shape
        q = C.linear(sp["wq"], x).reshape(B, S, cfg.n_heads, hd2)
        k_new = C.linear(sp["wk"], x).reshape(B, S, cfg.n_kv_heads, hd2)
        v_new = C.linear(sp["wv"], x).reshape(B, S, cfg.n_kv_heads, hd2)
        q = C.rope(q, positions, cfg.rope_theta)
        k_new = C.rope(k_new, positions, cfg.rope_theta)
        if decode:
            k = _write_rows(kv[0], slot, k_new)
            v = _write_rows(kv[1], slot, v_new)
            k_pos = kv_positions
            new_kv = (k, v)
        else:
            k, v, k_pos = k_new, v_new, positions
            new_kv = (k_new, v_new)  # prefill collects these into the cache
        out = C.attention(
            q, k, v, q_pos=positions, k_pos=k_pos, causal=True, window=window,
            impl="dense" if decode else None,
        )
        out = C.linear(sp["wo"], out.reshape(B, S, -1))
        out = taps.site("shared.attn.output", out, layer=g)
        y = xcat + out
        x2 = C.rms_norm(y, sp["mlp_norm"], cfg.norm_eps)
        y = y + C.swiglu_apply(sp["mlp"], x2)
        op = jax.tree.map(lambda a: a[g], params["shared_out"])
        delta = C.linear(op, y)
        h = h + delta
        return taps.site("shared.output", h, layer=g), new_kv

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, *, mode: str = "scan",
                window: int | None = None, remat: bool = False) -> dict:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        lengths = batch.get("lengths")
        positions = C.valid_positions(lengths, B, S)
        h = params["embed"][tokens].astype(cfg.dtype)
        h = shard_hint(h, P(("pod", "data"), None, None))
        h = taps.site("embed", h)
        h0 = h
        k_every = cfg.shared_attn_every

        if mode == "unrolled":
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                h, _ = self._mamba_layer(p, h, i, lengths)
                if (i + 1) % k_every == 0:
                    g = (i + 1) // k_every - 1
                    h, _ = self._shared_block(
                        params, h, h0, g, positions, window=window
                    )
        else:
            grouped = jax.tree.map(
                lambda a: a.reshape((self.n_apps, k_every) + a.shape[1:]),
                params["layers"],
            )

            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                pg, g = inp
                for j in range(k_every):
                    p = jax.tree.map(lambda a: a[j], pg)
                    h, _ = self._mamba_layer(p, h, g * k_every + j, lengths)
                h, _ = self._shared_block(params, h, h0, g, positions,
                                          window=window)
                return (h, taps.scan_env_update(env_c)), taps.scan_outputs()

            if remat:
                body = jax.checkpoint(body)
            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (grouped, jnp.arange(self.n_apps)),
            )
            taps.deliver_scan(ys)

        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = shard_hint(logits, P(("pod", "data"), None, "model"))
        logits = taps.site("logits", logits)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int, kind: str = "full"):
        cfg = self.cfg
        T = min(max_len, cfg.sliding_window) if kind == "window" else max_len
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        big = jnp.iinfo(jnp.int32).max // 2
        data = {
            "ssm": jnp.zeros(
                (cfg.n_layers, batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                 cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros(
                (cfg.n_layers, batch_size, cfg.ssm_conv_width - 1, conv_ch),
                cfg.dtype),
            "k": jnp.zeros(
                (self.n_apps, batch_size, T, cfg.n_kv_heads, self._hd2),
                cfg.dtype),
            "v": jnp.zeros(
                (self.n_apps, batch_size, T, cfg.n_kv_heads, self._hd2),
                cfg.dtype),
        }
        return KVCache(
            kind, data,
            jnp.full((batch_size, T), big, jnp.int32),
            jnp.zeros((batch_size,), jnp.int32),
        )

    def prefill(self, params, batch, *, mode: str = "scan", kind="full",
                max_len=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        lengths = batch.get("lengths")
        max_len = max_len or S
        cache = self.init_cache(B, max_len, kind=kind)
        T = cache.positions.shape[1]
        positions = C.valid_positions(lengths, B, S)
        h = params["embed"][tokens].astype(cfg.dtype)
        k_every = cfg.shared_attn_every
        window = cfg.sliding_window if kind == "window" else None

        h = taps.site("embed", h)
        h0 = h
        ssm_states, conv_states, ks, vs = [], [], [], []
        for i in range(cfg.n_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            h, (s, c) = self._mamba_layer(p, h, i, lengths)
            ssm_states.append(s)
            conv_states.append(c)
            if (i + 1) % k_every == 0:
                g = (i + 1) // k_every - 1
                h, (k_new, v_new) = self._shared_block(
                    params, h, h0, g, positions, window=window
                )
                ks.append(k_new)
                vs.append(v_new)

        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = taps.site("logits", logits)

        k_arr, v_arr = jnp.stack(ks), jnp.stack(vs)
        if kind == "window" and S > T and lengths is not None:
            # see TransformerModel._assemble_cache: a uniform column crop
            # would evict a short row's still-in-window keys — per-row gather
            aligned, kept = C.ring_align_ragged(
                {"k": k_arr, "v": v_arr}, positions, lengths, T
            )
            k_arr, v_arr = aligned["k"], aligned["v"]
        elif kind == "window" and S > T:
            k_arr = jnp.roll(k_arr[:, :, -T:], S % T, axis=2)
            v_arr = jnp.roll(v_arr[:, :, -T:], S % T, axis=2)
            kept = jnp.roll(positions[:, -T:], S % T, axis=1)
        else:
            kept = positions
        if kept.shape[1] < T:
            pad = T - kept.shape[1]
            k_arr = jnp.pad(k_arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v_arr = jnp.pad(v_arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            kept = jnp.pad(kept, ((0, 0), (0, pad)),
                           constant_values=jnp.iinfo(jnp.int32).max // 2)
        written = (jnp.full((B,), S, jnp.int32) if lengths is None
                   else jnp.asarray(lengths, jnp.int32))
        cache = KVCache(
            kind,
            {"ssm": jnp.stack(ssm_states), "conv": jnp.stack(conv_states),
             "k": k_arr, "v": v_arr},
            kept, written,
        )
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}, cache

    def empty_cache(self, params, batch, batch_size, max_len, kind="full"):
        return self.init_cache(batch_size, max_len, kind=kind)

    def cache_write_rows(self, table, rows, src, src_rows=None):
        """Scatter prefilled rows (ssm state + conv tail + shared-block KV)
        into the slot table (continuous batching); all entries are (L|G, B, …)."""
        from repro.models.paged import PagedKVCache, paged_write_rows
        from repro.models.transformer import scatter_kv_rows

        if isinstance(table, PagedKVCache):
            return paged_write_rows(table, rows, src, src_rows)
        return scatter_kv_rows(table, rows, src, src_rows)

    def cache_clear_rows(self, table, rows):
        from repro.models.paged import PagedKVCache, paged_clear_rows
        from repro.models.transformer import clear_kv_rows

        if isinstance(table, PagedKVCache):
            return paged_clear_rows(table, rows)
        return clear_kv_rows(table, rows)

    def decode_step(self, params, cache, batch, *, mode: str = "scan"):
        from repro.models.paged import PagedKVCache, paged_decode_step

        if isinstance(cache, PagedKVCache):
            return paged_decode_step(self, params, cache, batch, mode=mode)
        cfg = self.cfg
        token, pos = batch["token"], batch["pos"]
        B = token.shape[0]
        positions = pos[:, None]
        kind = cache.kind
        window = cfg.sliding_window if kind == "window" else None
        T = cache.positions.shape[1]
        slot = pos % T if kind == "window" else pos
        new_positions = _write_rows(cache.positions, slot, pos[:, None])

        h = params["embed"][token].astype(cfg.dtype)
        h = taps.site("embed", h)
        h0 = h
        k_every = cfg.shared_attn_every

        def mamba_step(p, h, st, idx):
            h = taps.site("layers.input", h, layer=idx)
            x = C.rms_norm(h, p["norm"], cfg.norm_eps)
            state_tap = lambda v: taps.site("layers.ssm_state", v, layer=idx)
            out, new_st = C.mamba2_decode_step(p["mixer"], x, cfg, st,
                                               state_tap=state_tap)
            out = taps.site("layers.mixer.output", out, layer=idx)
            h = h + out
            return taps.site("layers.output", h, layer=idx), new_st

        if mode == "unrolled":
            new_ssm, new_conv = list(cache.data["ssm"]), list(cache.data["conv"])
            new_k, new_v = list(cache.data["k"]), list(cache.data["v"])
            for i in range(cfg.n_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                h, (s, c) = mamba_step(p, h, (cache.data["ssm"][i], cache.data["conv"][i]), i)
                new_ssm[i], new_conv[i] = s, c
                if (i + 1) % k_every == 0:
                    g = (i + 1) // k_every - 1
                    h, kv = self._shared_block(
                        params, h, h0, g, positions,
                        kv=(cache.data["k"][g], cache.data["v"][g]),
                        kv_positions=new_positions, window=window,
                        slot=slot, decode=True,
                    )
                    new_k[g], new_v[g] = kv
            new_cache = KVCache(
                kind,
                {"ssm": jnp.stack(new_ssm), "conv": jnp.stack(new_conv),
                 "k": jnp.stack(new_k), "v": jnp.stack(new_v)},
                new_positions, cache.length + 1,
            )
        else:
            grouped = jax.tree.map(
                lambda a: a.reshape((self.n_apps, k_every) + a.shape[1:]),
                params["layers"],
            )
            ssm_g = cache.data["ssm"].reshape((self.n_apps, k_every) + cache.data["ssm"].shape[1:])
            conv_g = cache.data["conv"].reshape((self.n_apps, k_every) + cache.data["conv"].shape[1:])

            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                pg, sg, cg, kg, vg, g = inp
                new_s, new_c = [], []
                for j in range(k_every):
                    p = jax.tree.map(lambda a: a[j], pg)
                    h, (s2, c2) = mamba_step(p, h, (sg[j], cg[j]), g * k_every + j)
                    new_s.append(s2)
                    new_c.append(c2)
                h, kv = self._shared_block(
                    params, h, h0, g, positions, kv=(kg, vg),
                    kv_positions=new_positions, window=window,
                    slot=slot, decode=True,
                )
                ys = {**taps.scan_outputs(),
                      "__s__": jnp.stack(new_s), "__c__": jnp.stack(new_c),
                      "__k__": kv[0], "__v__": kv[1]}
                return (h, taps.scan_env_update(env_c)), ys

            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (grouped, ssm_g, conv_g, cache.data["k"], cache.data["v"],
                 jnp.arange(self.n_apps)),
            )
            new_cache = KVCache(
                kind,
                {"ssm": ys.pop("__s__").reshape(cache.data["ssm"].shape),
                 "conv": ys.pop("__c__").reshape(cache.data["conv"].shape),
                 "k": ys.pop("__k__"), "v": ys.pop("__v__")},
                new_positions, cache.length + 1,
            )
            taps.deliver_scan(ys)

        h = C.rms_norm(h, params["final_norm"], cfg.norm_eps)
        h = taps.site("final_norm", h)
        logits = C.linear(params["lm_head"], h)
        logits = taps.site("logits", logits)
        return {"logits": logits, "aux_loss": jnp.zeros((), jnp.float32)}, new_cache
