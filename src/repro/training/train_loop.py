"""Training step + loop, with first-class intervention support.

``make_train_step`` builds the pure step function the launcher jits/shards.
The loss is next-token cross-entropy (+ MoE router aux).  Interventions
compose with training the same way they compose with inference: a graph can
be interleaved into the *forward* of a train step (e.g. ablate a head while
training a probe — paper Code Example 5/8 territory).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.graph import InterventionGraph
from repro.core.interleave import Interleaver, InterleaveState, SiteSchedule
from repro.training.optimizer import AdamWConfig, adamw

__all__ = ["loss_fn", "make_train_step", "train_loop"]


_XENT_CHUNK = 512


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token NLL, chunked over the sequence axis when the fp32
    softmax would be large (151k-vocab archs: full (B,S,V) fp32 log-softmax
    costs ~GBs of temps per device — §Perf H1.7)."""
    B, S, V = logits.shape
    # Chunk only for truly large vocabularies: at V~50k the scan overhead
    # costs more than the fp32 softmax saves (measured +7% on mamba2 train).
    if S <= _XENT_CHUNK or S % _XENT_CHUNK != 0 or V < 100_000:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0].mean()
    n = S // _XENT_CHUNK
    lg = jnp.moveaxis(logits.reshape(B, n, _XENT_CHUNK, V), 1, 0)
    lb = jnp.moveaxis(labels.reshape(B, n, _XENT_CHUNK), 1, 0)

    def body(acc, x):
        lgc, lbc = x
        logp = jax.nn.log_softmax(lgc.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lbc[..., None], axis=-1)[..., 0]
        return acc + nll.sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (lg, lb))
    return total / (B * S)


def loss_fn(
    model: Any, params: Any, batch: dict, *, mode: str = "scan",
    aux_weight: float = 0.01, remat: bool = False,
) -> tuple[jax.Array, dict]:
    out = model.forward(params, batch, mode=mode, remat=remat)
    labels = batch["labels"]
    nll = _xent(out["logits"], labels)
    loss = nll + aux_weight * out["aux_loss"]
    return loss, {"nll": nll, "aux": out["aux_loss"]}


def make_train_step(
    model: Any,
    opt_cfg: AdamWConfig | None = None,
    *,
    mode: str = "scan",
    graph: InterventionGraph | None = None,
    schedule: SiteSchedule | None = None,
) -> tuple[Callable, Callable]:
    """Returns (init_state, train_step).

    train_step(state, batch) -> (state, metrics).  Pure; jit/pjit-ready.
    If ``graph`` is given, it is interleaved into the forward pass.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    opt_init, opt_update = adamw(opt_cfg)

    def init_state(params):
        return {"params": params, "opt": opt_init(params)}

    plan = None
    if graph is not None:
        schedule_ = schedule or model.site_schedule(mode)
        plan = Interleaver(graph, schedule_, mode=mode)
        if plan.grad_nodes:
            raise ValueError(
                "training-time interleave supports forward interventions "
                "(.grad protocol inside train_step is redundant — the step "
                "already differentiates)"
            )

    def fwd_loss(params, batch):
        if plan is None:
            return loss_fn(model, params, batch, mode=mode)
        state = InterleaveState(plan)
        taps.push_state(state)
        try:
            loss, metrics = loss_fn(model, params, batch, mode=mode)
        finally:
            taps.pop_state()
        state.finalize(include_grad_dependents=True)
        metrics = dict(metrics)
        metrics["saves"] = state.saves()
        return loss, metrics

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(fwd_loss, has_aux=True)(
            state["params"], batch
        )
        new_params, new_opt, opt_metrics = opt_update(
            grads, state["opt"], state["params"]
        )
        out = {"loss": loss, **{k: v for k, v in metrics.items()}, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out

    return init_state, train_step


def train_loop(
    model: Any,
    params: Any,
    data_iter,
    steps: int,
    opt_cfg: AdamWConfig | None = None,
    *,
    mode: str = "scan",
    jit: bool = True,
    log_every: int = 10,
    callback: Callable[[int, dict], None] | None = None,
) -> tuple[Any, list[dict]]:
    init_state, step_fn = make_train_step(model, opt_cfg, mode=mode)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    state = init_state(params)
    history = []
    for i in range(steps):
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in metrics.items()
                   if hasattr(v, "item") or isinstance(v, (int, float))}
            rec["step"] = i
            history.append(rec)
            if callback:
                callback(i, rec)
    return state, history
