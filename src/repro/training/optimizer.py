"""AdamW + cosine schedule, implemented directly in JAX (no optax on box)."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw", "cosine_schedule", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(math.pi * t)
        )
        return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)

    return lr


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw(cfg: AdamWConfig):
    """Returns (init_fn, update_fn). Optimizer state in fp32 master copies."""
    schedule = cosine_schedule(cfg)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        b1, b2 = cfg.b1, cfg.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads,
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads,
        )
        lr = schedule(step)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"step": step, "mu": mu, "nu": nu}, {
            "lr": lr, "grad_norm": gnorm,
        }

    return init, update
