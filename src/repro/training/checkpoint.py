"""msgpack checkpointing for params/optimizer state (pytree <-> bytes).

Layout: a directory with ``manifest.json`` (tree structure + dtypes/shapes +
step metadata) and one ``arrays.msgpack`` blob.  Restores to host numpy; the
launcher re-device_puts against the mesh (resharding on restore is therefore
free — the checkpoint is sharding-agnostic, unlike raw per-device dumps).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import msgpack
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree: Any, step: int, extra: dict | None = None) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(x) for x in leaves]
    blob = msgpack.packb(
        [a.tobytes() for a in arrays], use_bin_type=True
    )
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    with open(os.path.join(ckpt_dir, "arrays.msgpack"), "wb") as f:
        f.write(blob)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "tree": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "leaves": [
            {"dtype": a.dtype.name, "shape": list(a.shape)} for a in arrays
        ],
        "extra": extra or {},
    }
    with open(os.path.join(ckpt_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return ckpt_dir


def load_checkpoint(path: str, step: int | None = None) -> tuple[Any, dict]:
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(ckpt_dir, "arrays.msgpack"), "rb") as f:
        raw = msgpack.unpackb(f.read(), raw=False)
    leaves = [
        np.frombuffer(buf, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"]).copy()
        for buf, meta in zip(raw, manifest["leaves"])
    ]
    treedef = jax.tree_util.tree_structure_from_proto_bytes(
        bytes.fromhex(manifest["tree"])
    ) if hasattr(jax.tree_util, "tree_structure_from_proto_bytes") else None
    if treedef is None:
        from jax.tree_util import PyTreeDef

        treedef = PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(manifest["tree"])
        )
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_") and os.path.isdir(os.path.join(path, d))
    ]
    return max(steps) if steps else None
