"""Roofline analysis from compiled dry-run artifacts (§Roofline).

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs   / (chips × 197 TFLOP/s bf16)
  memory     = HLO_bytes   / (chips × 819 GB/s HBM)
  collective = coll_bytes  / (chips × 50 GB/s ICI/link)

``cost_analysis()`` supplies FLOPs and bytes; collective bytes are NOT in
cost_analysis, so :func:`collective_bytes_from_hlo` parses the compiled HLO
text and sums operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Also reported: MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste).
"""
from __future__ import annotations

import re
from typing import Any

import numpy as np

__all__ = [
    "collective_bytes_from_hlo",
    "model_flops",
    "roofline_report",
    "HW",
]


class HW:
    PEAK_FLOPS_BF16 = 197e12
    HBM_BW = 819e9
    ICI_BW = 50e9
    HBM_BYTES = 16 * 1024**3


_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  "bf16[2,4096,512]{2,1,0}"  or  "f32[128]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an HLO instruction line:  %name = TYPE[...] op-name(...)  OR fused tuples
_INSTR_RE = re.compile(
    r"=\s*(\(?[\w\[\],{}\s/]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO module.

    Uses the *result* shape of each collective (the data that actually moves
    through the interconnect at least once).  ``-start``/``-done`` pairs are
    counted once (on the start op).
    """
    by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped:
            continue  # counted at -start
        m = _INSTR_RE.search(stripped)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        by_kind[kind] += _shape_bytes(type_str)
        counts[kind] += 1
    total = sum(by_kind.values())
    return {
        "total": int(total),
        "by_kind": {k: int(v) for k, v in by_kind.items() if v},
        "counts": {k: v for k, v in counts.items() if v},
    }


def model_flops(cfg: Any, shape: Any) -> float:
    """6·N·D with N = active params, D = tokens processed by the step."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens  # forward only
    # decode: one token per row.
    return 2.0 * n_active * shape.batch


def roofline_report(rec: dict, cfg: Any, shape: Any) -> dict:
    """rec carries PER-DEVICE flops/bytes (the SPMD module is one partition),
    so each term divides by a single chip's peak.  Equivalent to the spec's
    global-total/(chips × peak) formulation."""
    chips = rec["n_chips"]
    flops = rec["flops"]
    mem_bytes = rec["bytes_accessed"]
    coll = rec["collective_bytes"]

    t_compute = flops / HW.PEAK_FLOPS_BF16
    t_memory = mem_bytes / HW.HBM_BW
    t_coll = coll / HW.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": (mf_dev / flops) if flops else None,
        "bound_time_s": max(terms.values()),
        "mfu_bound": (mf_dev / HW.PEAK_FLOPS_BF16) / max(terms.values())
        if max(terms.values()) > 0 else None,
    }
