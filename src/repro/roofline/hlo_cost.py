"""HLO-text cost model with while-loop trip-count awareness.

``compiled.cost_analysis()`` counts each while (scan) body ONCE, which
undercounts a 36-layer scanned transformer by ~36× and — worse — miscounts
collectives issued inside the scan.  This module parses the compiled HLO
module text, builds the computation graph, and computes

    flops             (dots: 2·M·N·K; elementwise: 1/elem)
    bytes_accessed    (operands + result per top-level instruction; fusion
                       internals excluded — that is what fusion is for)
    collective_bytes  (result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute)

with every while body multiplied by its ``known_trip_count``.  Validated in
tests against hand-computed counts on small programs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$"
)
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# elementwise-ish ops that cost ~1 flop per output element
_EW_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "sign",
    "cosine", "sine", "and", "or", "xor", "not", "compare", "select",
    "clamp", "floor", "ceil", "round-nearest-afz", "exponential-minus-one",
    "log-plus-one", "atan2", "remainder",
}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dtype, dims in _SHAPE_TOKEN.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    rest: str
    operands: list[str]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    transcendentals: float = 0.0
    unknown_trip_whiles: int = 0

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        self.unknown_trip_whiles += other.unknown_trip_whiles
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = (
                self.collective_by_kind.get(k, 0.0) + v * mult
            )


def _parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, list[_Instr]] = {}
    entry = None
    current: list[_Instr] | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                comps[name] = []
                current = comps[name]
                if m.group(1):
                    entry = name
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operand names: inside the first balanced paren group only
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND.findall(rest[:end])
        current.append(_Instr(name, type_str, op, rest, operands))
    return comps, entry


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    _, out_elems = 1, _shape_elems_bytes(instr.type_str)[0]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m or not instr.operands:
        return 2.0 * out_elems  # fallback
    lhs_type = shapes.get(instr.operands[0], "")
    dims = _shape_dims(lhs_type)
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _comp_cost(
    name: str,
    comps: dict,
    cache: dict,
    *,
    fusion_internal: bool = False,
) -> HloCost:
    key = (name, fusion_internal)
    if key in cache:
        return cache[key]
    cost = HloCost()
    cache[key] = cost  # pre-insert (cycles impossible in HLO, but cheap)
    shapes = {i.name: i.type_str for i in comps[name]}
    for instr in comps[name]:
        op = instr.op
        _, out_bytes = _shape_elems_bytes(instr.type_str)
        out_elems = _shape_elems_bytes(instr.type_str)[0]
        if op in _ZERO_COST_OPS:
            continue
        if op == "while":
            body = _BODY.search(instr.rest)
            cond = _COND.search(instr.rest)
            trip_m = _TRIP.search(instr.rest)
            trips = int(trip_m.group(1)) if trip_m else 1
            if not trip_m:
                cost.unknown_trip_whiles += 1
            if body:
                cost.add(_comp_cost(body.group(1), comps, cache), trips)
            if cond:
                cost.add(_comp_cost(cond.group(1), comps, cache), trips)
            continue
        if op in ("call", "conditional", "async-start"):
            for cm in _CALLS.finditer(instr.rest):
                cost.add(_comp_cost(cm.group(1), comps, cache))
            continue
        # bytes: operands + result (skip for fusion internals)
        if not fusion_internal:
            operand_bytes = sum(
                _shape_elems_bytes(shapes.get(o, ""))[1] for o in instr.operands
            )
            cost.bytes_accessed += operand_bytes + out_bytes
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if op.endswith("-done"):
                continue
            cost.collective_bytes += out_bytes
            cost.collective_by_kind[base] = (
                cost.collective_by_kind.get(base, 0.0) + out_bytes
            )
            continue
        if op == "fusion":
            cm = _CALLS.search(instr.rest)
            if cm:
                inner = _comp_cost(
                    cm.group(1), comps, cache, fusion_internal=True
                )
                cost.flops += inner.flops
                cost.collective_bytes += inner.collective_bytes
                for k, v in inner.collective_by_kind.items():
                    cost.collective_by_kind[k] = (
                        cost.collective_by_kind.get(k, 0.0) + v
                    )
            continue
        if op in ("dot", "dot-general"):
            cost.flops += _dot_flops(instr, shapes)
            continue
        if op == "convolution":
            # rough: 2 * output elems * kernel elems (we use no big convs)
            cost.flops += 2.0 * out_elems
            continue
        if op in ("reduce", "reduce-window", "scatter", "map", "sort"):
            cm = _CALLS.search(instr.rest)
            cost.flops += out_elems  # ~1 op per output element
            if cm and comps.get(cm.group(1)):
                pass  # applied computations are tiny scalars; approximated
            continue
        if op in _EW_FLOP_OPS:
            cost.flops += out_elems
            if op in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                      "cosine", "sine", "power"):
                cost.transcendentals += out_elems
            continue
        # everything else (copy, broadcast, reshape, slice, dus, gather,
        # transpose, convert, pad, concatenate, ...) — bytes already counted.
    return cost


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    return _comp_cost(entry, comps, {})


def breakdown_hlo(text: str, top: int = 25) -> list[tuple[str, float, float]]:
    """Per-instruction byte/flop attribution with trip-count multipliers.

    Returns [(label, bytes, flops)] sorted by bytes — the 'profile' the perf
    loop reads on a no-hardware dry-run (op_name metadata gives the model
    source line)."""
    comps, entry = _parse_computations(text)
    rows: list[tuple[str, float, float]] = []

    def walk(name: str, mult: float) -> None:
        shapes = {i.name: i.type_str for i in comps[name]}
        for instr in comps[name]:
            op = instr.op
            if op in _ZERO_COST_OPS:
                continue
            if op == "while":
                body = _BODY.search(instr.rest)
                trip_m = _TRIP.search(instr.rest)
                trips = int(trip_m.group(1)) if trip_m else 1
                if body:
                    walk(body.group(1), mult * trips)
                continue
            if op in ("call", "conditional"):
                for cm in _CALLS.finditer(instr.rest):
                    walk(cm.group(1), mult)
                continue
            _, out_bytes = _shape_elems_bytes(instr.type_str)
            operand_bytes = sum(
                _shape_elems_bytes(shapes.get(o, ""))[1]
                for o in instr.operands
            )
            flops = 0.0
            if op in ("dot", "dot-general"):
                flops = _dot_flops(instr, shapes)
            elif op == "fusion":
                cm = _CALLS.search(instr.rest)
                if cm:
                    inner = _comp_cost(cm.group(1), comps, {},
                                       fusion_internal=True)
                    flops = inner.flops
            m = re.search(r'op_name="([^"]*)"', instr.rest)
            label = f"{op}:{m.group(1)[:90]}" if m else f"{op}:{instr.name}"
            rows.append((label, (operand_bytes + out_bytes) * mult,
                         flops * mult))

    walk(entry, 1.0)
    agg: dict[str, list[float]] = {}
    for label, b, f in rows:
        a = agg.setdefault(label, [0.0, 0.0])
        a[0] += b
        a[1] += f
    out = [(k, v[0], v[1]) for k, v in agg.items()]
    out.sort(key=lambda r: -r[1])
    return out[:top]
