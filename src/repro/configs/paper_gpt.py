"""Paper-native small decoder (GPT-2-ish) used by the paper-claims
benchmarks and examples — the models the paper itself intervenes on are
dense decoders (GPT2-XL, Llama-3.1-8B, OPT suite)."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-gpt-small",
    arch_type="dense",
    n_layers=8,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1024,
    vocab_size=2048,
    dtype=jnp.float32,
    rope_theta=10000.0,
    source="[paper §4: OPT/GPT2 family stand-in]",
)
