"""Qwen3-MoE-30B-A3B — 128 experts, top-8 routing. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    moe_d_ff=768,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    qk_norm=True,
    head_dim=128,
    rope_theta=1_000_000.0,
    source="[hf:Qwen/Qwen3-30B-A3B]",
)
