"""SeamlessM4T-Large-v2 backbone — encoder-decoder; audio frontend stubbed
(precomputed frame embeddings). [arXiv:2308.11596]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    n_source_frames=3750,  # ~5 minutes of audio after the conv frontend
    rope_theta=10000.0,
    source="[arXiv:2308.11596]",
)
