"""InternLM2-20B — dense GQA decoder. [arXiv:2403.17297]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    source="[arXiv:2403.17297]",
)
