"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    attn_kind="none",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,  # 9 shared-block applications over 54 layers
    source="[arXiv:2411.15242]",
)
