"""Llama-3.2-Vision-90B backbone — cross-attention image layers every 5th
layer; ViT frontend stubbed (precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,  # 20 cross-attention layers over 100 blocks
    n_image_tokens=1601,
    rope_theta=500_000.0,
    source="[hf:meta-llama/Llama-3.2-11B-Vision]",
)
