"""The NNsight-style user API: invoke-based tracing contexts, sessions, and
the Envoy tree (paper §3.2).

Single-invoke tracing mirrors the paper's Figure 3b — one prompt, one
intervention graph, executed on context exit::

    lm = TracedModel(model_fn, params, schedule, ...)
    with lm.trace(tokens) as tracer:
        lm.layers[16].mlp.output[:, -1, neurons] = 10.0
        out = lm.output.save()
    print(out.value)

Multi-invoke tracing is the paper's headline form (§3.2, Fig. 3a): several
prompts — each with its OWN interventions, and possibly ragged lengths —
declared inside one trace and lowered into ONE merged batched forward on
exit (shorter prompts are right-padded; getters are sliced back to each
invoke's rows and true lengths, setters are row-confined, exactly the
co-tenancy merge of :mod:`repro.core.batching`)::

    with lm.trace() as tr:
        with tr.invoke(tokens_a):                 # invoke 0
            lm.layers[3].mlp.output[:, -1] = 0.0
            a = lm.output.save("out")
        with tr.invoke(tokens_b):                 # invoke 1 (other length)
            b = lm.output.save("out")
    a.value, b.value                              # each at its solo shape

``tracer.stop()`` truncates execution after the last site the graph
references (nothing downstream can observe the difference, so the model
forward is abandoned there).  ``trace(tokens)`` is sugar for a one-invoke
trace and behaves exactly as before.

Sessions batch several traces into one request and allow FORWARD value
flow: a ``.save()``d proxy from trace *k* may be consumed inside trace
*k+1* (it is bound as a constant input when *k+1* executes — locally, or
server-side when the session ships as one remote request)::

    with lm.session() as sess:
        with sess.trace(tokens) as t1:
            acts = lm.layers[2].output.save("acts")
        with sess.trace(tokens) as t2:
            lm.layers[2].output = acts * 0.5      # value from t1
            out = lm.output.save("out")

Generation tracing interleaves interventions with a multi-token greedy
decode loop; models bound via :func:`repro.models.traced.traced_lm`
support both the single- and the multi-invoke form::

    with lm.generate(tokens, max_new_tokens=8) as tr:
        for s in tr.steps():                      # decode steps 0..7
            lm.layers[4].mlp.output += steer      # write THIS step
            lm.logits.save("logits")              # same name every step
    tr.result("logits")                           # stacked (B, 8, V)
    tr.output_tokens                              # (B, 8) generated ids

    with lm.generate() as tr:                     # multi-invoke form
        with tr.invoke(tokens_a, max_new_tokens=4) as ia:
            for s in tr.steps():
                lm.logits.save("logits")
        with tr.invoke(tokens_b, max_new_tokens=9) as ib:
            ...
    ia.output_tokens                              # (B_a, 4)

Multi-invoke generation admits each invoke as a row-group of one
continuous slot-table decode loop (:class:`repro.core.generation
.DecodeLoop`): invokes share every decode step while co-resident and
retire independently at their own ``max_new_tokens``.  ``tr.step(k)``
targets one chosen step, ``tr.all_steps()`` broadcasts a setter over every
decode step, and ``tr.prefill()`` taps the prompt forward; ``scan=True``
shape-checks prefill-step taps via ``jax.eval_shape`` without running the
model.  With ``remote=True`` any of these ship to the NDIF server as ONE
request (multi-invoke traces ship pre-merged; the server never re-merges
them with co-tenants).  See :mod:`repro.core.generation` for the execution
model.
"""
from __future__ import annotations

import contextlib
import linecache
import os
import sys
from collections import Counter
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.core.graph import (
    ALL_STEPS,
    PREFILL_STEP,
    SOURCE_META_KEY,
    GraphValidationError,
    InterventionGraph,
    Node,
)
from repro.core.interleave import (
    SiteSchedule,
    last_referenced_site,
    run_interleaved,
)
from repro.core.proxy import Proxy, make_op_caller, unwrap

__all__ = [
    "Tracer",
    "GenerateTracer",
    "Invoke",
    "Envoy",
    "TracedModel",
    "Session",
]


class Envoy:
    """Attribute-path access to tap sites, mirroring the module tree.

    Built from the model's declared site names: ``layers.mlp.output`` with
    per-layer flag yields ``lm.layers[5].mlp.output``.  ``dir()`` on an
    envoy lists the reachable child paths and sites.
    """

    def __init__(
        self,
        tracer: "Tracer",
        prefix: str,
        layer: int | None,
        site_names: set[str],
        per_layer_prefixes: set[str],
    ) -> None:
        object.__setattr__(self, "_tracer", tracer)
        object.__setattr__(self, "_prefix", prefix)
        object.__setattr__(self, "_layer", layer)
        object.__setattr__(self, "_site_names", site_names)
        object.__setattr__(self, "_per_layer_prefixes", per_layer_prefixes)

    def _child_path(self, name: str) -> str:
        return f"{self._prefix}.{name}" if self._prefix else name

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_"):
            raise AttributeError(name)
        path = self._child_path(name)
        if path in self._site_names:
            return self._tracer._tap_proxy(path, self._layer)
        if any(s == path or s.startswith(path + ".") for s in self._site_names):
            return Envoy(
                self._tracer,
                path,
                self._layer,
                self._site_names,
                self._per_layer_prefixes,
            )
        raise AttributeError(
            f"no tap site or module path {path!r}; "
            f"available here: {self.__dir__()}"
        )

    def __dir__(self) -> list[str]:
        """Reachable children: next path segments of every site below us."""
        prefix = self._prefix + "." if self._prefix else ""
        out = set()
        for s in self._site_names:
            if s.startswith(prefix) and s != self._prefix:
                out.add(s[len(prefix):].split(".")[0])
        return sorted(out)

    def __getitem__(self, layer: int) -> "Envoy":
        if self._prefix not in self._per_layer_prefixes:
            raise TypeError(f"{self._prefix!r} is not a layered module path")
        if not isinstance(layer, int):
            raise TypeError("layer index must be a concrete int")
        return Envoy(
            self._tracer,
            self._prefix,
            layer,
            self._site_names,
            self._per_layer_prefixes,
        )

    def __setattr__(self, name: str, value: Any) -> None:
        path = self._child_path(name)
        if path in self._site_names:
            self._tracer._write_back(path, self._layer, (), value)
            return
        raise AttributeError(f"cannot assign to non-site path {path!r}")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<Envoy {self._prefix!r} layer={self._layer}>"


class Invoke:
    """One prompt (plus its interventions) inside a multi-invoke trace.

    Context manager: nodes built while it is open are stamped with this
    invoke's id.  After the trace executes, per-invoke results are read
    back through :meth:`result` / :attr:`results` (and, for generation
    invokes, :attr:`output_tokens` / :attr:`output_logits`).
    """

    def __init__(
        self,
        tracer: "Tracer",
        index: int,
        args: tuple,
        kwargs: dict,
        max_new_tokens: int | None = None,
    ) -> None:
        self.tracer = tracer
        self.index = index
        self.args = args
        self.kwargs = kwargs
        self.max_new_tokens = max_new_tokens  # generation invokes only
        self._results: dict[str, Any] | None = None
        self.output_tokens: np.ndarray | None = None
        self.output_logits: Any | None = None
        self.logs: list = []

    @property
    def batch(self) -> dict:
        """This invoke's model inputs as a batch dict (first positional
        input under the conventional ``tokens`` key)."""
        return {
            "tokens": np.asarray(self.args[0]),
            **{k: np.asarray(v) for k, v in self.kwargs.items()},
        }

    # ------------------------------------------------------------- context
    def __enter__(self) -> "Invoke":
        t = self.tracer
        if t._invoke is not None:
            raise RuntimeError("invoke contexts cannot be nested")
        t._invoke = self.index
        t.graph.invoke_default = self.index
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t = self.tracer
        t._invoke = None
        t.graph.invoke_default = None

    # ------------------------------------------------------------- results
    def result(self, name: str) -> Any:
        if self._results is None:
            raise RuntimeError(
                "results are only available after the trace context exits"
            )
        try:
            return self._results[name]
        except KeyError:
            raise KeyError(
                f"invoke {self.index} has no save named {name!r}; "
                f"available: {sorted(self._results)}"
            ) from None

    @property
    def results(self) -> dict[str, Any]:
        if self._results is None:
            raise RuntimeError("trace has not executed yet")
        return dict(self._results)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<Invoke {self.index}>"


# The repro package root: frames inside it are tracer/proxy plumbing, the
# first frame OUTSIDE it is the user statement that created a node.
_PKG_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _stamp_sources(graph: InterventionGraph) -> None:
    """Wrap ``graph.add`` so every node records its user source line.

    The line lands in ``Node.meta[SOURCE_META_KEY]`` ("file.py:12: <code>"),
    survives slicing/merging/serialization (meta is copied everywhere), and
    is surfaced by preflight diagnostics — but is EXCLUDED from node
    fingerprints and structural keys (provenance is not structure)."""
    orig_add = graph.add

    def add(op: str, *args: Any, **kwargs: Any) -> Node:
        node = orig_add(op, *args, **kwargs)
        f = sys._getframe(1)
        for _ in range(32):
            if f is None:
                break
            fname = f.f_code.co_filename
            if not fname.startswith(_PKG_ROOT):
                line = linecache.getline(fname, f.f_lineno).strip()
                loc = f"{os.path.basename(fname)}:{f.f_lineno}"
                node.meta[SOURCE_META_KEY] = (
                    f"{loc}: {line}" if line else loc
                )
                break
            f = f.f_back
        return node

    graph.add = add  # type: ignore[method-assign]


class Tracer:
    """Builds one intervention graph inside a ``with`` block.

    Constructed with inputs (``lm.trace(tokens)``) it is a one-invoke
    trace; constructed bare (``lm.trace()``) prompts are declared through
    :meth:`invoke` sub-contexts and lowered into one merged forward on
    exit.
    """

    def __init__(
        self,
        model: "TracedModel",
        model_args: tuple,
        model_kwargs: dict,
        *,
        remote: bool = False,
        scan: bool = False,
        mode: str | None = None,
        backend: Any | None = None,
        graph: InterventionGraph | None = None,
    ) -> None:
        self.model = model
        self.model_args = model_args
        self.model_kwargs = model_kwargs
        self.remote = remote
        self.scan = scan
        self.mode = mode or model.default_mode
        self.backend = backend
        self.graph = graph if graph is not None else InterventionGraph()
        _stamp_sources(self.graph)
        self._results: dict[str, Any] | None = None
        self._saved_proxies: dict[str, Proxy] = {}
        # Generation-step pointer: None for single-forward traces; the
        # GenerateTracer subclass moves it so taps are stamped per step.
        self._step: int | None = None
        # Multi-invoke state: the open invoke's index (None outside invoke
        # contexts) and the declared invokes in order.
        self._invoke: int | None = None
        self.invokes: list[Invoke] = []
        self._inputs_fixed = len(model_args) > 1  # trace(tokens) form
        self._current: dict[tuple, Node] = {}
        self._deferred = False  # True when owned by a Session
        self._session: "Session | None" = None
        self._stop = False
        # Cross-trace inputs (session value flow): input name ->
        # (source tracer, save name); values bound at execution time.
        self._cross_inputs: dict[str, tuple["Tracer", str]] = {}
        self._cross_nodes: dict[str, Node] = {}
        self._input_values: dict[str, Any] = {}
        # Lowered (merged) form of a multi-invoke trace, built on exit.
        self._merged = None  # MergedBatch
        self._merged_input_map: dict[str, str] = {}
        self._scan_pending = False  # scan=True deferred past input binding
        self.logs: list[tuple[int, Any]] = []
        # Static preflight report (repro.core.analysis), set at trace exit.
        self.preflight_report: Any | None = None

    # ------------------------------------------------------------- plumbing
    def _tap_proxy(self, site: str, layer: int | None) -> Proxy:
        key = (site, layer, self._step, self._invoke)
        if key not in self._current:
            node = self.graph.add(
                "tap_get", site=site, layer=layer, step=self._step
            )
            self._current[key] = node
        node = self._current[key]
        return Proxy(self, node, root_site=site, root_layer=layer)

    def _write_back(
        self, site: str, layer: int | None, path: tuple, value: Any
    ) -> None:
        value = self._adopt(value)
        key = (site, layer, self._step, self._invoke)
        if path:
            current = self._current.get(key)
            if current is None:
                current = self.graph.add(
                    "tap_get", site=site, layer=layer, step=self._step
                )
                self._current[key] = current
            new = self.graph.add(
                "update_path", _ref(current), path, unwrap(value)
            )
        else:
            new = _as_node(self, value)
        self.graph.add(
            "tap_set", _ref(new), site=site, layer=layer, step=self._step
        )
        self._current[key] = new

    def _register_save(self, name: str, proxy: Proxy) -> str:
        if self._invoke is not None:
            # qualify: every invoke may reuse the same user-facing name
            nid = self.graph.saves.pop(name)
            name = f"i{self._invoke}/{name}"
            self.graph.saves[name] = nid
        self._saved_proxies[name] = proxy
        return name

    # ----------------------------------------------------- session bridging
    def _target(self) -> "Tracer":
        """The tracer new nodes should append to.

        Normally ``self``; when a proxy from an EARLIER session trace is
        used while a LATER trace of the same session is active, nodes go to
        the active trace (cross-trace value flow)."""
        active = self.model._tracers[-1] if self.model._tracers else None
        if (
            active is not None
            and active is not self
            and self._session is not None
            and active._session is self._session
        ):
            return active
        return self

    def _adopt(self, obj: Any) -> Any:
        """Map proxies owned by other tracers into this graph (bridged as
        cross-trace inputs); containers handled structurally."""
        if isinstance(obj, Proxy):
            if obj._tracer is self:
                return obj
            return self._bridge(obj)
        if isinstance(obj, tuple):
            return tuple(self._adopt(o) for o in obj)
        if isinstance(obj, list):
            return [self._adopt(o) for o in obj]
        if isinstance(obj, dict):
            return {k: self._adopt(v) for k, v in obj.items()}
        return obj

    def _bridge(self, proxy: Proxy) -> Proxy:
        src = proxy._tracer
        if self._session is None or src._session is not self._session:
            raise GraphValidationError(
                "a proxy may only be used inside the trace that created it, "
                "or inside a LATER trace of the same session"
            )
        name = getattr(proxy, "_save_name", None)
        if name is None:
            raise GraphValidationError(
                "only .save()d values may flow across session traces; call "
                ".save(name) in the producing trace first"
            )
        src_idx = self._session.tracers.index(src)
        my_idx = self._session.tracers.index(self)
        if src_idx >= my_idx:
            raise GraphValidationError(
                "cross-trace values only flow FORWARD: trace "
                f"{src_idx} cannot feed trace {my_idx}"
            )
        key = f"__xtrace{src_idx}/{name}"
        if key not in self._cross_nodes:
            node = self.graph.add("input", key)
            # invoke-free: replicated into whichever invoke(s) consume it
            node.invoke = None
            self._cross_nodes[key] = node
            self._cross_inputs[key] = (src, name)
        return Proxy(self, self._cross_nodes[key])

    # ------------------------------------------------------------ protocols
    def apply(self, op_name: str) -> Callable[..., Proxy]:
        """Call a registry op on proxies (the paper's ``nnsight.apply``)."""
        return make_op_caller(self, op_name)

    def constant(self, value: Any) -> Proxy:
        value = np.asarray(value) if not np.isscalar(value) else value
        return Proxy(self, self.graph.add("constant", value))

    def input(self, name: str) -> Proxy:
        """A named experiment input, bound at execution time."""
        return Proxy(self, self.graph.add("input", name))

    def backward(self, loss: Proxy) -> None:
        """Declare the scalar loss for the backward pass (GradProtocol)."""
        self.graph.backward_loss = loss.node.id

    def log(self, value: Any) -> None:
        node = _as_node(self, self._adopt(value))
        self.graph.add("log", _ref(node))

    # --------------------------------------------------------------- invoke
    def invoke(self, *args: Any, **kwargs: Any) -> Invoke:
        """Declare one prompt of a multi-invoke trace (paper Fig. 3a).

        ``args`` is the prompt input (tokens); ``kwargs`` are extra model
        inputs.  Prompts may have different lengths — shorter ones are
        right-padded into the merged forward and results are returned at
        each invoke's true solo shape.
        """
        if self._inputs_fixed:
            raise RuntimeError(
                "this trace was given inputs directly; use "
                "`with model.trace() as tr:` (no inputs) for the "
                "multi-invoke form"
            )
        if len(args) != 1:
            raise TypeError(
                "invoke() takes exactly one positional input (the tokens); "
                "extra model inputs go as keywords"
            )
        inv = Invoke(self, len(self.invokes), args, kwargs)
        self.invokes.append(inv)
        return inv

    def stop(self) -> None:
        """Truncate execution after the LAST site this graph references.

        Model computation past that site cannot affect any getter, setter,
        or save, so the forward is abandoned there (the paper's early-stop:
        pay only for the layers you use).  The truncation happens BEFORE
        lowering, so the partial program compiles; ``.grad`` composes with
        it — the perturbation driver differentiates the truncated forward
        (every grad site is referenced, so it fires before the stop)."""
        self._stop = True

    # -------------------------------------------------------------- results
    def result(self, name: str) -> Any:
        if self._results is None:
            raise RuntimeError(
                "results are only available after the trace context exits"
            )
        try:
            return self._results[name]
        except KeyError:
            raise KeyError(
                f"no save named {name!r}; available: {sorted(self._results)}"
            ) from None

    @property
    def results(self) -> dict[str, Any]:
        if self._results is None:
            raise RuntimeError("trace has not executed yet")
        return dict(self._results)

    # ------------------------------------------------------------- context
    def __enter__(self) -> "Tracer":
        self.model._push_tracer(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.model._pop_tracer()
        if exc_type is not None:
            return
        if not self._inputs_fixed and not self.invokes:
            raise GraphValidationError(
                "trace() without inputs expects invoke() sub-contexts: "
                "declare prompts with `with tr.invoke(tokens):`"
            )
        if self.scan:
            if self._deferred and self._cross_inputs:
                # cross-trace inputs have no values (their producers have
                # not run); the session validates right before execution
                self._scan_pending = True
            else:
                self.validate_shapes()
        self.preflight()
        if self._deferred:
            return
        self.execute()

    # ------------------------------------------------------------ preflight
    def preflight(self) -> Any:
        """Static preflight (layer 1 of 4: trace exit) — zero model FLOPs.

        Structural facts (ops, sites, dead nodes) always check; shape
        facts check when abstract site avals can be captured via
        ``jax.eval_shape`` of the model (cached per batch signature on the
        model).  In enforcing mode (``REPRO_PREFLIGHT=enforce``, the
        default) definite errors raise
        :class:`repro.core.analysis.PreflightError` before anything
        executes or ships."""
        from repro.core import analysis

        mode = analysis.preflight_mode()
        if mode == "off":
            return None
        graph = self.execution_graph()
        site_order = list(self.model.schedule.order)
        site_avals = input_avals = None
        # Shape facts need one abstract model evaluation, which replays any
        # host-side effects in the model fn (counters, callbacks) — so the
        # tracer layer captures them only for scan=True traces, where the
        # user already opted into abstract evaluation.  Plain traces get
        # the structural lint here and full shape checking at the serving
        # layers (engine/scheduler admission), whose model fns are pure.
        if self.scan:
            try:
                cache = self.model.__dict__.setdefault(
                    "_preflight_avals", {}
                )
                key = analysis.aval_signature(
                    self.model_args, self.model_kwargs
                )
                site_avals = cache.get(key)
                if site_avals is None:
                    site_avals = analysis.capture_forward_avals(
                        self.model.wrapped_fn,
                        self.model_args,
                        self.model_kwargs,
                    )
                    cache[key] = site_avals
                inputs = self._execution_inputs() or {}
                input_avals = {
                    k: jax.eval_shape(lambda x: x, v)
                    for k, v in inputs.items()
                    if v is not None
                }
            except Exception:
                # model facts unavailable (abstract-params client, unbound
                # cross-trace inputs): structural lint only
                site_avals = input_avals = None
        report = analysis.analyze(
            graph,
            site_order=site_order,
            site_avals=site_avals,
            input_avals=input_avals,
        )
        self.preflight_report = report
        report.enforce(mode)
        return report

    # ------------------------------------------------------------- lowering
    def _lower(self) -> None:
        """Lower a multi-invoke trace: split the invoke-stamped graph into
        per-invoke graphs and merge them (plus the right-padded inputs)
        into ONE batched execution.  Idempotent."""
        from repro.core.batching import (
            merge_graphs,
            merge_invoke_batches,
            split_invokes,
        )

        if self._merged is not None:
            return
        graphs = split_invokes(self.graph, len(self.invokes))
        batch, tap_lengths, sizes, real, padded = merge_invoke_batches(
            [inv.batch for inv in self.invokes]
        )
        zoo = self.model.zoo_model
        self._merged = merge_graphs(
            graphs,
            sizes,
            lengths=tap_lengths,
            site_length_key=getattr(zoo, "site_length_key", None),
        )
        self.pad_stats = {"real_cells": real, "padded_cells": padded}
        tokens = batch.pop("tokens")
        # after lowering the tracer looks like an ordinary padded batched
        # trace: (params, tokens) + extras (incl. synthesized lengths)
        self.model_args = (self.model.params, jax.numpy.asarray(tokens))
        self.model_kwargs = batch
        self._merged_input_map = {}
        for g, prefix in zip(graphs, self._merged.save_prefixes):
            for n in g.nodes:
                if n.op == "input":
                    self._merged_input_map[f"{prefix}/{n.args[0]}"] = (
                        n.args[0]
                    )

    def execution_graph(self) -> InterventionGraph:
        """The graph actually executed/shipped: the lowered merged graph
        for multi-invoke traces, the user graph otherwise."""
        if self.invokes:
            self._lower()
            return self._merged.graph
        return self.graph

    def _bind_cross_inputs(self) -> None:
        """Pull cross-trace values from source traces (session exit)."""
        for key, (src, name) in self._cross_inputs.items():
            self._input_values[key] = src.result(name)

    def _execution_inputs(self) -> dict[str, Any] | None:
        if self.invokes:
            out = {
                merged: self._input_values[orig]
                for merged, orig in self._merged_input_map.items()
                if orig in self._input_values
            }
            return out or None
        return self._input_values or None

    def _finish_invoke_results(self, per: list[dict[str, Any]]) -> dict:
        flat: dict[str, Any] = {}
        counts: Counter = Counter()
        for inv, res in zip(self.invokes, per):
            inv._results = dict(res)
            for name, val in res.items():
                flat[f"i{inv.index}/{name}"] = val
                counts[name] += 1
        # unqualified aliases where the name is unique across invokes
        for inv in self.invokes:
            for name, val in inv._results.items():
                if counts[name] == 1:
                    flat.setdefault(name, val)
        self._results = flat
        return flat

    # ------------------------------------------------------------ execution
    def validate_shapes(self) -> None:
        """The paper's FakeTensor scan: eval_shape the interleaved program."""
        graph = self.execution_graph()
        jax.eval_shape(
            lambda a, k, i: run_interleaved(
                self.model.wrapped_fn,
                graph,
                self.model.schedule,
                a,
                k,
                mode=self.mode,
                inputs=i,
            ),
            self.model_args,
            self.model_kwargs,
            self._execution_inputs(),
        )

    def _stop_site(self, graph: InterventionGraph) -> int | None:
        if not self._stop:
            return None
        return last_referenced_site(graph, self.model.schedule)

    def execute(self) -> dict[str, Any]:
        from repro.core.batching import split_results

        if self.remote:
            backend = self.backend or self.model.backend
            if backend is None:
                raise RuntimeError(
                    "remote=True requires a backend (NDIF client); pass "
                    "backend= or attach one to the model"
                )
            if self.invokes:
                self._lower()
                raw = backend.execute(self)
                # reserved logs key travels OUTSIDE the per-invoke save
                # namespace: pop before the prefix-keyed split, attribute
                # per invoke by merged node-id segment
                logs = raw.pop("__logs__", None) if isinstance(raw, dict) \
                    else None
                if logs:
                    self.logs = [(int(n), v) for n, v in logs]
                    for k, inv in enumerate(self.invokes):
                        inv.logs = [
                            e for e in self.logs
                            if self._merged.owner_of(e[0]) == k
                        ]
                return self._finish_invoke_results(
                    split_results(raw, self._merged)
                )
            self._results = backend.execute(self)
            if isinstance(self._results, dict):
                logs = self._results.pop("__logs__", None)
                if logs:
                    self.logs = [(int(n), v) for n, v in logs]
            return self._results
        if self._scan_pending:
            self._scan_pending = False
            self.validate_shapes()  # cross-trace inputs are bound now
        graph = self.execution_graph()
        graph.validate(self.model.schedule.order)
        out, saves, logs = run_interleaved(
            self.model.wrapped_fn,
            graph,
            self.model.schedule,
            self.model_args,
            self.model_kwargs,
            mode=self.mode,
            inputs=self._execution_inputs(),
            stop_after_site=self._stop_site(graph),
        )
        self.logs = logs
        if self.invokes:
            return self._finish_invoke_results(
                split_results(saves, self._merged)
            )
        self._results = saves
        return saves


class GenerateTracer(Tracer):
    """Builds a step-annotated graph over a multi-token decode loop.

    Tap nodes are stamped with the *current step* — decode step ``0`` by
    default; move the pointer with :meth:`steps` (iterate all), :meth:`step`
    (one chosen step), :meth:`all_steps` (broadcast setters), or
    :meth:`prefill` (the prompt forward).  ``.save(name)`` at several steps
    under one name yields per-step values stacked along the token axis.

    The multi-invoke form (``lm.generate()`` with no tokens) declares
    prompts via ``tr.invoke(tokens, max_new_tokens=N)``; every invoke is
    admitted as a row-group of ONE continuous slot-table decode loop
    (:class:`repro.core.generation.DecodeLoop`) and retires independently
    at its own ``max_new_tokens``.
    """

    def __init__(
        self,
        model: "TracedModel",
        tokens: Any,
        max_new_tokens: int,
        *,
        mode: str | None = None,
        extras: dict | None = None,
        remote: bool = False,
        scan: bool = False,
        backend: Any | None = None,
    ) -> None:
        args = (tokens,) if tokens is not None else ()
        super().__init__(model, args, dict(extras or {}), mode=mode,
                         remote=remote, scan=scan, backend=backend)
        self.tokens = tokens
        self.max_new_tokens = int(max_new_tokens)
        self._inputs_fixed = tokens is not None
        self._step: int = 0
        # base save name -> {step -> wire save name}; base names carry the
        # ``i{k}/`` invoke qualifier in multi-invoke traces
        self._step_save_names: dict[str, dict[int, str]] = {}
        self.output_tokens: np.ndarray | None = None
        self.output_logits: Any | None = None
        # Step-uniformity mark, stamped when the trace context exits (before
        # execution): True when the whole decode loop can run as ONE fused
        # lax.scan program (a list, one flag per invoke, for multi-invoke
        # traces; None if the graph failed step validation — the execution
        # path raises the real error).
        self.steps_uniform: bool | list[bool] | None = None

    # ----------------------------------------------------------------- form
    def invoke(self, *args: Any, max_new_tokens: int | None = None,
               **kwargs: Any) -> Invoke:
        """Declare one prompt of a multi-invoke generation trace.

        ``max_new_tokens`` may differ per invoke — every invoke is a
        row-group of one shared decode loop and retires independently."""
        inv = super().invoke(*args, **kwargs)
        inv.max_new_tokens = int(
            max_new_tokens if max_new_tokens is not None
            else self.max_new_tokens
        )
        return inv

    def stop(self) -> None:  # pragma: no cover - guard
        raise NotImplementedError(
            "stop() is not supported inside generation traces; bound the "
            "decode loop with max_new_tokens instead"
        )

    def _active_n(self) -> int:
        if self._invoke is not None:
            return self.invokes[self._invoke].max_new_tokens
        return self.max_new_tokens

    # ------------------------------------------------------- step pointer
    def steps(self, start: int = 0, stop: int | None = None) -> Iterator[int]:
        """Iterate decode steps, moving the tap pointer to each in turn.

        Inside an invoke context the default ``stop`` is that invoke's own
        ``max_new_tokens``."""
        stop = self._active_n() if stop is None else stop
        prev = self._step
        try:
            for s in range(start, stop):
                self._step = s
                yield s
        finally:
            # restore the enclosing pointer even on early break — a loop
            # nested in step()/prefill() must not leak its last step
            self._step = prev

    @contextlib.contextmanager
    def step(self, s: int):
        """Target one chosen decode step (0-based)."""
        prev, self._step = self._step, int(s)
        try:
            yield self
        finally:
            self._step = prev

    @contextlib.contextmanager
    def all_steps(self):
        """Broadcast over every decode step.

        Read-modify-write chains (``site += delta``) are replicated into
        each step; only *saving* a broadcast value is rejected (ambiguous
        step) — iterate :meth:`steps` to collect per-step values.
        """
        prev, self._step = self._step, ALL_STEPS
        try:
            yield self
        finally:
            self._step = prev

    @contextlib.contextmanager
    def prefill(self):
        """Tap the prompt-prefill forward (full prompt-length shapes)."""
        prev, self._step = self._step, PREFILL_STEP
        try:
            yield self
        finally:
            self._step = prev

    # ------------------------------------------------------ stacked saves
    def _register_save(self, name: str, proxy: Proxy) -> str:
        base = f"i{self._invoke}/{name}" if self._invoke is not None else name
        by_step = self._step_save_names.setdefault(base, {})
        mixed = (self._step == PREFILL_STEP and any(
            s != PREFILL_STEP for s in by_step
        )) or (self._step != PREFILL_STEP and PREFILL_STEP in by_step)
        if mixed:
            raise GraphValidationError(
                f"save {name!r} mixes prefill() and decode-step values; "
                "prefill shapes are prompt-length and cannot stack with "
                "per-step values — use a different name for the prefill "
                "save"
            )
        nid = self.graph.saves.pop(name)
        wire = f"{base}@step{self._step}"
        self.graph.saves[wire] = nid
        by_step[self._step] = wire
        self._saved_proxies[base] = proxy
        return base

    # ---------------------------------------------------------- validation
    def validate_shapes(self) -> None:
        """``scan=True``: shape-check prefill-step taps via ``jax.eval_shape``
        (the paper's FakeTensor scanning), without running the model.

        Decode-step slices are additionally validated against the
        per-execution site schedule; their shapes are fixed ``(B, 1, ...)``
        singletons, so the prefill forward is where shape errors hide.
        """
        from repro.core.batching import split_invokes
        from repro.core.generation import _step_order, slice_steps

        zoo = self.model.zoo_model
        if zoo is None:
            raise RuntimeError(
                "scan=True generation validation requires a model bound "
                "via traced_lm (needs prefill)"
            )
        if self.invokes:
            items = [
                (g, inv.batch, inv.max_new_tokens)
                for g, inv in zip(
                    split_invokes(self.graph, len(self.invokes)),
                    self.invokes,
                )
            ]
        else:
            batch = {"tokens": np.asarray(self.tokens),
                     **{k: np.asarray(v)
                        for k, v in self.model_kwargs.items()}}
            items = [(self.graph, batch, self.max_new_tokens)]
        step_sched = _step_order(zoo.site_schedule(self.mode))
        for graph, batch, n_new in items:
            slices = slice_steps(graph, n_new)  # step-rule validation
            for step, sl in slices.items():
                if step != PREFILL_STEP and not sl.is_empty():
                    sl.graph.validate(step_sched.order)
            pre = slices.get(PREFILL_STEP)
            if pre is None or pre.is_empty():
                continue
            tokens = jax.numpy.asarray(batch.pop("tokens"))
            if tokens.shape[1] < 2:
                raise GraphValidationError(
                    "prefill() taps require a prompt of >= 2 tokens; a "
                    "single-token prompt has no prefill execution"
                )
            pre_mode = self.mode
            pre_sched = step_sched
            if pre_mode == "scan" and not getattr(zoo, "scan_prefill", True):
                pre_mode = "unrolled"
                pre_sched = _step_order(zoo.site_schedule("unrolled"))
            batch.pop("lengths", None)
            prompt = {"tokens": tokens[:, :-1], **batch}
            max_len = int(tokens.shape[1]) - 1 + n_new

            def pre_fn(params_, batch_):
                return zoo.prefill(
                    params_, batch_, mode=pre_mode, max_len=max_len
                )

            jax.eval_shape(
                lambda p, b: run_interleaved(
                    pre_fn, pre.graph, pre_sched, (p, b), {}, mode=pre_mode,
                ),
                self.model.params,
                prompt,
            )

    # ------------------------------------------------------------ preflight
    def preflight(self) -> Any:
        """Generation preflight: step-flow + per-execution shape facts.

        Prefill taps check against ``(B, S-1, ...)`` prompt avals, decode
        taps against ``(B, 1, ...)`` step avals — both captured with
        ``jax.eval_shape`` of ``prefill``/``decode_step`` (zero FLOPs,
        cached per batch signature).  Multi-invoke traces analyze each
        per-invoke graph against its own batch and horizon."""
        from repro.core import analysis
        from repro.core.generation import _step_order

        mode = analysis.preflight_mode()
        if mode == "off":
            return None
        zoo = self.model.zoo_model
        if zoo is None:
            return None  # plain TracedModel: execute() raises its own error
        sched = _step_order(zoo.site_schedule(self.mode))
        step_order = list(sched.order)
        if self.invokes:
            from repro.core.batching import split_invokes

            graphs = split_invokes(self.graph, len(self.invokes))
            items = [
                (g, inv.batch, inv.max_new_tokens)
                for g, inv in zip(graphs, self.invokes)
            ]
        else:
            batch = {
                "tokens": np.asarray(self.tokens),
                **{k: np.asarray(v) for k, v in self.model_kwargs.items()},
            }
            items = [(self.graph, batch, self.max_new_tokens)]
        cache = self.model.__dict__.setdefault("_preflight_gen_avals", {})
        report = None
        for graph, batch, n_new in items:
            pre_avals = dec_avals = None
            try:
                tokens = np.asarray(batch["tokens"])
                # runtime prefills on the prompt minus its last token and
                # decodes from there — mirror that split for the avals
                cap = dict(batch)
                if tokens.shape[1] > 1:
                    cap["tokens"] = tokens[:, :-1]
                max_len = int(cap["tokens"].shape[1]) + int(n_new)
                key = (
                    analysis.aval_signature(cap),
                    int(n_new),
                    self.mode,
                )
                if key in cache:
                    pre_avals, dec_avals = cache[key]
                else:
                    pre_avals, dec_avals = analysis.capture_generation_avals(
                        zoo, self.model.params, cap,
                        max_len=max_len, mode=self.mode,
                    )
                    cache[key] = (pre_avals, dec_avals)
            except Exception:
                pre_avals = dec_avals = None  # structural lint only
            report = analysis.analyze(
                graph,
                site_order=step_order,
                decode_order=step_order,
                site_avals=pre_avals,
                decode_avals=dec_avals,
                n_steps=int(n_new),
                schedule=sched,
            )
            self.preflight_report = report
            report.enforce(mode)
        return report

    # ---------------------------------------------------------- execution
    def _require_zoo(self):
        zoo = self.model.zoo_model
        if zoo is None:
            raise RuntimeError(
                "lm.generate requires a model bound via traced_lm (needs "
                "prefill/decode_step); plain TracedModel wraps only a "
                "single forward"
            )
        return zoo

    def _mark_uniform(self) -> None:
        """Stamp :attr:`steps_uniform` — whether the decode loop will run
        fused.  Best-effort: a graph that fails step validation is marked
        ``None`` and the execution path raises the real error."""
        from repro.core.batching import split_invokes
        from repro.core.generation import steps_uniform

        try:
            if self.invokes:
                self.steps_uniform = [
                    steps_uniform(g, inv.max_new_tokens)
                    for g, inv in zip(
                        split_invokes(self.graph, len(self.invokes)),
                        self.invokes,
                    )
                ]
            else:
                self.steps_uniform = steps_uniform(
                    self.graph, self.max_new_tokens
                )
        except Exception:
            self.steps_uniform = None

    def execute(self) -> dict[str, Any]:
        from repro.core.generation import run_generation

        self._mark_uniform()
        if self.remote:
            return self._execute_remote()
        if self.invokes:
            return self._execute_invokes()
        zoo = self._require_zoo()
        extras = dict(self.model_kwargs)
        lengths = extras.pop("lengths", None)
        res = run_generation(
            zoo,
            self.model.params,
            self.graph,
            jax.numpy.asarray(self.tokens),
            self.max_new_tokens,
            mode=self.mode,
            extras=extras,
            lengths=lengths,
        )
        self.output_tokens = np.asarray(res.tokens)
        self.output_logits = res.logits
        self.logs = res.logs
        self._results = self._assemble_results(res.saves)
        return self._results

    def _execute_invokes(self) -> dict[str, Any]:
        """Multi-invoke generation: every invoke becomes a row-group of ONE
        slot-table decode loop; invokes share each decode step while
        co-resident and retire independently (per-invoke max_new_tokens)."""
        from repro.core.batching import split_invokes
        from repro.core.generation import run_generation_invokes

        zoo = self._require_zoo()
        graphs = split_invokes(self.graph, len(self.invokes))
        items = [
            (g, inv.batch, inv.max_new_tokens)
            for g, inv in zip(graphs, self.invokes)
        ]
        results = run_generation_invokes(
            zoo, self.model.params, items, mode=self.mode
        )
        return self._finish_generation_invokes(results)

    def _finish_generation_invokes(self, results: list) -> dict[str, Any]:
        per = []
        for inv, res in zip(self.invokes, results):
            inv.output_tokens = np.asarray(res.tokens)
            inv.output_logits = res.logits
            inv.logs = res.logs
            per.append(self._assemble_results(res.saves, invoke=inv.index))
        return self._finish_invoke_results(per)

    def _execute_remote(self) -> dict[str, Any]:
        """Ship the step-annotated graph over the wire (paper §3.3): the
        server's ``kind="generate"`` path runs the decode loop with the
        graph interleaved and only saves + generated tokens return.  A
        multi-invoke trace ships all invokes in ONE request; the server
        admits each as a row-group of its decode loop."""
        backend = self.backend or self.model.backend
        if backend is None:
            raise RuntimeError(
                "remote=True requires a backend (NDIF client); pass "
                "backend= or attach one to the model"
            )
        if self.invokes:
            from repro.core.batching import split_invokes
            from repro.core.generation import GenerationResult

            graphs = split_invokes(self.graph, len(self.invokes))
            wires = backend.generate_invokes([
                {"graph": g, "batch": inv.batch,
                 "max_new_tokens": inv.max_new_tokens}
                for g, inv in zip(graphs, self.invokes)
            ])
            results = []
            for wire in wires:
                saves = dict(wire)
                logs = saves.pop("__logs__", None) or []
                results.append(GenerationResult(
                    tokens=np.asarray(saves.pop("tokens")),
                    logits=saves.pop("logits"),
                    saves=saves,
                    logs=[(int(n), v) for n, v in logs],
                ))
            return self._finish_generation_invokes(results)
        extras = {k: np.asarray(v) for k, v in self.model_kwargs.items()}
        lengths = extras.pop("lengths", None)
        wire = backend.generate(
            np.asarray(self.tokens),
            self.max_new_tokens,
            graph=self.graph,
            lengths=lengths,
            **extras,
        )
        saves = dict(wire)
        logs = saves.pop("__logs__", None)
        if logs:
            self.logs = [(int(n), v) for n, v in logs]
        # reserved keys: the generated ids and last-step logits
        self.output_tokens = np.asarray(saves.pop("tokens"))
        self.output_logits = saves.pop("logits")
        self._results = self._assemble_results(saves)
        return self._results

    def _assemble_results(
        self, saves: dict[str, Any], invoke: int | None = None
    ) -> dict[str, Any]:
        """Stack per-step wire saves (``name@stepK``) back to user names.

        ``invoke`` scopes assembly to one invoke of a multi-invoke trace:
        its per-invoke graph carries DEqualified wire names, so the
        ``i{k}/`` prefix is stripped from the registered bases before
        lookup."""
        from repro.core.generation import stack_step_saves

        prefix = f"i{invoke}/" if invoke is not None else ""
        results: dict[str, Any] = {}
        for base, by_step in self._step_save_names.items():
            if prefix:
                if not base.startswith(prefix):
                    continue
                local = {s: w[len(prefix):] for s, w in by_step.items()}
                out_name = base[len(prefix):]
            else:
                local = by_step
                out_name = base
            vals = {s: saves[w] for s, w in local.items() if w in saves}
            if not vals:
                continue
            if len(vals) == 1:
                results[out_name] = next(iter(vals.values()))
            else:
                results[out_name] = stack_step_saves(vals)
        # saves made outside the tracer API (hand-built graphs)
        for name, val in saves.items():
            if "@step" not in name:
                results.setdefault(name, val)
        if invoke is None:
            self._results = results
        return results


def _ref(node: Node):
    from repro.core.graph import Ref

    return Ref(node.id)


def _as_node(tracer: Tracer, value: Any) -> Node:
    if isinstance(value, Proxy):
        return value.node
    value = np.asarray(value) if not np.isscalar(value) else value
    return tracer.graph.add("constant", value)


class TracedModel:
    """Wraps a pure model function + params into the NNsight-like object.

    ``model_fn(params, *inputs)`` must call ``taps.site`` at its tap points
    and finish by returning its output; the wrapper adds the ``output`` site.
    """

    def __init__(
        self,
        model_fn: Callable[..., Any],
        params: Any,
        schedule: SiteSchedule,
        *,
        name: str = "model",
        default_mode: str = "unrolled",
        backend: Any | None = None,
    ) -> None:
        self.model_fn = model_fn
        self.params = params
        self.name = name
        self.default_mode = default_mode
        self.backend = backend
        # zoo-model binding (prefill/decode_step), set by traced_lm;
        # required for lm.generate
        self.zoo_model: Any | None = None
        self._tracers: list[Tracer] = []
        self._session_active = False
        order = list(schedule.order)
        if ("output", None) not in order:
            order = order + [("output", None)]
        self.schedule = SiteSchedule(
            order=order,
            scan_sites=schedule.scan_sites,
            n_layers=schedule.n_layers,
        )
        self.site_names = {name for name, _ in self.schedule.order}
        self.per_layer_prefixes = _layer_prefixes(
            {name for name, layer in self.schedule.order if layer is not None}
        )

        def wrapped(params_, *args, **kwargs):
            from repro.core import taps

            out = model_fn(params_, *args, **kwargs)
            return taps.site("output", out)

        self._wrapped = wrapped

    @property
    def wrapped_fn(self) -> Callable[..., Any]:
        return self._wrapped

    # ------------------------------------------------------------- tracing
    def trace(self, *args: Any, **kwargs: Any) -> Tracer:
        """Open a tracing context.

        ``trace(tokens, ...)`` is a one-invoke trace; bare ``trace()``
        expects prompts declared via ``tr.invoke(tokens)`` sub-contexts,
        lowered into ONE merged forward on exit."""
        remote = kwargs.pop("remote", False)
        scan = kwargs.pop("scan", False)
        mode = kwargs.pop("mode", None)
        backend = kwargs.pop("backend", None)
        return Tracer(
            self,
            (self.params,) + args,
            kwargs,
            remote=remote,
            scan=scan,
            mode=mode,
            backend=backend,
        )

    def generate(
        self,
        tokens: Any = None,
        max_new_tokens: int = 8,
        *,
        mode: str | None = None,
        remote: bool = False,
        scan: bool = False,
        backend: Any | None = None,
        **extras: Any,
    ) -> "GenerateTracer":
        """Trace a multi-token greedy decode loop (see GenerateTracer).

        With ``tokens=None`` this is the multi-invoke form: declare prompts
        via ``tr.invoke(tokens, max_new_tokens=N)``; every invoke rides ONE
        continuous decode loop and retires at its own ``max_new_tokens``
        (which defaults to this call's value).

        Locally this requires a zoo-model binding
        (:func:`repro.models.traced.traced_lm`) because generation needs
        ``prefill``/``decode_step``.  With ``remote=True`` the step graph
        ships to the NDIF server instead (``kind="generate"`` + ``graph``)
        and only saves + generated tokens come back.  ``scan=True``
        shape-checks prefill-step taps via ``jax.eval_shape``.
        """
        return GenerateTracer(
            self, tokens, max_new_tokens, mode=mode, extras=extras,
            remote=remote, scan=scan, backend=backend,
        )

    def session(self, *, remote: bool = False, backend: Any | None = None):
        return Session(self, remote=remote, backend=backend)

    def _push_tracer(self, tracer: Tracer) -> None:
        self._tracers.append(tracer)

    def _pop_tracer(self) -> None:
        self._tracers.pop()

    @property
    def _active(self) -> Tracer:
        if not self._tracers:
            raise RuntimeError(
                "tap sites are only accessible inside a trace context"
            )
        return self._tracers[-1]

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_") or name in type(self).__dict__:
            raise AttributeError(name)
        tracer = self._active
        root = Envoy(
            tracer, "", None, self.site_names, self.per_layer_prefixes
        )
        return getattr(root, name)

    def __setattr__(self, name: str, value: Any) -> None:
        # Top-level site assignment (``lm.logits += bias``) is a setter on
        # that site, exactly like the Envoy paths — never a plain attribute
        # (which would silently shadow the site for the rest of the
        # process).  Outside a trace context this raises.
        sites = self.__dict__.get("site_names")
        if sites is not None and name in sites:
            self._active._write_back(name, None, (), value)
            return
        object.__setattr__(self, name, value)

    def __dir__(self) -> list[str]:
        roots = {s.split(".")[0] for s in self.site_names}
        return sorted(set(super().__dir__()) | roots)


def _layer_prefixes(per_layer_sites: set[str]) -> set[str]:
    """Module-path prefixes that accept a [layer] index."""
    out: set[str] = set()
    for name in per_layer_sites:
        parts = name.split(".")
        # by convention the first segment of a per-layer site is the stack
        # ("layers", "blocks", "encoder", ...)
        out.add(parts[0])
    return out


class Session:
    """The paper's Session context: several traces, one request, value flow.

    Traces created inside a session are deferred; on session exit they
    execute in declaration order (locally) or ship as ONE request
    (remotely).  Saves from an earlier trace are legal inside a later one —
    the tracer bridges them as cross-trace inputs, bound as constants when
    the consuming trace executes (server-side for remote sessions, so the
    intermediate values never cross the wire).

    Sessions do not nest, and a remote session without a backend fails at
    construction — before any trace body runs.
    """

    def __init__(
        self, model: TracedModel, *, remote: bool, backend: Any | None
    ) -> None:
        self.model = model
        self.remote = remote
        self.backend = backend or model.backend
        if remote and self.backend is None:
            raise RuntimeError(
                "remote session requires a backend (NDIF client); pass "
                "backend= or attach one to the model"
            )
        self.tracers: list[Tracer] = []
        self._active = False

    def trace(self, *args: Any, **kwargs: Any) -> Tracer:
        if not self._active:
            raise RuntimeError("session is not active")
        tracer = self.model.trace(*args, **kwargs)
        tracer._deferred = True
        tracer._session = self
        self.tracers.append(tracer)
        return tracer

    def __enter__(self) -> "Session":
        if self.model._session_active:
            raise RuntimeError("sessions cannot be nested")
        self.model._session_active = True
        self._active = True
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._active = False
        self.model._session_active = False
        if exc_type is not None:
            return
        from repro.core.batching import split_results

        if self.remote:
            results = self.backend.execute_session(self)
            for tracer, res in zip(self.tracers, results):
                logs = res.pop("__logs__", None) if isinstance(res, dict) \
                    else None
                if logs:
                    tracer.logs = [(int(n), v) for n, v in logs]
                if tracer.invokes:
                    tracer._finish_invoke_results(
                        split_results(res, tracer._merged)
                    )
                else:
                    tracer._results = res
        else:
            # declaration order; an exception in trace k propagates and
            # skips every later trace (their results stay unavailable)
            for tracer in self.tracers:
                tracer._deferred = False
                tracer._bind_cross_inputs()
                tracer.execute()
