"""The NNsight-style user API: tracing contexts and the Envoy tree (§3.2).

Usage mirrors the paper's Figure 3b::

    lm = TracedModel(model_fn, params, schedule, ...)
    with lm.trace(tokens) as tracer:
        lm.layers[16].mlp.output[:, -1, neurons] = 10.0
        out = lm.output.save()
    print(out.value)

Exiting the context finalizes the intervention graph and executes it —
locally, or remotely when ``remote=True`` (serialized and shipped to the NDIF
server, paper §3.3).  ``scan=True`` validates shapes via ``jax.eval_shape``
without running the model (the paper's FakeTensor scanning).

Generation tracing (the paper's multi-invoke / ``.next()`` semantics, §3.2)
interleaves interventions with a multi-token greedy decode loop; models
bound via :func:`repro.models.traced.traced_lm` support::

    with lm.generate(tokens, max_new_tokens=8) as tr:
        for s in tr.steps():                      # decode steps 0..7
            lm.layers[4].mlp.output += steer      # write THIS step
            lm.logits.save("logits")              # same name every step
    tr.result("logits")                           # stacked (B, 8, V)
    tr.output_tokens                              # (B, 8) generated ids

``tr.step(k)`` targets one chosen step, ``tr.all_steps()`` broadcasts a
setter over every decode step, and ``tr.prefill()`` taps the prompt
forward.  Values saved under one name at several steps come back stacked
along the token axis.  See :mod:`repro.core.generation` for the execution
model.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.core.graph import (
    ALL_STEPS,
    PREFILL_STEP,
    GraphValidationError,
    InterventionGraph,
    Node,
)
from repro.core.interleave import SiteSchedule, run_interleaved
from repro.core.proxy import Proxy, make_op_caller, unwrap

__all__ = ["Tracer", "GenerateTracer", "Envoy", "TracedModel", "Session"]


class Envoy:
    """Attribute-path access to tap sites, mirroring the module tree.

    Built from the model's declared site names: ``layers.mlp.output`` with
    per-layer flag yields ``lm.layers[5].mlp.output``.
    """

    def __init__(
        self,
        tracer: "Tracer",
        prefix: str,
        layer: int | None,
        site_names: set[str],
        per_layer_prefixes: set[str],
    ) -> None:
        object.__setattr__(self, "_tracer", tracer)
        object.__setattr__(self, "_prefix", prefix)
        object.__setattr__(self, "_layer", layer)
        object.__setattr__(self, "_site_names", site_names)
        object.__setattr__(self, "_per_layer_prefixes", per_layer_prefixes)

    def _child_path(self, name: str) -> str:
        return f"{self._prefix}.{name}" if self._prefix else name

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_"):
            raise AttributeError(name)
        path = self._child_path(name)
        if path in self._site_names:
            return self._tracer._tap_proxy(path, self._layer)
        if any(s == path or s.startswith(path + ".") for s in self._site_names):
            return Envoy(
                self._tracer,
                path,
                self._layer,
                self._site_names,
                self._per_layer_prefixes,
            )
        raise AttributeError(
            f"no tap site or module path {path!r}; "
            f"available: {sorted(self._site_names)}"
        )

    def __getitem__(self, layer: int) -> "Envoy":
        if self._prefix not in self._per_layer_prefixes:
            raise TypeError(f"{self._prefix!r} is not a layered module path")
        if not isinstance(layer, int):
            raise TypeError("layer index must be a concrete int")
        return Envoy(
            self._tracer,
            self._prefix,
            layer,
            self._site_names,
            self._per_layer_prefixes,
        )

    def __setattr__(self, name: str, value: Any) -> None:
        path = self._child_path(name)
        if path in self._site_names:
            self._tracer._write_back(path, self._layer, (), value)
            return
        raise AttributeError(f"cannot assign to non-site path {path!r}")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<Envoy {self._prefix!r} layer={self._layer}>"


class Tracer:
    """Builds one intervention graph inside a ``with`` block."""

    def __init__(
        self,
        model: "TracedModel",
        model_args: tuple,
        model_kwargs: dict,
        *,
        remote: bool = False,
        scan: bool = False,
        mode: str | None = None,
        backend: Any | None = None,
        graph: InterventionGraph | None = None,
    ) -> None:
        self.model = model
        self.model_args = model_args
        self.model_kwargs = model_kwargs
        self.remote = remote
        self.scan = scan
        self.mode = mode or model.default_mode
        self.backend = backend
        self.graph = graph if graph is not None else InterventionGraph()
        self._results: dict[str, Any] | None = None
        self._saved_proxies: dict[str, Proxy] = {}
        # Generation-step pointer: None for single-forward traces; the
        # GenerateTracer subclass moves it so taps are stamped per step.
        self._step: int | None = None
        self._current: dict[tuple[str, int | None, int | None], Node] = {}
        self._deferred = False  # True when owned by a Session
        self.logs: list[tuple[int, Any]] = []

    # ------------------------------------------------------------- plumbing
    def _tap_proxy(self, site: str, layer: int | None) -> Proxy:
        key = (site, layer, self._step)
        if key not in self._current:
            node = self.graph.add(
                "tap_get", site=site, layer=layer, step=self._step
            )
            self._current[key] = node
        node = self._current[key]
        return Proxy(self, node, root_site=site, root_layer=layer)

    def _write_back(
        self, site: str, layer: int | None, path: tuple, value: Any
    ) -> None:
        key = (site, layer, self._step)
        if path:
            current = self._current.get(key)
            if current is None:
                current = self.graph.add(
                    "tap_get", site=site, layer=layer, step=self._step
                )
                self._current[key] = current
            new = self.graph.add(
                "update_path", _ref(current), path, unwrap(value)
            )
        else:
            new = _as_node(self, value)
        self.graph.add(
            "tap_set", _ref(new), site=site, layer=layer, step=self._step
        )
        self._current[key] = new

    def _register_save(self, name: str, proxy: Proxy) -> None:
        self._saved_proxies[name] = proxy

    # ------------------------------------------------------------ protocols
    def apply(self, op_name: str) -> Callable[..., Proxy]:
        """Call a registry op on proxies (the paper's ``nnsight.apply``)."""
        return make_op_caller(self, op_name)

    def constant(self, value: Any) -> Proxy:
        value = np.asarray(value) if not np.isscalar(value) else value
        return Proxy(self, self.graph.add("constant", value))

    def input(self, name: str) -> Proxy:
        """A named experiment input, bound at execution time."""
        return Proxy(self, self.graph.add("input", name))

    def backward(self, loss: Proxy) -> None:
        """Declare the scalar loss for the backward pass (GradProtocol)."""
        self.graph.backward_loss = loss.node.id

    def log(self, value: Any) -> None:
        node = _as_node(self, value)
        self.graph.add("log", _ref(node))

    # -------------------------------------------------------------- results
    def result(self, name: str) -> Any:
        if self._results is None:
            raise RuntimeError(
                "results are only available after the trace context exits"
            )
        return self._results[name]

    @property
    def results(self) -> dict[str, Any]:
        if self._results is None:
            raise RuntimeError("trace has not executed yet")
        return dict(self._results)

    # ------------------------------------------------------------- context
    def __enter__(self) -> "Tracer":
        self.model._push_tracer(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.model._pop_tracer()
        if exc_type is not None:
            return
        if self.scan:
            self.validate_shapes()
        if self._deferred:
            return
        self.execute()

    def validate_shapes(self) -> None:
        """The paper's FakeTensor scan: eval_shape the interleaved program."""
        jax.eval_shape(
            lambda a, k: run_interleaved(
                self.model.wrapped_fn,
                self.graph,
                self.model.schedule,
                a,
                k,
                mode=self.mode,
            ),
            self.model_args,
            self.model_kwargs,
        )

    def execute(self) -> dict[str, Any]:
        if self.remote:
            backend = self.backend or self.model.backend
            if backend is None:
                raise RuntimeError(
                    "remote=True requires a backend (NDIF client); pass "
                    "backend= or attach one to the model"
                )
            self._results = backend.execute(self)
            return self._results
        self.graph.validate(self.model.schedule.order)
        out, saves, logs = run_interleaved(
            self.model.wrapped_fn,
            self.graph,
            self.model.schedule,
            self.model_args,
            self.model_kwargs,
            mode=self.mode,
        )
        self._results = saves
        self.logs = logs
        return saves


class GenerateTracer(Tracer):
    """Builds a step-annotated graph over a multi-token decode loop.

    Tap nodes are stamped with the *current step* — decode step ``0`` by
    default; move the pointer with :meth:`steps` (iterate all), :meth:`step`
    (one chosen step), :meth:`all_steps` (broadcast setters), or
    :meth:`prefill` (the prompt forward).  ``.save(name)`` at several steps
    under one name yields per-step values stacked along the token axis.
    """

    def __init__(
        self,
        model: "TracedModel",
        tokens: Any,
        max_new_tokens: int,
        *,
        mode: str | None = None,
        extras: dict | None = None,
        remote: bool = False,
        backend: Any | None = None,
    ) -> None:
        super().__init__(model, (tokens,), dict(extras or {}), mode=mode,
                         remote=remote, backend=backend)
        self.tokens = tokens
        self.max_new_tokens = int(max_new_tokens)
        self._step: int = 0
        # base save name -> {step -> wire save name}
        self._step_save_names: dict[str, dict[int, str]] = {}
        self.output_tokens: np.ndarray | None = None
        self.output_logits: Any | None = None

    # ------------------------------------------------------- step pointer
    def steps(self, start: int = 0, stop: int | None = None) -> Iterator[int]:
        """Iterate decode steps, moving the tap pointer to each in turn."""
        stop = self.max_new_tokens if stop is None else stop
        prev = self._step
        try:
            for s in range(start, stop):
                self._step = s
                yield s
        finally:
            # restore the enclosing pointer even on early break — a loop
            # nested in step()/prefill() must not leak its last step
            self._step = prev

    @contextlib.contextmanager
    def step(self, s: int):
        """Target one chosen decode step (0-based)."""
        prev, self._step = self._step, int(s)
        try:
            yield self
        finally:
            self._step = prev

    @contextlib.contextmanager
    def all_steps(self):
        """Broadcast over every decode step.

        Read-modify-write chains (``site += delta``) are replicated into
        each step; only *saving* a broadcast value is rejected (ambiguous
        step) — iterate :meth:`steps` to collect per-step values.
        """
        prev, self._step = self._step, ALL_STEPS
        try:
            yield self
        finally:
            self._step = prev

    @contextlib.contextmanager
    def prefill(self):
        """Tap the prompt-prefill forward (full prompt-length shapes)."""
        prev, self._step = self._step, PREFILL_STEP
        try:
            yield self
        finally:
            self._step = prev

    # ------------------------------------------------------ stacked saves
    def _register_save(self, name: str, proxy: Proxy) -> None:
        by_step = self._step_save_names.setdefault(name, {})
        mixed = (self._step == PREFILL_STEP and any(
            s != PREFILL_STEP for s in by_step
        )) or (self._step != PREFILL_STEP and PREFILL_STEP in by_step)
        if mixed:
            raise GraphValidationError(
                f"save {name!r} mixes prefill() and decode-step values; "
                "prefill shapes are prompt-length and cannot stack with "
                "per-step values — use a different name for the prefill "
                "save"
            )
        nid = self.graph.saves.pop(name)
        wire = f"{name}@step{self._step}"
        self.graph.saves[wire] = nid
        by_step[self._step] = wire
        self._saved_proxies[name] = proxy

    # ---------------------------------------------------------- execution
    def validate_shapes(self) -> None:  # pragma: no cover - guard
        raise NotImplementedError(
            "scan=True shape validation is not supported for generation "
            "traces yet"
        )

    def execute(self) -> dict[str, Any]:
        from repro.core.generation import run_generation

        if self.remote:
            return self._execute_remote()
        zoo = self.model.zoo_model
        if zoo is None:
            raise RuntimeError(
                "lm.generate requires a model bound via traced_lm (needs "
                "prefill/decode_step); plain TracedModel wraps only a "
                "single forward"
            )
        extras = dict(self.model_kwargs)
        lengths = extras.pop("lengths", None)
        res = run_generation(
            zoo,
            self.model.params,
            self.graph,
            jax.numpy.asarray(self.tokens),
            self.max_new_tokens,
            mode=self.mode,
            extras=extras,
            lengths=lengths,
        )
        self.output_tokens = np.asarray(res.tokens)
        self.output_logits = res.logits
        self.logs = res.logs
        return self._assemble_results(res.saves)

    def _execute_remote(self) -> dict[str, Any]:
        """Ship the step-annotated graph over the wire (paper §3.3): the
        server's ``kind="generate"`` path runs the decode loop with the
        graph interleaved and only saves + generated tokens return."""
        backend = self.backend or self.model.backend
        if backend is None:
            raise RuntimeError(
                "remote=True requires a backend (NDIF client); pass "
                "backend= or attach one to the model"
            )
        extras = {k: np.asarray(v) for k, v in self.model_kwargs.items()}
        lengths = extras.pop("lengths", None)
        wire = backend.generate(
            np.asarray(self.tokens),
            self.max_new_tokens,
            graph=self.graph,
            lengths=lengths,
            **extras,
        )
        saves = dict(wire)
        # reserved keys: the generated ids and last-step logits
        self.output_tokens = np.asarray(saves.pop("tokens"))
        self.output_logits = saves.pop("logits")
        return self._assemble_results(saves)

    def _assemble_results(self, saves: dict[str, Any]) -> dict[str, Any]:
        """Stack per-step wire saves (``name@stepK``) back to user names."""
        from repro.core.generation import stack_step_saves

        results: dict[str, Any] = {}
        for base, by_step in self._step_save_names.items():
            vals = {s: saves[w] for s, w in by_step.items() if w in saves}
            if not vals:
                continue
            if len(vals) == 1:
                results[base] = next(iter(vals.values()))
            else:
                results[base] = stack_step_saves(vals)
        # saves made outside the tracer API (hand-built graphs)
        for name, val in saves.items():
            if "@step" not in name:
                results.setdefault(name, val)
        self._results = results
        return results


def _ref(node: Node):
    from repro.core.graph import Ref

    return Ref(node.id)


def _as_node(tracer: Tracer, value: Any) -> Node:
    if isinstance(value, Proxy):
        return value.node
    value = np.asarray(value) if not np.isscalar(value) else value
    return tracer.graph.add("constant", value)


def _encode_path(path: tuple) -> tuple:
    return path


class TracedModel:
    """Wraps a pure model function + params into the NNsight-like object.

    ``model_fn(params, *inputs)`` must call ``taps.site`` at its tap points
    and finish by returning its output; the wrapper adds the ``output`` site.
    """

    def __init__(
        self,
        model_fn: Callable[..., Any],
        params: Any,
        schedule: SiteSchedule,
        *,
        name: str = "model",
        default_mode: str = "unrolled",
        backend: Any | None = None,
    ) -> None:
        self.model_fn = model_fn
        self.params = params
        self.name = name
        self.default_mode = default_mode
        self.backend = backend
        # zoo-model binding (prefill/decode_step), set by traced_lm;
        # required for lm.generate
        self.zoo_model: Any | None = None
        self._tracers: list[Tracer] = []
        order = list(schedule.order)
        if ("output", None) not in order:
            order = order + [("output", None)]
        self.schedule = SiteSchedule(
            order=order,
            scan_sites=schedule.scan_sites,
            n_layers=schedule.n_layers,
        )
        self.site_names = {name for name, _ in self.schedule.order}
        self.per_layer_prefixes = _layer_prefixes(
            {name for name, layer in self.schedule.order if layer is not None}
        )

        def wrapped(params_, *args, **kwargs):
            from repro.core import taps

            out = model_fn(params_, *args, **kwargs)
            return taps.site("output", out)

        self._wrapped = wrapped

    @property
    def wrapped_fn(self) -> Callable[..., Any]:
        return self._wrapped

    # ------------------------------------------------------------- tracing
    def trace(self, *args: Any, **kwargs: Any) -> Tracer:
        remote = kwargs.pop("remote", False)
        scan = kwargs.pop("scan", False)
        mode = kwargs.pop("mode", None)
        backend = kwargs.pop("backend", None)
        return Tracer(
            self,
            (self.params,) + args,
            kwargs,
            remote=remote,
            scan=scan,
            mode=mode,
            backend=backend,
        )

    def generate(
        self,
        tokens: Any,
        max_new_tokens: int = 8,
        *,
        mode: str | None = None,
        remote: bool = False,
        backend: Any | None = None,
        **extras: Any,
    ) -> "GenerateTracer":
        """Trace a multi-token greedy decode loop (see GenerateTracer).

        Locally this requires a zoo-model binding
        (:func:`repro.models.traced.traced_lm`) because generation needs
        ``prefill``/``decode_step``.  With ``remote=True`` the step graph
        ships to the NDIF server instead (``kind="generate"`` + ``graph``)
        and only saves + generated tokens come back.
        """
        return GenerateTracer(
            self, tokens, max_new_tokens, mode=mode, extras=extras,
            remote=remote, backend=backend,
        )

    def session(self, *, remote: bool = False, backend: Any | None = None):
        return Session(self, remote=remote, backend=backend)

    def _push_tracer(self, tracer: Tracer) -> None:
        self._tracers.append(tracer)

    def _pop_tracer(self) -> None:
        self._tracers.pop()

    @property
    def _active(self) -> Tracer:
        if not self._tracers:
            raise RuntimeError(
                "tap sites are only accessible inside a trace context"
            )
        return self._tracers[-1]

    def __getattr__(self, name: str) -> Any:
        if name.startswith("_") or name in type(self).__dict__:
            raise AttributeError(name)
        tracer = self._active
        root = Envoy(
            tracer, "", None, self.site_names, self.per_layer_prefixes
        )
        return getattr(root, name)


def _layer_prefixes(per_layer_sites: set[str]) -> set[str]:
    """Module-path prefixes that accept a [layer] index."""
    out: set[str] = set()
    for name in per_layer_sites:
        parts = name.split(".")
        # by convention the first segment of a per-layer site is the stack
        # ("layers", "blocks", "encoder", ...)
        out.add(parts[0])
    return out


class Session:
    """The paper's Session context: several traces, one remote request.

    Traces created inside a session are deferred; on session exit they
    execute sequentially (locally) or ship as one request (remotely),
    ``saves`` from earlier traces usable by later ones is out of scope —
    each trace is self-contained, matching the paper's performance benefit
    (one request, N traces).
    """

    def __init__(
        self, model: TracedModel, *, remote: bool, backend: Any | None
    ) -> None:
        self.model = model
        self.remote = remote
        self.backend = backend or model.backend
        self.tracers: list[Tracer] = []
        self._active = False

    def trace(self, *args: Any, **kwargs: Any) -> Tracer:
        if not self._active:
            raise RuntimeError("session is not active")
        tracer = self.model.trace(*args, **kwargs)
        tracer._deferred = True
        self.tracers.append(tracer)
        return tracer

    def __enter__(self) -> "Session":
        self._active = True
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._active = False
        if exc_type is not None:
            return
        if self.remote:
            if self.backend is None:
                raise RuntimeError("remote session requires a backend")
            results = self.backend.execute_session(self)
            for tracer, res in zip(self.tracers, results):
                tracer._results = res
        else:
            for tracer in self.tracers:
                tracer._deferred = False
                tracer.execute()
