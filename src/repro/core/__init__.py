"""The paper's primary contribution: intervention graphs in JAX.

Public surface:
  InterventionGraph, Node, Ref          — the IR (graph.py)
  TracedModel, Tracer, Session, Envoy   — the NNsight-style API (tracer.py)
  SiteSchedule, run_interleaved         — interleaving engine (interleave.py)
  taps.site / taps.scan_outputs         — model-side tap points (taps.py)
  dumps/loads, graph_to_json            — wire format (serialize.py)
  merge_graphs / split_results          — parallel co-tenancy (batching.py)
"""
from repro.core.batching import (
    MergedBatch,
    merge_graphs,
    merge_invoke_batches,
    split_invokes,
    split_results,
)
from repro.core.graph import (
    GraphValidationError,
    InterventionGraph,
    Node,
    Ref,
)
from repro.core.interleave import (
    Interleaver,
    InterleaveState,
    SiteSchedule,
    run_interleaved,
)
from repro.core.op_registry import OPS, register_op, resolve_op
from repro.core.serialize import (
    dumps,
    graph_from_json,
    graph_to_json,
    loads,
)
from repro.core.tracer import Envoy, Invoke, Session, TracedModel, Tracer

__all__ = [
    "GraphValidationError",
    "InterventionGraph",
    "Node",
    "Ref",
    "TracedModel",
    "Tracer",
    "Session",
    "Envoy",
    "Invoke",
    "SiteSchedule",
    "Interleaver",
    "InterleaveState",
    "run_interleaved",
    "OPS",
    "register_op",
    "resolve_op",
    "dumps",
    "loads",
    "graph_to_json",
    "graph_from_json",
    "MergedBatch",
    "merge_graphs",
    "split_results",
    "split_invokes",
    "merge_invoke_batches",
]
