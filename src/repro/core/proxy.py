"""Deferred-execution proxies (the paper's Proxy/Node pair, §B.1).

Every operation on a :class:`Proxy` appends a node to the active
:class:`~repro.core.graph.InterventionGraph` and returns a new proxy — the
same deferred-computation idiom deep-learning frameworks use for autodiff
(paper §1).  A proxy additionally carries *provenance*: if it was derived from
a tap site purely via ``getitem``, in-place writes (``p[idx] = v``) are
rewritten into a functional ``update_path`` + ``tap_set`` pair, reproducing
the NNsight idiom ``layer.output[0][1, tok, :] = x``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, TYPE_CHECKING

from repro.core.graph import InterventionGraph, Node, Ref

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.tracer import Tracer

__all__ = ["Proxy", "unwrap", "wrap_args"]


def unwrap(obj: Any) -> Any:
    """Proxy -> Ref; containers mapped structurally; literals unchanged."""
    if isinstance(obj, Proxy):
        return Ref(obj.node.id)
    if isinstance(obj, tuple):
        return tuple(unwrap(o) for o in obj)
    if isinstance(obj, list):
        return [unwrap(o) for o in obj]
    if isinstance(obj, dict):
        return {k: unwrap(v) for k, v in obj.items()}
    return obj


def wrap_args(args: tuple, kwargs: dict) -> tuple[tuple, dict]:
    return unwrap(args), unwrap(kwargs)


class Proxy:
    """A handle on a future value inside a tracing context."""

    # Make numpy defer to our reflected operators.
    __array_priority__ = 1000

    def __init__(
        self,
        tracer: "Tracer",
        node: Node,
        root_site: str | None = None,
        root_layer: int | None = None,
        path: tuple = (),
    ) -> None:
        self._tracer = tracer
        self.node = node
        # Provenance: set only while the proxy is a pure getitem-chain off a
        # tap site, enabling write-back semantics.
        self._root_site = root_site
        self._root_layer = root_layer
        self._path = path

    # ------------------------------------------------------------- plumbing
    @property
    def graph(self) -> InterventionGraph:
        return self._tracer.graph

    def _emit(self, op: str, *args: Any, **kwargs: Any) -> "Proxy":
        # Nodes append to the ACTIVE tracer: inside a later trace of the
        # same session, a proxy from an earlier trace is bridged in as a
        # cross-trace input (session value flow) instead of corrupting its
        # home graph.
        tracer = self._tracer._target()
        a, k = wrap_args(tracer._adopt(args), tracer._adopt(kwargs))
        node = tracer.graph.add(op, *a, **k)
        return Proxy(tracer, node)

    # ------------------------------------------------------------ protocols
    def save(self, name: str | None = None) -> "Proxy":
        """LockProtocol: make this value available after execution."""
        a, _ = wrap_args((self,), {})
        node = self.graph.add("save", *a)
        name = name or f"save_{node.id}"
        self.graph.mark_saved(name, node)
        saved = Proxy(self._tracer, node)
        # the tracer may qualify the name (per-invoke save tables)
        saved._save_name = self._tracer._register_save(name, saved)  # type: ignore[attr-defined]
        return saved

    @property
    def value(self) -> Any:
        """After execution, the concrete value of a saved proxy."""
        name = getattr(self, "_save_name", None)
        if name is None:
            raise ValueError(
                "only .save()d proxies expose .value after execution"
            )
        return self._tracer.result(name)

    @property
    def grad(self) -> "Proxy":
        """GradProtocol: d(backward loss)/d(this tap value)."""
        if self._root_site is None or self._path:
            raise ValueError(
                ".grad is only available directly on tap-site proxies"
            )
        node = self.graph.add(
            "grad_get", site=self._root_site, layer=self._root_layer,
            step=getattr(self._tracer._target(), "_step", None),
        )
        return Proxy(self._tracer, node)

    def log(self) -> "Proxy":
        a, _ = wrap_args((self,), {})
        return Proxy(self._tracer, self.graph.add("log", *a))

    # -------------------------------------------------------------- getitem
    def __getitem__(self, key: Any) -> "Proxy":
        out = self._emit("getitem", self, key)
        if self._root_site is not None and out._tracer is self._tracer:
            # write-back provenance only holds within the owning trace
            out._root_site = self._root_site
            out._root_layer = self._root_layer
            out._path = self._path + (key,)
        return out

    def __setitem__(self, key: Any, val: Any) -> None:
        if self._root_site is None:
            raise ValueError(
                "in-place writes are only supported on values derived from "
                "a tap site by indexing (the write-back target is the site)"
            )
        self._tracer._write_back(
            self._root_site, self._root_layer, self._path + (key,), val
        )

    # ------------------------------------------------------------ operators
    def __add__(self, o): return self._emit("add", self, o)
    def __radd__(self, o): return self._emit("add", o, self)
    def __sub__(self, o): return self._emit("sub", self, o)
    def __rsub__(self, o): return self._emit("rsub", self, o)
    def __mul__(self, o): return self._emit("mul", self, o)
    def __rmul__(self, o): return self._emit("mul", o, self)
    def __truediv__(self, o): return self._emit("truediv", self, o)
    def __rtruediv__(self, o): return self._emit("rtruediv", self, o)
    def __floordiv__(self, o): return self._emit("floordiv", self, o)
    def __mod__(self, o): return self._emit("mod", self, o)
    def __pow__(self, o): return self._emit("pow", self, o)
    def __matmul__(self, o): return self._emit("matmul", self, o)
    def __rmatmul__(self, o): return self._emit("rmatmul", self, o)
    def __neg__(self): return self._emit("neg", self)
    def __abs__(self): return self._emit("abs", self)
    def __eq__(self, o): return self._emit("eq", self, o)  # type: ignore[override]
    def __ne__(self, o): return self._emit("ne", self, o)  # type: ignore[override]
    def __lt__(self, o): return self._emit("lt", self, o)
    def __le__(self, o): return self._emit("le", self, o)
    def __gt__(self, o): return self._emit("gt", self, o)
    def __ge__(self, o): return self._emit("ge", self, o)
    def __invert__(self): return self._emit("invert", self)
    def __and__(self, o): return self._emit("and", self, o)
    def __or__(self, o): return self._emit("or", self, o)

    __hash__ = object.__hash__  # __eq__ override would otherwise kill hashing

    # ------------------------------------------------------- ndarray-likes
    def astype(self, dtype) -> "Proxy":
        return self._emit("astype", self, str(dtype))

    def sum(self, axis=None, **kw): return self._emit("jnp.sum", self, axis=axis, **kw)
    def mean(self, axis=None, **kw): return self._emit("jnp.mean", self, axis=axis, **kw)
    def max(self, axis=None, **kw): return self._emit("jnp.max", self, axis=axis, **kw)
    def min(self, axis=None, **kw): return self._emit("jnp.min", self, axis=axis, **kw)
    def argmax(self, axis=None): return self._emit("jnp.argmax", self, axis=axis)
    def argmin(self, axis=None): return self._emit("jnp.argmin", self, axis=axis)
    def reshape(self, *shape): return self._emit("jnp.reshape", self, shape)
    def squeeze(self, axis=None): return self._emit("jnp.squeeze", self, axis=axis)
    def ravel(self): return self._emit("jnp.ravel", self)
    def norm(self, axis=None): return self._emit("jnp.linalg.norm", self, axis=axis)

    @property
    def T(self) -> "Proxy":
        return self._emit("jnp.transpose", self)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        src = f" from {self._root_site}{list(self._path)}" if self._root_site else ""
        return f"<Proxy %{self.node.id} op={self.node.op}{src}>"


def make_op_caller(tracer: "Tracer", op_name: str) -> Callable[..., Proxy]:
    """An ``nnsight.apply``-style helper: call a registry op on proxies."""

    def _call(*args: Any, **kwargs: Any) -> Proxy:
        a, k = wrap_args(tracer._adopt(args), tracer._adopt(kwargs))
        return Proxy(tracer, tracer.graph.add(op_name, *a, **k))

    return _call
