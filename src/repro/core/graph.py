"""Intervention graph IR — the paper's core data structure (§3.1).

The paper formalizes an experiment as a bipartite computation graph
``C' = (V', A', E')`` plus *getter* edges (model activation -> experiment op)
and *setter* edges (experiment value -> model graph).  Here the IR is a flat
list of :class:`Node` records; variable nodes are implicit (every apply node
has exactly one output, the paper's Appendix E many-to-one form).  Getters are
``tap_get`` nodes, setters are ``tap_set`` nodes; everything else is a pure op
from the registry (:mod:`repro.core.op_registry`).

Acyclicity is *by construction*: a node may only reference nodes with smaller
ids, so node-id order is a topological order.  The paper's validity rule
("no directed path from a setter's apply node back to a getter's variable
node") becomes a *site-schedule* check: every node is assigned the earliest
model tap site at which all of its dependencies are available, and a
``tap_set`` at site S must be computable no later than S.

Generation traces add a second scheduling axis: ``Node.step`` places a tap
on one execution of a multi-token decode loop (prefill + N decode steps,
NNsight's ``.next()``/iteration semantics).  :func:`assign_steps` is the
step-level analogue of :meth:`InterventionGraph.schedule`; per-step site
scheduling is then inherited unchanged (see :mod:`repro.core.generation`).

Multi-invoke traces (the paper's §3.2 / Fig. 3 headline API) add a third
coordinate: ``Node.invoke`` stamps a node with the prompt it belongs to.
Several prompts declared inside one ``with model.trace()`` block each carry
their own interventions; :func:`repro.core.batching.split_invokes` partitions
an invoke-stamped graph back into per-invoke graphs (cross-invoke value flow
is rejected), which the tracer lowers through ``merge_graphs`` into ONE
batched execution.  The coordinate crosses the wire (see
:mod:`repro.core.serialize`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

__all__ = [
    "Ref",
    "Node",
    "InterventionGraph",
    "GraphValidationError",
    "PRE_SITE",
    "POST_SITE",
    "PREFILL_STEP",
    "ALL_STEPS",
    "PRE_STEP",
    "assign_steps",
    "node_fingerprint",
    "SOURCE_META_KEY",
]

# Pseudo-site indices used by the scheduler.
PRE_SITE = -1      # available before the model runs (constants, inputs)
POST_SITE = 1 << 30  # only available after the forward completes

# Pseudo-step indices used by generation traces (see repro.core.generation).
# Decode steps are 0..N-1; the prompt prefill is PREFILL_STEP; a broadcast
# setter (fires at every decode step) is ALL_STEPS; constants/inputs and
# pure functions thereof are PRE_STEP (available at any step).
PREFILL_STEP = -1
ALL_STEPS = -2
PRE_STEP = -3

# Reserved ``Node.meta`` key holding the user source line captured at trace
# time ("file.py:12: x = y + z") — surfaced by preflight diagnostics
# (:mod:`repro.core.analysis`).  Excluded from :func:`node_fingerprint` and
# from the serving engine's structural key: provenance is not structure, and
# two users running the same experiment from different files must still
# share one compiled executable.
SOURCE_META_KEY = "src"


class GraphValidationError(ValueError):
    """Raised when an intervention graph violates the paper's validity rules."""


@dataclasses.dataclass(frozen=True)
class Ref:
    """A reference to another node's output (a variable-node edge)."""

    node_id: int

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"%{self.node_id}"


@dataclasses.dataclass
class Node:
    """One apply node. ``op`` names an entry in the op registry or a protocol.

    Protocol ops (executed by the interleaver, not the registry):
      * ``tap_get``   — read the value at ``site``.
      * ``tap_set``   — replace the value at ``site`` with ``args[0]``.
      * ``input``     — a named experiment input provided at execution time.
      * ``constant``  — a literal embedded in the graph (in ``args[0]``).
      * ``save``      — pin ``args[0]`` as a user-visible result (LockProtocol).
      * ``grad_get``  — read d(loss)/d(site value) (GradProtocol).
      * ``log``       — record ``args[0]`` into the execution log.
    """

    id: int
    op: str
    args: tuple
    kwargs: dict
    site: str | None = None
    layer: int | None = None  # for scan-mode per-layer sites
    # Generation-step coordinate (NNsight's .next()/iteration semantics).
    # None in single-forward traces; in a generation trace, tap nodes carry
    # the decode step they fire at (0..N-1), PREFILL_STEP for the prompt
    # forward, or ALL_STEPS for broadcast setters.
    step: int | None = None
    # Multi-invoke coordinate: which tracer invoke (prompt) this node belongs
    # to.  None in single-invoke traces and for nodes built outside any
    # invoke context (constants shared by every invoke).
    invoke: int | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    def refs(self) -> Iterator[Ref]:
        yield from _iter_refs(self.args)
        yield from _iter_refs(tuple(self.kwargs.values()))


def _iter_refs(obj: Any) -> Iterator[Ref]:
    if isinstance(obj, Ref):
        yield obj
    elif isinstance(obj, (tuple, list)):
        for item in obj:
            yield from _iter_refs(item)
    elif isinstance(obj, dict):
        for item in obj.values():
            yield from _iter_refs(item)


def map_refs(obj: Any, fn: Callable[[Ref], Any]) -> Any:
    """Structurally map ``fn`` over every Ref in a nested arg structure."""
    if isinstance(obj, Ref):
        return fn(obj)
    if isinstance(obj, tuple):
        return tuple(map_refs(o, fn) for o in obj)
    if isinstance(obj, list):
        return [map_refs(o, fn) for o in obj]
    if isinstance(obj, dict):
        return {k: map_refs(v, fn) for k, v in obj.items()}
    return obj


class InterventionGraph:
    """A serializable experiment: nodes + saves, in topological id order."""

    def __init__(self) -> None:
        self.nodes: list[Node] = []
        # save-name -> node id (the LockProtocol table).
        self.saves: dict[str, int] = {}
        # node id of the scalar loss for the backward pass (GradProtocol).
        self.backward_loss: int | None = None
        # Default invoke coordinate stamped onto new nodes; the tracer sets
        # this while a ``tr.invoke(...)`` context is open so every node built
        # inside it (taps, ops, constants) lands on that invoke.
        self.invoke_default: int | None = None

    # ------------------------------------------------------------------ build
    def add(
        self,
        op: str,
        *args: Any,
        site: str | None = None,
        layer: int | None = None,
        step: int | None = None,
        invoke: int | None = None,
        meta: dict | None = None,
        **kwargs: Any,
    ) -> Node:
        for ref in _iter_refs(args):
            self._check_ref(ref)
        for ref in _iter_refs(tuple(kwargs.values())):
            self._check_ref(ref)
        node = Node(
            id=len(self.nodes),
            op=op,
            args=args,
            kwargs=kwargs,
            site=site,
            layer=layer,
            step=step,
            invoke=invoke if invoke is not None else self.invoke_default,
            meta=meta or {},
        )
        self.nodes.append(node)
        return node

    def _check_ref(self, ref: Ref) -> None:
        if not 0 <= ref.node_id < len(self.nodes):
            raise GraphValidationError(
                f"reference to unknown node %{ref.node_id} "
                f"(graph has {len(self.nodes)} nodes)"
            )

    def mark_saved(self, name: str, node: Node) -> None:
        self.saves[name] = node.id

    # ------------------------------------------------------------ inspection
    def __len__(self) -> int:
        return len(self.nodes)

    def node(self, node_id: int) -> Node:
        return self.nodes[node_id]

    def listeners(self) -> dict[int, list[int]]:
        """node id -> ids of nodes that consume it (paper's listener count)."""
        out: dict[int, list[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for ref in n.refs():
                out[ref.node_id].append(n.id)
        return out

    def sites_used(self) -> set[str]:
        return {n.site for n in self.nodes if n.site is not None}

    # ------------------------------------------------------------ validation
    def schedule(
        self, site_order: list[tuple[str, int | None]]
    ) -> dict[int, int]:
        """Assign every node the earliest site index at which it can run.

        ``site_order`` is the model's tap-site execution order as
        ``(site_name, layer)`` keys (layer is None for non-layered sites).
        Returns node id -> site index (PRE_SITE for pre-model, POST_SITE for
        gradient values that only exist after the backward pass).
        Raises GraphValidationError on the paper's setter-cycle rule.
        """
        site_index = {key: i for i, key in enumerate(site_order)}
        ready: dict[int, int] = {}
        for n in self.nodes:
            key = (n.site, n.layer)
            if n.op in ("tap_get", "grad_get"):
                if key not in site_index:
                    raise GraphValidationError(
                        f"node %{n.id} taps unknown site {key!r}"
                    )
                # grad values only exist after the backward pass -> POST.
                ready[n.id] = (
                    site_index[key] if n.op == "tap_get" else POST_SITE
                )
            elif n.op in ("constant", "input"):
                ready[n.id] = PRE_SITE
            else:
                dep_sites = [ready[r.node_id] for r in n.refs()]
                ready[n.id] = max(dep_sites, default=PRE_SITE)
            if n.op == "tap_set":
                if key not in site_index:
                    raise GraphValidationError(
                        f"setter %{n.id} targets unknown site {key!r}"
                    )
                target = site_index[key]
                if ready[n.id] > target:
                    # The paper's acyclicity rule: a setter may not depend on
                    # a value produced later in model execution.
                    raise GraphValidationError(
                        f"setter %{n.id} at site {key!r} (index {target}) "
                        f"depends on values only ready at index {ready[n.id]}"
                    )
                ready[n.id] = target
        return ready

    def validate(self, site_order: list[tuple[str, int | None]]) -> None:
        self.schedule(site_order)
        for name, nid in self.saves.items():
            if not 0 <= nid < len(self.nodes):
                raise GraphValidationError(
                    f"save {name!r} references unknown node %{nid}"
                )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        lines = [f"InterventionGraph({len(self.nodes)} nodes)"]
        for n in self.nodes:
            tag = f" @{n.site}" if n.site else ""
            if n.layer is not None:
                tag += f"[layer={n.layer}]"
            if n.step is not None:
                tag += f"[step={n.step}]"
            if n.invoke is not None:
                tag += f"[invoke={n.invoke}]"
            lines.append(f"  %{n.id} = {n.op}{tag} {n.args!r}")
        if self.saves:
            lines.append(f"  saves: {self.saves}")
        return "\n".join(lines)


def _freeze_value(obj: Any) -> Any:
    """Hashable, ==-comparable form of a node arg/kwarg value.

    Arrays compare by CONTENT (dtype, shape, bytes): two nodes whose raw
    array args hold equal values fingerprint equal, differing values do not
    — the fused decode planner relies on this to decide whether one
    compiled step can serve several decode steps.
    """
    if isinstance(obj, Ref):
        return ("__ref__", obj.node_id)
    if obj is Ellipsis:
        return "__ellipsis__"
    if isinstance(obj, slice):
        return ("__slice__", obj.start, obj.stop, obj.step)
    if isinstance(obj, (tuple, list)):
        return ("__seq__",) + tuple(_freeze_value(o) for o in obj)
    if isinstance(obj, dict):
        return ("__map__",) + tuple(
            sorted((str(k), _freeze_value(v)) for k, v in obj.items())
        )
    if obj is None or isinstance(obj, (bool, int, float, str, bytes)):
        return obj
    import numpy as _np

    arr = _np.asarray(obj)
    return ("__array__", arr.dtype.name, arr.shape,
            _np.ascontiguousarray(arr).tobytes())


def node_fingerprint(node: Node, *, abstract_constants: bool = False) -> Any:
    """Structural identity of one node, EXCLUDING its step coordinate.

    Used by the fused-decode planner (:mod:`repro.core.generation`) to test
    whether per-step slice graphs are structurally identical — the step
    stamp is scheduling metadata, not structure.  With
    ``abstract_constants`` a ``constant`` node's value collapses to its
    (dtype, shape): the planner threads differing per-step constant values
    through the scan as stacked inputs, so they need not match.
    """
    if node.op == "constant" and abstract_constants:
        import numpy as _np

        arr = _np.asarray(node.args[0])
        args: Any = (("__const_spec__", arr.dtype.name, arr.shape),)
    else:
        args = _freeze_value(node.args)
    meta = {
        k: v for k, v in node.meta.items() if k != SOURCE_META_KEY
    }
    return (
        node.op,
        node.site,
        node.layer,
        node.invoke,
        args,
        _freeze_value(node.kwargs),
        _freeze_value(meta),
    )


def assign_steps(graph: InterventionGraph, n_steps: int) -> dict[int, int]:
    """Assign every node the earliest generation step at which it can run.

    The multi-token analogue of :meth:`InterventionGraph.schedule`: a
    generation trace executes the model ``1 + n_steps`` times (one prompt
    prefill, ``n_steps`` decode steps) and every node must be placed on one
    of those executions.  Returns node id -> step, where step is
    ``PRE_STEP`` (available at any step: constants, inputs, and pure
    functions thereof), ``PREFILL_STEP``, or a decode step in
    ``[0, n_steps)``.  ``ALL_STEPS`` setters stay at ``ALL_STEPS``.

    Validity rules (the paper's setter-acyclicity rule lifted to steps):
      * a tap node must carry a concrete step (the tracer stamps it);
      * an op's step is the max of its dependencies' steps;
      * a setter at step s may not depend on values first available at a
        LATER step (within-step site ordering is validated per step by the
        interleaver);
      * ``ALL_STEPS`` values (broadcast reads/writes and ops between them)
        are *replicated* into every decode step; they may not mix with
        single-step values and may not be saved — read each step explicitly
        with ``steps()`` to collect per-step values.
    """
    ready: dict[int, int] = {}
    for n in graph.nodes:
        if n.op in ("constant", "input"):
            ready[n.id] = PRE_STEP
            continue
        if n.op in ("tap_get", "tap_set", "grad_get"):
            if n.step is None:
                raise GraphValidationError(
                    f"node %{n.id} taps ({n.site!r}, layer={n.layer}) with "
                    "no step; generation-trace taps must be made inside "
                    "tracer.steps()/step(s)/prefill()/all_steps()"
                )
            if n.step != ALL_STEPS and not (
                PREFILL_STEP <= n.step < n_steps
            ):
                raise GraphValidationError(
                    f"node %{n.id} targets step {n.step}, outside "
                    f"[{PREFILL_STEP}, {n_steps})"
                )
        dep_steps = [ready[r.node_id] for r in n.refs()]
        broadcast = ALL_STEPS in dep_steps
        concrete = [d for d in dep_steps if d not in (PRE_STEP, ALL_STEPS)]
        if broadcast and concrete:
            raise GraphValidationError(
                f"node %{n.id} mixes an all_steps() value with a "
                "single-step value; broadcast chains may only touch "
                "constants/inputs"
            )
        avail = ALL_STEPS if broadcast else max(concrete, default=PRE_STEP)
        if n.op in ("tap_get", "grad_get"):
            # grad_get places like a getter: the gradient materializes on
            # the same execution the loss (validated by the interleaver to
            # sit in the same slice) is computed on.
            ready[n.id] = n.step
        elif n.op == "tap_set":
            target = n.step
            if target == ALL_STEPS:
                if avail not in (PRE_STEP, ALL_STEPS):
                    raise GraphValidationError(
                        f"all_steps() setter %{n.id} depends on a "
                        "single-step value; broadcast writes must be "
                        "functions of constants/inputs or broadcast reads"
                    )
                ready[n.id] = ALL_STEPS
            else:
                if avail == ALL_STEPS:
                    raise GraphValidationError(
                        f"setter %{n.id} at step {target} consumes an "
                        "all_steps() value; broadcast values only feed "
                        "all_steps() writes"
                    )
                if avail > target:
                    raise GraphValidationError(
                        f"setter %{n.id} at step {target} depends on values "
                        f"only available at step {avail} (writes cannot "
                        "flow backwards in decode time)"
                    )
                ready[n.id] = target
        else:
            if broadcast and (
                n.op in ("save", "log") or n.id in graph.saves.values()
            ):
                raise GraphValidationError(
                    f"%{n.id}: all_steps() values cannot be saved/logged "
                    "(ambiguous step); iterate steps() to collect per-step"
                )
            ready[n.id] = avail
    return ready
