"""Intervention graph IR — the paper's core data structure (§3.1).

The paper formalizes an experiment as a bipartite computation graph
``C' = (V', A', E')`` plus *getter* edges (model activation -> experiment op)
and *setter* edges (experiment value -> model graph).  Here the IR is a flat
list of :class:`Node` records; variable nodes are implicit (every apply node
has exactly one output, the paper's Appendix E many-to-one form).  Getters are
``tap_get`` nodes, setters are ``tap_set`` nodes; everything else is a pure op
from the registry (:mod:`repro.core.op_registry`).

Acyclicity is *by construction*: a node may only reference nodes with smaller
ids, so node-id order is a topological order.  The paper's validity rule
("no directed path from a setter's apply node back to a getter's variable
node") becomes a *site-schedule* check: every node is assigned the earliest
model tap site at which all of its dependencies are available, and a
``tap_set`` at site S must be computable no later than S.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

__all__ = [
    "Ref",
    "Node",
    "InterventionGraph",
    "GraphValidationError",
    "PRE_SITE",
    "POST_SITE",
]

# Pseudo-site indices used by the scheduler.
PRE_SITE = -1      # available before the model runs (constants, inputs)
POST_SITE = 1 << 30  # only available after the forward completes


class GraphValidationError(ValueError):
    """Raised when an intervention graph violates the paper's validity rules."""


@dataclasses.dataclass(frozen=True)
class Ref:
    """A reference to another node's output (a variable-node edge)."""

    node_id: int

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"%{self.node_id}"


@dataclasses.dataclass
class Node:
    """One apply node. ``op`` names an entry in the op registry or a protocol.

    Protocol ops (executed by the interleaver, not the registry):
      * ``tap_get``   — read the value at ``site``.
      * ``tap_set``   — replace the value at ``site`` with ``args[0]``.
      * ``input``     — a named experiment input provided at execution time.
      * ``constant``  — a literal embedded in the graph (in ``args[0]``).
      * ``save``      — pin ``args[0]`` as a user-visible result (LockProtocol).
      * ``grad_get``  — read d(loss)/d(site value) (GradProtocol).
      * ``log``       — record ``args[0]`` into the execution log.
    """

    id: int
    op: str
    args: tuple
    kwargs: dict
    site: str | None = None
    layer: int | None = None  # for scan-mode per-layer sites
    meta: dict = dataclasses.field(default_factory=dict)

    def refs(self) -> Iterator[Ref]:
        yield from _iter_refs(self.args)
        yield from _iter_refs(tuple(self.kwargs.values()))


def _iter_refs(obj: Any) -> Iterator[Ref]:
    if isinstance(obj, Ref):
        yield obj
    elif isinstance(obj, (tuple, list)):
        for item in obj:
            yield from _iter_refs(item)
    elif isinstance(obj, dict):
        for item in obj.values():
            yield from _iter_refs(item)


def map_refs(obj: Any, fn: Callable[[Ref], Any]) -> Any:
    """Structurally map ``fn`` over every Ref in a nested arg structure."""
    if isinstance(obj, Ref):
        return fn(obj)
    if isinstance(obj, tuple):
        return tuple(map_refs(o, fn) for o in obj)
    if isinstance(obj, list):
        return [map_refs(o, fn) for o in obj]
    if isinstance(obj, dict):
        return {k: map_refs(v, fn) for k, v in obj.items()}
    return obj


class InterventionGraph:
    """A serializable experiment: nodes + saves, in topological id order."""

    def __init__(self) -> None:
        self.nodes: list[Node] = []
        # save-name -> node id (the LockProtocol table).
        self.saves: dict[str, int] = {}
        # node id of the scalar loss for the backward pass (GradProtocol).
        self.backward_loss: int | None = None

    # ------------------------------------------------------------------ build
    def add(
        self,
        op: str,
        *args: Any,
        site: str | None = None,
        layer: int | None = None,
        meta: dict | None = None,
        **kwargs: Any,
    ) -> Node:
        for ref in _iter_refs(args):
            self._check_ref(ref)
        for ref in _iter_refs(tuple(kwargs.values())):
            self._check_ref(ref)
        node = Node(
            id=len(self.nodes),
            op=op,
            args=args,
            kwargs=kwargs,
            site=site,
            layer=layer,
            meta=meta or {},
        )
        self.nodes.append(node)
        return node

    def _check_ref(self, ref: Ref) -> None:
        if not 0 <= ref.node_id < len(self.nodes):
            raise GraphValidationError(
                f"reference to unknown node %{ref.node_id} "
                f"(graph has {len(self.nodes)} nodes)"
            )

    def mark_saved(self, name: str, node: Node) -> None:
        self.saves[name] = node.id

    # ------------------------------------------------------------ inspection
    def __len__(self) -> int:
        return len(self.nodes)

    def node(self, node_id: int) -> Node:
        return self.nodes[node_id]

    def listeners(self) -> dict[int, list[int]]:
        """node id -> ids of nodes that consume it (paper's listener count)."""
        out: dict[int, list[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for ref in n.refs():
                out[ref.node_id].append(n.id)
        return out

    def sites_used(self) -> set[str]:
        return {n.site for n in self.nodes if n.site is not None}

    # ------------------------------------------------------------ validation
    def schedule(
        self, site_order: list[tuple[str, int | None]]
    ) -> dict[int, int]:
        """Assign every node the earliest site index at which it can run.

        ``site_order`` is the model's tap-site execution order as
        ``(site_name, layer)`` keys (layer is None for non-layered sites).
        Returns node id -> site index (PRE_SITE for pre-model, POST_SITE for
        gradient values that only exist after the backward pass).
        Raises GraphValidationError on the paper's setter-cycle rule.
        """
        site_index = {key: i for i, key in enumerate(site_order)}
        ready: dict[int, int] = {}
        for n in self.nodes:
            key = (n.site, n.layer)
            if n.op in ("tap_get", "grad_get"):
                if key not in site_index:
                    raise GraphValidationError(
                        f"node %{n.id} taps unknown site {key!r}"
                    )
                # grad values only exist after the backward pass -> POST.
                ready[n.id] = (
                    site_index[key] if n.op == "tap_get" else POST_SITE
                )
            elif n.op in ("constant", "input"):
                ready[n.id] = PRE_SITE
            else:
                dep_sites = [ready[r.node_id] for r in n.refs()]
                ready[n.id] = max(dep_sites, default=PRE_SITE)
            if n.op == "tap_set":
                if key not in site_index:
                    raise GraphValidationError(
                        f"setter %{n.id} targets unknown site {key!r}"
                    )
                target = site_index[key]
                if ready[n.id] > target:
                    # The paper's acyclicity rule: a setter may not depend on
                    # a value produced later in model execution.
                    raise GraphValidationError(
                        f"setter %{n.id} at site {key!r} (index {target}) "
                        f"depends on values only ready at index {ready[n.id]}"
                    )
                ready[n.id] = target
        return ready

    def validate(self, site_order: list[tuple[str, int | None]]) -> None:
        self.schedule(site_order)
        for name, nid in self.saves.items():
            if not 0 <= nid < len(self.nodes):
                raise GraphValidationError(
                    f"save {name!r} references unknown node %{nid}"
                )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        lines = [f"InterventionGraph({len(self.nodes)} nodes)"]
        for n in self.nodes:
            tag = f" @{n.site}" if n.site else ""
            if n.layer is not None:
                tag += f"[layer={n.layer}]"
            lines.append(f"  %{n.id} = {n.op}{tag} {n.args!r}")
        if self.saves:
            lines.append(f"  saves: {self.saves}")
        return "\n".join(lines)
