"""Intervention-aware generation: interleave a step-annotated graph with a
multi-token decode loop.

The paper's NNsight traces *generation*, not just single forwards (§3.2,
multi-invoke/``.next()`` semantics): users read and write activations at
every decoded token.  This module is the execution engine behind
``lm.generate(tokens, max_new_tokens=N)`` (:mod:`repro.core.tracer`) and the
serving engine's graph-bearing generation path.

Execution model
---------------
A generation request runs the model ``1 + N`` times::

    prefill(tokens[:, :-1])                # step PREFILL_STEP (-1)
    decode_step(tokens[:, -1],  pos=S-1)   # step 0 -> logits for new tok 0
    decode_step(new_tok_0,      pos=S)     # step 1 -> logits for new tok 1
    ...                                    # step N-1

The prompt's last token goes through the *decode* path so every decode step
has identical shapes — per-step values are ``(B, 1, ...)`` and stack to
``(B, N, ...)`` — and step 0 is interveneable like any other step.

The step-annotated intervention graph (``Node.step``) is *sliced* into one
sub-graph per model execution (:func:`slice_steps`): each slice keeps that
step's tap nodes plus the op nodes first ready at that step; values flowing
across steps become ``input`` nodes bound from a persistent environment, and
values needed later are exported as internal saves.  Each slice then runs
through the ordinary single-forward interleaver
(:func:`repro.core.interleave.run_interleaved`), so site scheduling, scan
mode, and setter validation are inherited unchanged.  Steps whose slice is
empty take a caller-provided fast path (the serving engine passes its cached
compiled prefill/decode functions, so uninstrumented steps never retrace).

Greedy sampling reads the *post-intervention* logits: a setter on the
``logits`` site (or anything upstream) steers which token is fed back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import (
    ALL_STEPS,
    PRE_STEP,
    PREFILL_STEP,
    GraphValidationError,
    InterventionGraph,
    Node,
    Ref,
    assign_steps,
    map_refs,
)
from repro.core.interleave import SiteSchedule, run_interleaved

__all__ = ["StepSlice", "slice_steps", "run_generation", "GenerationResult"]

_ENV = "__env%d"  # import/export name for a cross-step value (by orig id)


@dataclasses.dataclass
class StepSlice:
    """The sub-graph of one model execution inside a generation trace."""

    step: int
    graph: InterventionGraph
    imports: dict[str, int]  # input name -> ORIGINAL node id (bound from env)
    exports: dict[str, int]  # save name  -> ORIGINAL node id (put into env)

    def is_empty(self) -> bool:
        return not self.graph.nodes


def slice_steps(
    graph: InterventionGraph, n_steps: int
) -> dict[int, StepSlice]:
    """Partition a step-annotated graph into per-execution sub-graphs.

    Returns slices keyed by step (``PREFILL_STEP`` and ``0..n_steps-1``);
    steps with no work are omitted.  Raises
    :class:`~repro.core.graph.GraphValidationError` on cross-step rule
    violations (see :func:`repro.core.graph.assign_steps`).
    """
    ready = assign_steps(graph, n_steps)

    # Which original node ids each step's slice contains.  PRE_STEP nodes
    # (constants/inputs and pure functions of them) are replicated into every
    # slice that uses them — recomputing a handful of scalar ops per step is
    # cheaper than threading them through the environment.
    members: dict[int, set[int]] = {}

    def want(step: int, nid: int) -> None:
        node = graph.node(nid)
        if node.op == "tap_set":  # setters are claimed by their own step
            return
        # PRE_STEP and ALL_STEPS nodes are replicated into any slice that
        # needs them; same-step nodes are included directly.
        if ready[nid] in (step, PRE_STEP, ALL_STEPS):
            if nid in members.setdefault(step, set()):
                return
            members[step].add(nid)
            for r in node.refs():
                want(step, r.node_id)

    for n in graph.nodes:
        s = ready[n.id]
        if s == PRE_STEP:
            # Pure functions of constants are pulled in on demand by want();
            # but a user-visible save/log of one must still execute somewhere
            # — pin it to the prefill execution.
            if n.op not in ("save", "log") and n.id not in graph.saves.values():
                continue
            s = PREFILL_STEP
        steps = (
            list(range(n_steps)) if s == ALL_STEPS else [s]
        )
        for step in steps:
            members.setdefault(step, set()).add(n.id)
            for r in n.refs():
                want(step, r.node_id)

    # Cross-step edges: node produced at step s, consumed at step s' > s
    # (imports pull from the persistent env; exports feed it).
    needs_export: set[int] = set()
    for n in graph.nodes:
        s = ready[n.id]
        if s == PRE_STEP:
            continue
        for r in n.refs():
            rs = ready[r.node_id]
            if rs not in (PRE_STEP, s) and rs != ALL_STEPS:
                needs_export.add(r.node_id)

    slices: dict[int, StepSlice] = {}
    for step in sorted(members):
        ids = sorted(members[step])
        sub = InterventionGraph()
        idmap: dict[int, int] = {}
        imports: dict[str, int] = {}
        exports: dict[str, int] = {}

        def local_ref(ref: Ref) -> Ref:
            nid = ref.node_id
            if nid in idmap:
                return Ref(idmap[nid])
            # produced at an earlier step: import from the environment
            name = _ENV % nid
            inp = sub.add("input", name)
            imports[name] = nid
            idmap[nid] = inp.id
            return Ref(inp.id)

        for nid in ids:
            n = graph.node(nid)
            new = sub.add(
                n.op,
                *map_refs(n.args, local_ref),
                site=n.site,
                layer=n.layer,
                step=n.step,
                meta=dict(n.meta),
                **map_refs(n.kwargs, local_ref),
            )
            idmap[nid] = new.id
            if nid in needs_export:
                name = _ENV % nid
                sv = sub.add("save", Ref(new.id))
                sub.mark_saved(name, sv)
                exports[name] = nid

        # user saves whose save node lives in this slice
        for name, nid in graph.saves.items():
            if nid in idmap:
                sub.saves[name] = idmap[nid]

        slices[step] = StepSlice(
            step=step, graph=sub, imports=imports, exports=exports
        )
    return slices


@dataclasses.dataclass
class GenerationResult:
    tokens: Any  # (B, N) generated token ids
    logits: Any  # (B, 1, V) post-intervention logits of the LAST step
    saves: dict[str, Any]
    logs: list


def _step_order(schedule: SiteSchedule) -> SiteSchedule:
    """The per-execution tap-site order (drop the wrapper-only 'output')."""
    order = [k for k in schedule.order if k[0] != "output"]
    return SiteSchedule(order, schedule.scan_sites, schedule.n_layers)


def run_generation(
    model: Any,
    params: Any,
    graph: InterventionGraph,
    tokens: jax.Array,
    max_new_tokens: int,
    *,
    mode: str = "unrolled",
    extras: dict | None = None,
    inputs: dict[str, Any] | None = None,
    prefill_fn: Callable | None = None,
    decode_fn: Callable | None = None,
    empty_cache_fn: Callable | None = None,
    cache_kind: str = "full",
    lengths: Any | None = None,
) -> GenerationResult:
    """Greedy-decode ``max_new_tokens`` with ``graph`` interleaved.

    ``model`` is a zoo model object (``prefill`` / ``decode_step`` /
    ``site_schedule``).  ``prefill_fn(params, batch, max_len)`` and
    ``decode_fn(params, cache, token, pos)`` are optional fast paths used
    for steps with no interventions (the serving engine passes its cached
    jitted functions); instrumented steps always run through
    :func:`run_interleaved`.

    ``lengths`` (B,) gives each row's TRUE prompt length for right-padded
    ragged batches: prefill masks padding (sentinel cache positions, dt=0
    SSD scans), each row's LAST REAL token is decoded as step 0 at its own
    position, and decode step ``t`` runs at ``lengths - 1 + t`` per row —
    so prompts of different lengths share ONE prefill and ONE decode loop.

    A single-token prompt (``S == 1``) skips prefill entirely: the cache is
    initialized empty (``model.empty_cache``) and the whole prompt is
    decoded as step 0.  Graphs tapping ``prefill()`` therefore require
    prompts of >= 2 tokens.
    """
    extras = dict(extras or {})
    B, S = tokens.shape
    if S < 1:
        raise ValueError("generation requires a non-empty prompt")
    N = int(max_new_tokens)
    if N < 1:
        raise ValueError("max_new_tokens must be >= 1")
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        if lengths.shape != (B,):
            raise ValueError(f"lengths must be shape ({B},), got {lengths.shape}")

    slices = slice_steps(graph, N)
    schedule = _step_order(model.site_schedule(mode))
    # Families whose prefill runs a Python layer loop (hybrid, enc-dec) fire
    # taps eagerly per layer — scan-site scheduling would mis-place them, so
    # the prefill slice is forced onto the unrolled schedule (decode_step
    # uses lax.scan in scan mode for every family and stays as requested).
    pre_mode = mode
    pre_schedule = schedule
    if mode == "scan" and not getattr(model, "scan_prefill", True):
        pre_mode = "unrolled"
        pre_schedule = _step_order(model.site_schedule("unrolled"))
    max_len = S - 1 + N if S > 1 else N

    env: dict[int, Any] = {}
    saves: dict[str, Any] = {}
    logs: list = []

    def run_slice(sl: StepSlice, model_fn, args: tuple,
                  sl_schedule: SiteSchedule, sl_mode: str) -> Any:
        sl.graph.validate(sl_schedule.order)
        bound = {name: env[nid] for name, nid in sl.imports.items()}
        if inputs:
            for n in sl.graph.nodes:
                if n.op == "input" and not n.args[0].startswith("__env"):
                    bound[n.args[0]] = inputs[n.args[0]]
        out, sl_saves, sl_logs = run_interleaved(
            model_fn, sl.graph, sl_schedule, args, {}, mode=sl_mode,
            inputs=bound,
        )
        for name, nid in sl.exports.items():
            env[nid] = sl_saves.pop(name)
        saves.update(sl_saves)
        logs.extend(sl_logs)
        return out

    # ------------------------------------------------------------- prefill
    pre_slice = slices.get(PREFILL_STEP)
    if S == 1:
        if pre_slice is not None:
            raise GraphValidationError(
                "prefill() taps require a prompt of >= 2 tokens; a "
                "single-token prompt has no prefill execution (the whole "
                "prompt is decoded as step 0)"
            )
        make_cache = empty_cache_fn or model.empty_cache
        cache = make_cache(params, extras, B, max_len, cache_kind)
    else:
        prompt = {"tokens": tokens[:, :-1], **extras}
        if lengths is not None:
            prompt["lengths"] = lengths - 1
        if pre_slice is None and prefill_fn is not None:
            out, cache = prefill_fn(params, prompt, max_len)
        elif pre_slice is None:
            out, cache = model.prefill(
                params, prompt, mode=mode, kind=cache_kind, max_len=max_len
            )
        else:
            def pre_fn(params_, batch_):
                return model.prefill(
                    params_, batch_, mode=pre_mode, kind=cache_kind,
                    max_len=max_len,
                )

            out, cache = run_slice(
                pre_slice, pre_fn, (params, prompt), pre_schedule, pre_mode
            )

    # -------------------------------------------------------------- decode
    def plain_decode(params_, cache_, token_, pos_):
        if decode_fn is not None:
            return decode_fn(params_, cache_, token_, pos_)
        return model.decode_step(
            params_, cache_, {"token": token_, "pos": pos_}, mode=mode
        )

    if lengths is None:
        token = tokens[:, -1:]
        base_pos = jnp.full((B,), S - 1, jnp.int32)
    else:
        # each row's LAST REAL token, decoded as step 0 at its own position
        token = jnp.take_along_axis(tokens, (lengths - 1)[:, None], axis=1)
        base_pos = lengths - 1
    new_tokens = []
    logits = None
    for t in range(N):
        pos = base_pos + t
        sl = slices.get(t)
        if sl is None or sl.is_empty():
            out, cache = plain_decode(params, cache, token, pos)
        else:
            def step_fn(params_, cache_, token_, pos_):
                return model.decode_step(
                    params_, cache_, {"token": token_, "pos": pos_},
                    mode=mode,
                )

            out, cache = run_slice(
                sl, step_fn, (params, cache, token, pos), schedule, mode
            )
        logits = out["logits"]
        token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        new_tokens.append(token[:, 0])

    return GenerationResult(
        tokens=jnp.stack(new_tokens, axis=1),
        logits=logits,
        saves=saves,
        logs=logs,
    )


def stack_step_saves(
    per_step: dict[int, Any], axis: int = 1
) -> Any:
    """Stack one save name's per-step values in step order.

    Values shaped ``(B, 1, ...)`` (token-axis singletons, the common case
    for decode-step activations) concatenate along the token axis to
    ``(B, n_steps, ...)``; anything else stacks along a new leading axis.
    """
    steps = sorted(per_step)
    vals = [per_step[s] for s in steps]

    def stack(*xs):
        if all(
            hasattr(x, "ndim") and x.ndim >= 2 and x.shape[1] == 1
            for x in xs
        ):
            return jnp.concatenate(xs, axis=axis)
        return jnp.stack(xs, axis=0)

    return jax.tree.map(stack, *vals)
