"""Intervention-aware generation: interleave a step-annotated graph with a
multi-token decode loop.

The paper's NNsight traces *generation*, not just single forwards (§3.2,
multi-invoke/``.next()`` semantics): users read and write activations at
every decoded token.  This module is the execution engine behind
``lm.generate(tokens, max_new_tokens=N)`` (:mod:`repro.core.tracer`) and the
serving engine's graph-bearing generation path.

Execution model
---------------
A generation request runs the model ``1 + N`` times::

    prefill(tokens[:, :-1])                # step PREFILL_STEP (-1)
    decode_step(tokens[:, -1],  pos=S-1)   # step 0 -> logits for new tok 0
    decode_step(new_tok_0,      pos=S)     # step 1 -> logits for new tok 1
    ...                                    # step N-1

The prompt's last token goes through the *decode* path so every decode step
has identical shapes — per-step values are ``(B, 1, ...)`` and stack to
``(B, N, ...)`` — and step 0 is interveneable like any other step.

The step-annotated intervention graph (``Node.step``) is *sliced* into one
sub-graph per model execution (:func:`slice_steps`): each slice keeps that
step's tap nodes plus the op nodes first ready at that step; values flowing
across steps become ``input`` nodes bound from a persistent environment, and
values needed later are exported as internal saves.  Each slice then runs
through the ordinary single-forward interleaver
(:func:`repro.core.interleave.run_interleaved`), so site scheduling, scan
mode, and setter validation are inherited unchanged.  Steps whose slice is
empty take a caller-provided fast path (the serving engine passes its cached
compiled prefill/decode functions, so uninstrumented steps never retrace).

Greedy sampling reads the *post-intervention* logits: a setter on the
``logits`` site (or anything upstream) steers which token is fed back.

Fused decode
------------
When a generation graph is *step-uniform* — no step-dependent slice
structure: uninstrumented, ``all_steps()``-only, or identical site/op sets
at every step (:func:`steps_uniform`) — the decode loop lowers into ONE
``lax.scan`` program (:func:`make_fused_step`): the scan body is the
interleaved decode step, per-step saves come back pre-stacked as scan ys,
and the greedy token feedback plus cache thread through the carry.  N host
dispatches + N Python re-merges become one dispatch; the serving engine
caches the compiled program by structural graph signature.  The slot-table
loop fuses every step-uniform stretch between admission/retirement
boundaries (:meth:`DecodeLoop.step_fused`); non-uniform remainders run as
length-1 windows of the SAME compiled machinery — window splits are
bit-identical, so co-tenancy changes windowing but never a request's
numerics.  Only ``log`` nodes, failed fused compiles, and ``fused=False``
take the unjitted eager per-step path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (
    ALL_STEPS,
    PRE_STEP,
    PREFILL_STEP,
    GraphValidationError,
    InterventionGraph,
    Node,
    Ref,
    assign_steps,
    map_refs,
    node_fingerprint,
)
from repro.core.interleave import (
    SiteSchedule,
    make_step_callable,
    run_interleaved,
)

__all__ = [
    "StepSlice",
    "slice_steps",
    "steps_uniform",
    "make_fused_step",
    "run_generation",
    "run_generation_invokes",
    "GenerationResult",
    "DecodeLoop",
    "SlotRequest",
    "SlotAllocationError",
]

_ENV = "__env%d"  # import/export name for a cross-step value (by orig id)

_faults_fire: Callable[[str], None] | None = None


def _fault(point: str) -> None:
    """Fault-injection hook (repro.serving.faults) for the core decode
    loop's instrumented sites — prefill dispatch, decode windows, page
    allocation.  Imported lazily: core must not import the serving package
    at module load (serving imports core), and the deferred bind keeps the
    disabled-path cost at one global check + one call per WINDOW, not per
    token."""
    global _faults_fire
    if _faults_fire is None:
        from repro.serving.faults import fire
        _faults_fire = fire
    _faults_fire(point)


@dataclasses.dataclass
class StepSlice:
    """The sub-graph of one model execution inside a generation trace."""

    step: int
    graph: InterventionGraph
    imports: dict[str, int]  # input name -> ORIGINAL node id (bound from env)
    exports: dict[str, int]  # save name  -> ORIGINAL node id (put into env)

    def is_empty(self) -> bool:
        return not self.graph.nodes


def slice_steps(
    graph: InterventionGraph, n_steps: int
) -> dict[int, StepSlice]:
    """Partition a step-annotated graph into per-execution sub-graphs.

    Returns slices keyed by step (``PREFILL_STEP`` and ``0..n_steps-1``);
    steps with no work are omitted.  Raises
    :class:`~repro.core.graph.GraphValidationError` on cross-step rule
    violations (see :func:`repro.core.graph.assign_steps`).
    """
    ready = assign_steps(graph, n_steps)

    # Which original node ids each step's slice contains.  PRE_STEP nodes
    # (constants/inputs and pure functions of them) are replicated into every
    # slice that uses them — recomputing a handful of scalar ops per step is
    # cheaper than threading them through the environment.
    members: dict[int, set[int]] = {}

    def want(step: int, nid: int) -> None:
        node = graph.node(nid)
        if node.op == "tap_set":  # setters are claimed by their own step
            return
        # PRE_STEP and ALL_STEPS nodes are replicated into any slice that
        # needs them; same-step nodes are included directly.
        if ready[nid] in (step, PRE_STEP, ALL_STEPS):
            if nid in members.setdefault(step, set()):
                return
            members[step].add(nid)
            for r in node.refs():
                want(step, r.node_id)

    for n in graph.nodes:
        s = ready[n.id]
        if s == PRE_STEP:
            # Pure functions of constants are pulled in on demand by want();
            # but a user-visible save/log of one must still execute somewhere
            # — pin it to the prefill execution.
            if n.op not in ("save", "log") and n.id not in graph.saves.values():
                continue
            s = PREFILL_STEP
        steps = (
            list(range(n_steps)) if s == ALL_STEPS else [s]
        )
        for step in steps:
            members.setdefault(step, set()).add(n.id)
            for r in n.refs():
                want(step, r.node_id)

    # Cross-step edges: node produced at step s, consumed at step s' > s
    # (imports pull from the persistent env; exports feed it).
    needs_export: set[int] = set()
    for n in graph.nodes:
        s = ready[n.id]
        if s == PRE_STEP:
            continue
        for r in n.refs():
            rs = ready[r.node_id]
            if rs not in (PRE_STEP, s) and rs != ALL_STEPS:
                needs_export.add(r.node_id)

    slices: dict[int, StepSlice] = {}
    for step in sorted(members):
        ids = sorted(members[step])
        sub = InterventionGraph()
        idmap: dict[int, int] = {}
        imports: dict[str, int] = {}
        exports: dict[str, int] = {}

        def local_ref(ref: Ref) -> Ref:
            nid = ref.node_id
            if nid in idmap:
                return Ref(idmap[nid])
            # produced at an earlier step: import from the environment
            name = _ENV % nid
            inp = sub.add("input", name)
            imports[name] = nid
            idmap[nid] = inp.id
            return Ref(inp.id)

        for nid in ids:
            n = graph.node(nid)
            new = sub.add(
                n.op,
                *map_refs(n.args, local_ref),
                site=n.site,
                layer=n.layer,
                step=n.step,
                meta=dict(n.meta),
                **map_refs(n.kwargs, local_ref),
            )
            idmap[nid] = new.id
            if nid in needs_export:
                name = _ENV % nid
                sv = sub.add("save", Ref(new.id))
                sub.mark_saved(name, sv)
                exports[name] = nid

        # user saves whose save node lives in this slice
        for name, nid in graph.saves.items():
            if nid in idmap:
                sub.saves[name] = idmap[nid]

        # a backward loss landing in this slice makes it a grad slice: the
        # perturbation driver differentiates just this step's forward
        if graph.backward_loss is not None and graph.backward_loss in idmap:
            sub.backward_loss = idmap[graph.backward_loss]

        slices[step] = StepSlice(
            step=step, graph=sub, imports=imports, exports=exports
        )
    return slices


# --------------------------------------------------------------------------
# Fused decode: detect step-uniform schedules and compile the decode loop
# into ONE lax.scan program (the ROADMAP "fused decode" item).
# --------------------------------------------------------------------------

# Fingerprint of a step with no intervention work (slice absent or empty).
_EMPTY_FP = ("__empty__",)


def _slice_fingerprint(sl: StepSlice | None) -> Any | None:
    """Structural identity of one decode-step slice, step stamps excluded.

    Two slices with equal fingerprints execute the same program — one
    compiled step body can serve both, with constant values threaded in as
    runtime arguments (equal-valued raw array args are folded into the
    fingerprint, so a mismatch there forces separate steps).  ``log`` and
    ``grad_get`` slices fingerprint like any other since the harvest-mold
    interpreter lowers both into the compiled body (``jax.debug.callback``
    / the in-trace perturbation driver).
    """
    if sl is None or sl.is_empty():
        return _EMPTY_FP
    nodes = []
    for n in sl.graph.nodes:
        nodes.append(node_fingerprint(n, abstract_constants=True))
    return (
        tuple(nodes),
        tuple(sorted(sl.imports)),
        tuple(sorted(sl.exports)),
        tuple(sorted(sl.graph.saves.values())),
    )


def steps_uniform(graph: InterventionGraph, n_steps: int) -> bool:
    """Is this generation graph *step-uniform* — same slice structure at
    every decode step?

    True for uninstrumented graphs, ``all_steps()``-only graphs, and
    identical per-step site/op sets (e.g. ``for s in tr.steps(): ...`` with
    the same body each iteration); prefill-only instrumentation is uniform
    too (the prefill is not part of the decode loop).  A uniform graph's
    whole decode loop lowers into ONE ``lax.scan`` program — N dispatches
    plus N Python re-merges collapse to one dispatch (see
    :meth:`DecodeLoop.step_fused`).  Differing per-step constant VALUES do
    not break uniformity: they thread through the scan as stacked inputs.
    """
    slices = slice_steps(graph, n_steps)
    fps = [_slice_fingerprint(slices.get(s)) for s in range(n_steps)]
    if any(fp is None for fp in fps):
        return False
    if not fps:
        return True
    if any(fp != fps[0] for fp in fps[1:]):
        return False
    # cross-step env flow needs per-step export/import routing — eager only
    return not any(
        sl is not None and sl.exports
        for sl in (slices.get(s) for s in range(n_steps))
    )


def make_fused_step(
    model: Any,
    graph: InterventionGraph,
    schedule: SiteSchedule,
    n_steps: int,
    *,
    mode: str = "unrolled",
) -> Callable:
    """Build the fused decode program: ``n_steps`` interleaved decode steps
    as ONE ``lax.scan``.

    ``graph`` is the (merged, step-normalized) intervention graph of ONE
    decode step — empty for uninstrumented generation.  The scan body is
    the jit-able interleaved step (:func:`repro.core.interleave
    .make_step_callable`): tap getters/setters apply inside the traced
    body, per-step saves return as scan ys (pre-stacked ``(n_steps, ...)``),
    and the greedy-argmax token feedback plus the cache thread through the
    scan carry — so the whole decode loop is one XLA dispatch instead of
    ``n_steps`` dispatches + ``n_steps`` Python re-merges.

    Returns ``fused(params, cache, token, base_pos, consts, step_consts,
    inputs) -> ((cache, token), ys)`` where ``consts`` maps constant node
    ids to values shared by every step, ``step_consts`` maps constant node
    ids to ``(n_steps, ...)`` stacks of per-step values, and ``ys`` carries
    ``token`` ``(n_steps, B, 1)``, ``logits`` ``(n_steps, B, 1, V)`` and
    ``saves`` (each ``(n_steps, ...)``).  Pure — wrap in ``jax.jit`` and
    cache by the graph's structural key (the serving engine does).
    """

    def step_fn(params_, cache_, token_, pos_):
        return model.decode_step(
            params_, cache_, {"token": token_, "pos": pos_}, mode=mode
        )

    run_step = make_step_callable(step_fn, graph, schedule, mode=mode)

    def fused(params, cache, token, base_pos, consts, step_consts, inputs):
        def body(carry, xs):
            cache_, token_, t = carry
            pos = base_pos + t
            const_env = dict(consts)
            if xs:
                const_env.update(xs)
            (out, new_cache), saves = run_step(
                (params, cache_, token_, pos), {},
                inputs=inputs, const_env=const_env,
            )
            logits = out["logits"]
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[
                :, None
            ]
            return (new_cache, tok, t + 1), {
                "token": tok, "logits": logits, "saves": saves,
            }

        (cache, token, _), ys = jax.lax.scan(
            body,
            (cache, token, jnp.zeros((), jnp.int32)),
            step_consts,
            length=n_steps,
        )
        return (cache, token), ys

    return fused


@dataclasses.dataclass
class _FusedPlan:
    """One fused decode segment, ready to dispatch."""

    key: Any                    # structural graph identity (failure memo)
    graph: InterventionGraph    # merged step-normalized template
    k: int                      # scan length
    # instrumented residents: (request, per-step slices, {slice save node
    # id -> merged wire save name})
    need: list[tuple]
    consts: dict[int, Any]      # constant node id -> shared value
    step_consts: dict[int, Any]  # constant node id -> (k, ...) stack
    inputs: dict[str, Any]
    # per-need [lo, hi) merged node-id ranges: log entries drained from the
    # compiled body carry merged ids and route back by segment, exactly
    # like the eager path's MergedBatch.owner_of
    node_ranges: list = dataclasses.field(default_factory=list)
    # the merged graph carries ops the pre-harvest loop ran eagerly
    # (log / grad_get / cross-layer scan flow) — a compiled island
    island: bool = False


@dataclasses.dataclass
class GenerationResult:
    tokens: Any  # (B, N) generated token ids
    logits: Any  # (B, 1, V) post-intervention logits of the LAST step
    saves: dict[str, Any]
    logs: list


def _step_order(schedule: SiteSchedule) -> SiteSchedule:
    """The per-execution tap-site order (drop the wrapper-only 'output')."""
    order = [k for k in schedule.order if k[0] != "output"]
    return SiteSchedule(order, schedule.scan_sites, schedule.n_layers)


def run_generation(
    model: Any,
    params: Any,
    graph: InterventionGraph,
    tokens: jax.Array,
    max_new_tokens: int,
    *,
    mode: str = "unrolled",
    extras: dict | None = None,
    inputs: dict[str, Any] | None = None,
    prefill_fn: Callable | None = None,
    decode_fn: Callable | None = None,
    empty_cache_fn: Callable | None = None,
    cache_kind: str = "full",
    lengths: Any | None = None,
    fused: bool = True,
    fused_fn: Callable | None = None,
    stats: Any = None,
) -> GenerationResult:
    """Greedy-decode ``max_new_tokens`` with ``graph`` interleaved.

    ``model`` is a zoo model object (``prefill`` / ``decode_step`` /
    ``site_schedule``).  ``prefill_fn(params, batch, max_len)`` and
    ``decode_fn(params, cache, token, pos)`` are optional fast paths used
    for steps with no interventions (the serving engine passes its cached
    jitted functions); instrumented steps always run through
    :func:`run_interleaved`.

    ``lengths`` (B,) gives each row's TRUE prompt length for right-padded
    ragged batches: prefill masks padding (sentinel cache positions, dt=0
    SSD scans), each row's LAST REAL token is decoded as step 0 at its own
    position, and decode step ``t`` runs at ``lengths - 1 + t`` per row —
    so prompts of different lengths share ONE prefill and ONE decode loop.

    A single-token prompt (``S == 1``) skips prefill entirely: the cache is
    initialized empty (``model.empty_cache``) and the whole prompt is
    decoded as step 0.  Graphs tapping ``prefill()`` therefore require
    prompts of >= 2 tokens.

    ``fused=True`` (default) compiles step-uniform stretches of the decode
    loop into ONE ``lax.scan`` dispatch (:meth:`DecodeLoop.step_fused`);
    non-uniform graphs fall back to the eager per-step path unchanged.
    ``fused_fn(graph, n_steps)`` lets a caller supply the compiled-program
    cache (the serving engine keys executables by structural graph
    signature, so a second identically-shaped request compiles nothing).

    Since the continuous-batching refactor this is a thin wrapper: the
    request is admitted into a :class:`DecodeLoop` whose slot table is
    exactly its own rows and stepped to completion — one execution engine
    serves solo runs, burst-merged groups, and in-flight admission alike.
    """
    B, S = tokens.shape
    if S < 1:
        raise ValueError("generation requires a non-empty prompt")
    N = int(max_new_tokens)
    if N < 1:
        raise ValueError("max_new_tokens must be >= 1")
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        if lengths.shape != (B,):
            raise ValueError(f"lengths must be shape ({B},), got {lengths.shape}")

    loop = DecodeLoop(
        model,
        params,
        num_slots=B,
        max_len=S - 1 + N if S > 1 else N,
        mode=mode,
        cache_kind=cache_kind,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        empty_cache_fn=empty_cache_fn,
        fuse=fused,
        fused_fn=fused_fn,
        stats=stats,
    )
    batch = {"tokens": tokens, **(extras or {})}
    if lengths is not None:
        batch["lengths"] = lengths
    sr = loop.admit(graph, batch, N, inputs=inputs)
    loop.run_to_completion()
    return sr.result()


def run_generation_invokes(
    model: Any,
    params: Any,
    items: list[tuple],
    *,
    mode: str = "unrolled",
    cache_kind: str = "full",
    prefill_fn: Callable | None = None,
    decode_fn: Callable | None = None,
    empty_cache_fn: Callable | None = None,
    write_rows_fn: Callable | None = None,
    clear_rows_fn: Callable | None = None,
    stats: Any = None,
    fused: bool = True,
    fused_fn: Callable | None = None,
) -> list[GenerationResult]:
    """Run several generation invokes through ONE slot-table decode loop.

    ``items`` is ``[(graph, batch, max_new_tokens), ...]`` — the lowered
    form of a multi-invoke ``lm.generate()`` trace (each graph is one
    invoke's step-annotated slice, batches may be ragged).  Every invoke is
    admitted as a row-group of one :class:`DecodeLoop` sized to the union
    of rows: multi-token prompts share one merged prefill (ragged widths
    right-padded, saves unpadded to true shapes), single-token prompts are
    admitted alone with an empty cache, and every invoke retires
    independently at its own ``max_new_tokens`` while sharing each decode
    step with the invokes still resident.

    Returns one :class:`GenerationResult` per item, in order, each at its
    solo shapes — parity with running the invokes through separate
    ``run_generation`` calls is bit-exact for causal families.
    """
    if not items:
        return []
    parsed = []
    for graph, batch, n_new in items:
        batch = dict(batch)
        tokens = jnp.asarray(batch["tokens"])
        parsed.append((graph, tokens, batch, int(n_new)))
    widths = [t.shape[1] for _, t, _, _ in parsed]
    num_slots = sum(t.shape[0] for _, t, _, _ in parsed)
    multi_target = max((w for w in widths if w > 1), default=0)
    max_len = max(
        (multi_target - 1 + N) if S > 1 else N
        for (_, _, _, N), S in zip(parsed, widths)
    )
    loop = DecodeLoop(
        model,
        params,
        num_slots=num_slots,
        max_len=max_len,
        mode=mode,
        cache_kind=cache_kind,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        empty_cache_fn=empty_cache_fn,
        write_rows_fn=write_rows_fn,
        clear_rows_fn=clear_rows_fn,
        stats=stats,
        fuse=fused,
        fused_fn=fused_fn,
    )
    # multi-token prompts share one (merged, padded) prefill; single-token
    # prompts have no prefill execution and must be admitted alone
    group = [
        (g, b, N, idx)
        for idx, ((g, _, b, N), w) in enumerate(zip(parsed, widths))
        if w > 1
    ]
    srs: dict[int, SlotRequest] = {}
    if group:
        for sr, (_, _, _, idx) in zip(
            loop.admit_group([(g, b, N, idx) for g, b, N, idx in group]),
            group,
        ):
            srs[idx] = sr
    for idx, ((g, _, b, N), w) in enumerate(zip(parsed, widths)):
        if w == 1:
            srs[idx] = loop.admit(g, b, N, request_id=idx)
    loop.run_to_completion()
    return [srs[i].result() for i in range(len(items))]


# --------------------------------------------------------------------------
# Continuous batching: a persistent slot-table decode loop.
# --------------------------------------------------------------------------

# Position fed for FREE slot rows.  It matches the cache sentinel
# (repro.models.common.PAD_POS): attention masks every key for such a query,
# and the decode-step cache write at slot == pos is out of bounds, which JAX
# scatter semantics DROP — so free rows compute garbage that touches nothing.
_FREE_POS = np.iinfo(np.int32).max // 2


class SlotAllocationError(RuntimeError):
    """The slot table (rows) or page pool is genuinely exhausted RIGHT NOW.

    Distinct from other runtime failures on purpose: the scheduler retries
    the admission at the next step boundary (rows and pages free as
    co-tenants retire), whereas any other exception fails the request's
    ticket.  Carries the structured deficit so a capped-out retry can name
    exactly what was missing (pages/rows requested vs free)."""

    def __init__(self, msg: str, *, rows_requested: int | None = None,
                 rows_free: int | None = None,
                 pages_requested: int | None = None,
                 pages_free: int | None = None) -> None:
        super().__init__(msg)
        self.rows_requested = rows_requested
        self.rows_free = rows_free
        self.pages_requested = pages_requested
        self.pages_free = pages_free

    def deficit(self) -> str:
        """Human-readable deficit summary for ticket diagnostics."""
        parts = []
        if self.pages_requested is not None:
            parts.append(
                f"{self.pages_requested} pages requested, "
                f"{self.pages_free} free"
            )
        if self.rows_requested is not None:
            parts.append(
                f"{self.rows_requested} rows requested, "
                f"{self.rows_free} free"
            )
        return "; ".join(parts) or str(self)


@dataclasses.dataclass
class SlotRequest:
    """One request resident in the slot table of a :class:`DecodeLoop`.

    The request owns batch rows ``[start, start + size)`` of the shared
    cache for its whole lifetime (admission -> retirement); ``t`` is its own
    decode-step index, independent of every co-tenant's.
    """

    request_id: Any
    start: int
    size: int
    max_new_tokens: int
    slices: dict[int, StepSlice]
    inputs: dict[str, Any] | None = None
    env: dict[int, Any] = dataclasses.field(default_factory=dict)
    saves: dict[str, Any] = dataclasses.field(default_factory=dict)
    logs: list = dataclasses.field(default_factory=list)
    # set when the request was EVICTED by a step-time failure of its own
    # intervention graph; result() is unavailable in that case
    error: str | None = None
    # machine-readable eviction class for structured client errors
    # (e.g. "deadline" | "cancelled" | "engine_restart"); None for plain
    # step-time failures
    error_code: str | None = None
    t: int = 0
    base_pos: Any = None  # (size,) int32 — each row's step-0 position
    new_tokens: list = dataclasses.field(default_factory=list)
    last_logits: Any = None
    # Non-contiguous placement: the exact rows this request owns, when the
    # allocator had to fall back from a contiguous run (None -> contiguous
    # [start, start+size)).  ``start`` is then rows[0] for display/stats.
    row_list: np.ndarray | None = None
    # Paged KV bookkeeping (host side): pages allocated per row, and each
    # row's total lifetime page need (allocated + still-reserved).
    pages: dict[int, list[int]] = dataclasses.field(default_factory=dict)
    page_need: dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def rows(self) -> np.ndarray:
        if self.row_list is not None:
            return np.asarray(self.row_list)
        return np.arange(self.start, self.start + self.size)

    @property
    def placement(self) -> int | tuple[int, ...]:
        """Merge-plan start for this request: a plain int offset when the
        rows are one contiguous run (the historical dynamic-slice rewrite,
        preserving compiled-program reuse), else the explicit row tuple
        (index-array gather/scatter rewrites)."""
        r = self.rows
        if len(r) == 0 or np.array_equal(r, np.arange(r[0], r[0] + len(r))):
            return int(self.start)
        return tuple(int(x) for x in r)

    def done(self) -> bool:
        return self.t >= self.max_new_tokens

    def result(self) -> GenerationResult:
        """Per-request result, identical in shape to a solo run's."""
        if self.error is not None:
            raise RuntimeError(
                f"request {self.request_id!r} was evicted: {self.error}"
            )
        return GenerationResult(
            tokens=jnp.stack(self.new_tokens, axis=1),
            logits=self.last_logits,
            saves=self.saves,
            logs=self.logs,
        )


def _row_list_or_none(rows) -> np.ndarray | None:
    """None for a contiguous run (SlotRequest then derives rows from
    start/size, keeping historical reprs and merge rewrites), else the
    explicit row array."""
    rows = np.asarray(rows)
    if np.array_equal(rows, np.arange(rows[0], rows[0] + len(rows))):
        return None
    return rows


def _rows_index(sr: SlotRequest):
    """Cheapest index selecting a request's rows from a batch-axis array:
    a slice when contiguous (no gather), else the row array."""
    if sr.row_list is None:
        return slice(sr.start, sr.start + sr.size)
    return np.asarray(sr.row_list)


class DecodeLoop:
    """A persistent, fixed-capacity decode loop (continuous batching).

    The loop owns ``num_slots`` rows of preallocated cache (shape
    ``(num_slots, max_len, ...)`` — never reshaped, so the compiled decode
    step is traced ONCE) and exposes the vLLM-style lifecycle:

      * :meth:`admit` / :meth:`admit_group` — prefill an arriving request
        (solo, or bucket-merged with simultaneous arrivals) and scatter its
        cache rows into free slots (``model.cache_write_rows``);
      * :meth:`step` — decode ONE token for every resident request, each at
        its own position and local step; requests whose intervention graph
        has work at their current step run through the interleaver with
        their getters/setters rewritten against their slot rows
        (slot-scoped merging, re-sliced whenever membership changes);
      * retirement (inside :meth:`step`) — a row that reaches its own
        ``max_new_tokens`` is cleared (``model.cache_clear_rows``) and its
        slots are immediately reusable, while co-tenants keep decoding.

    Free rows ride along in every decode step at a sentinel position: the
    mask machinery of ragged co-tenancy proves their compute inert, and
    their out-of-bounds cache writes are dropped.  Parity: a request's saves
    and tokens are bit-exact (causal families) vs admitting it alone,
    regardless of what is admitted or retired around it.
    """

    def __init__(
        self,
        model: Any,
        params: Any,
        num_slots: int,
        max_len: int,
        *,
        mode: str = "unrolled",
        cache_kind: str = "full",
        prefill_fn: Callable | None = None,
        decode_fn: Callable | None = None,
        empty_cache_fn: Callable | None = None,
        write_rows_fn: Callable | None = None,
        clear_rows_fn: Callable | None = None,
        stats: Any = None,
        fuse: bool = True,
        fused_fn: Callable | None = None,
        paged: bool = False,
        page_size: int = 16,
        num_pages: int | None = None,
        on_segment: Callable[[int, list["SlotRequest"]], None] | None = None,
    ) -> None:
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.model = model
        self.params = params
        self.num_slots = int(num_slots)
        self.max_len = int(max_len)
        self.mode = mode
        self.cache_kind = cache_kind
        self._prefill_fn = prefill_fn
        self._decode_fn = decode_fn
        self._empty_cache_fn = empty_cache_fn
        self._write_rows_fn = write_rows_fn or model.cache_write_rows
        self._clear_rows_fn = clear_rows_fn or model.cache_clear_rows
        self.stats = stats
        # Segment-boundary hook: called as ``on_segment(k, retired)`` after
        # every decode window (fused or eager) with the number of steps it
        # served and the requests that retired inside it (already off the
        # slot table; every other resident has fresh ``new_tokens`` /
        # ``saves`` / ``logs`` entries).  The live front door streams
        # incremental chunks from here — a driver looping
        # ``step_fused(fusable_steps())`` would otherwise only observe
        # retirement boundaries.
        self.on_segment = on_segment
        self.schedule = _step_order(model.site_schedule(mode))
        # Fused decode: step-uniform stretches of the loop run as ONE
        # lax.scan dispatch.  `fused_fn(graph, n_steps)` supplies the
        # compiled executable (the engine passes its structural-key cache);
        # without one, executables are cached per loop.
        self.fuse = bool(fuse)
        self._fused_fn = fused_fn
        self._fused_cache: dict[Any, Callable] = {}
        self._fused_bad: set[Any] = set()  # keys whose compile/run failed
        # Why the most recent _plan_fused declined (machine-readable: a
        # repro.core.analysis fusion reason or "failed-compile"); None when
        # the last plan fused.
        self.last_fusion_reason: str | None = None
        self.fused_segments = 0
        self.fused_steps = 0
        self.eager_steps = 0
        # Fused segments whose merged graph carries ops the pre-harvest
        # loop HAD to run eagerly (log / grad / cross-layer scan flow) —
        # each one is an island that now compiles.
        self.islands_compiled = 0
        # The slot table is allocated lazily: a whole-table admission (the
        # run_generation solo path) adopts the prefilled cache directly and
        # never pays for a throwaway zero table.
        self.cache = None
        self.token = jnp.zeros((num_slots, 1), jnp.int32)
        self.resident: list[SlotRequest] = []
        self._free = set(range(num_slots))
        self.steps_run = 0
        # ---- paged KV pool (block-table indirection) ---------------------
        # Families with nothing to page (Mamba2's O(1) recurrent state)
        # silently fall back to the dense slot table; the allocator still
        # serves non-contiguous rows either way.
        from repro.models.paged import FIRST_PAGE

        self.page_size = int(page_size)
        self._paged = bool(paged) and hasattr(model, "paged_exclude_keys")
        win = getattr(getattr(model, "cfg", None), "sliding_window", None)
        self._t_ring = (min(self.max_len, int(win))
                        if (cache_kind == "window" and win) else self.max_len)
        self._blocks_per_row = -(-self._t_ring // self.page_size)
        if num_pages is None:
            # default pool: every row can hold a full-length request (the
            # capacity win then comes purely from shorter actual requests)
            num_pages = FIRST_PAGE + self.num_slots * self._blocks_per_row
        self.num_pages = int(num_pages)
        if self._paged and self.num_pages < FIRST_PAGE + 1:
            raise ValueError(
                f"num_pages must be >= {FIRST_PAGE + 1} "
                "(pages 0/1 are reserved null/trash)"
            )
        # lowest-first free list keeps block tables dense near the pool head
        self._free_pages: list[int] = (
            list(range(FIRST_PAGE, self.num_pages)) if self._paged else []
        )
        # pages promised to residents for decode growth but not yet handed
        # out — page-by-page growth can never fail mid-decode
        self._reserved_unalloc = 0
        self._bt_host = (
            np.zeros((self.num_slots, self._blocks_per_row), np.int32)
            if self._paged else None
        )
        self.frag_avoided = 0

    # ------------------------------------------------------------ occupancy
    @property
    def active(self) -> list[SlotRequest]:
        return list(self.resident)

    def free_rows(self) -> int:
        return len(self._free)

    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.num_slots

    def find_run(self, size: int, exclude: set | frozenset = frozenset()
                 ) -> int | None:
        """First-fit contiguous free run of ``size`` rows (or None).

        ``exclude`` marks rows already promised to earlier members of an
        in-flight admission group."""
        run = 0
        for row in range(self.num_slots):
            ok = row in self._free and row not in exclude
            run = run + 1 if ok else 0
            if run == size:
                return row - size + 1
        return None

    def alloc_rows(self, size: int, exclude: set | frozenset = frozenset()
                   ) -> list[int]:
        """Rows for one admission: contiguous first-fit when a run exists
        (those placements keep the historical dynamic-slice merge rewrites
        and their compiled-program reuse), else ANY free rows — the paged
        index-array rewrites lifted the contiguity requirement, so
        fragmentation of the row table no longer rejects admissions."""
        start = self.find_run(size, exclude=exclude)
        if start is not None:
            return list(range(start, start + size))
        free = sorted(r for r in self._free if r not in exclude)
        if len(free) >= size:
            self.frag_avoided += 1
            if self.stats is not None and hasattr(self.stats,
                                                  "record_frag_avoided"):
                self.stats.record_frag_avoided()
            return free[:size]
        raise SlotAllocationError(
            f"slot table exhausted: {size} rows requested, "
            f"{len(free)} free of {self.num_slots}",
            rows_requested=size, rows_free=len(free),
        )

    # ------------------------------------------------------------- paged KV
    @property
    def paged(self) -> bool:
        return self._paged

    def usable_pages(self) -> int:
        from repro.models.paged import FIRST_PAGE

        return max(0, self.num_pages - FIRST_PAGE) if self._paged else 0

    def pages_free(self) -> int:
        return len(self._free_pages)

    def pages_in_use(self) -> int:
        return self.usable_pages() - len(self._free_pages)

    def pages_available(self) -> int:
        """Pages neither allocated nor reserved for resident growth."""
        return len(self._free_pages) - self._reserved_unalloc

    def page_occupancy(self) -> float:
        u = self.usable_pages()
        return self.pages_in_use() / u if u else 0.0

    def request_page_need(self, prompt_len: int, n_new: int) -> int:
        """Lifetime page need of one row: blocks covering every cache slot
        the request will ever write.  Full caches write ``[0, base+N)``;
        sliding-window rings wrap at ``t_ring``, so the frontier clamps
        there (after the wrap, writes land in already-allocated low
        blocks)."""
        base = max(0, int(prompt_len) - 1)
        extent = min(base + int(n_new), self._t_ring)
        return min(self._blocks_per_row, -(-extent // self.page_size))

    def _plan_pages(self, row_lengths_list: list, n_new_list: list[int]
                    ) -> list[list[tuple[int, int]]]:
        """Per-request per-row ``(need, now)`` block counts, after an
        all-or-nothing feasibility check against unreserved free pages.
        Nothing is committed here — allocation happens in ``_install`` and
        is then guaranteed to succeed."""
        # fault point: an injected SlotAllocationError here simulates a
        # page-exhaustion burst — the scheduler requeues the admission for
        # the next boundary exactly as for a genuinely empty pool
        _fault("page.alloc")
        plan: list[list[tuple[int, int]]] = []
        total = 0
        for lens, n_new in zip(row_lengths_list, n_new_list):
            rows_plan = []
            for L in np.asarray(lens).reshape(-1):
                need = self.request_page_need(int(L), n_new)
                if self.cache_kind == "window":
                    # the ring's high blocks can be hit from step 0 (long
                    # prompts land near the wrap point): allocate the whole
                    # lifetime extent up front — rings are small
                    now = need
                else:
                    # blocks covering the prefilled prompt plus the step-0
                    # write; the rest is reserved and allocated page-by-page
                    # as decode crosses block boundaries
                    now = min(need, max(0, int(L) - 1) // self.page_size + 1)
                rows_plan.append((need, now))
                total += need
            plan.append(rows_plan)
        avail = self.pages_available()
        if total > avail:
            raise SlotAllocationError(
                f"page pool exhausted: {total} pages requested, "
                f"{avail} unreserved of {self.usable_pages()} usable",
                pages_requested=total, pages_free=avail,
            )
        return plan

    def _take_page(self) -> int:
        return self._free_pages.pop(0)

    def _sync_block_tables(self) -> None:
        """Value-only device refresh of the block tables — shapes are
        static, so no recompile is ever triggered."""
        from repro.models.paged import with_block_tables

        if self.cache is not None:
            self.cache = with_block_tables(self.cache, self._bt_host)

    def _alloc_request_pages(self, sr: SlotRequest,
                             rows_plan: list[tuple[int, int]]) -> None:
        """Commit one request's page plan: hand out the ``now`` blocks,
        reserve the remainder for growth.  ``_plan_pages`` already proved
        feasibility for the whole admission group."""
        allocated = 0
        for row, (need, now) in zip(sr.rows, rows_plan):
            row = int(row)
            sr.page_need[row] = need
            pages = [self._take_page() for _ in range(now)]
            sr.pages[row] = pages
            self._bt_host[row, :] = 0
            self._bt_host[row, :now] = pages
            self._reserved_unalloc += need - now
            allocated += now
        if self.stats is not None and hasattr(self.stats,
                                              "record_page_alloc"):
            self.stats.record_page_alloc(
                allocated, self.pages_in_use(), self.pages_free()
            )

    def _grow_pages(self, k: int) -> None:
        """Before dispatching a ``k``-step window, extend every resident's
        block table to cover the window's write frontier, drawing from its
        admission-time reservation (so this can never fail)."""
        if not self._paged or self.cache is None:
            return
        changed = False
        grown = 0
        for sr in self.resident:
            base = np.asarray(sr.base_pos).reshape(-1)
            for idx, row in enumerate(sr.rows):
                row = int(row)
                target = int(base[idx]) + min(sr.t + k, sr.max_new_tokens)
                target = min(target, self._t_ring)
                want = min(sr.page_need.get(row, 0),
                           -(-target // self.page_size))
                have = sr.pages.get(row)
                if have is None:
                    continue
                while len(have) < want:
                    page = self._take_page()
                    self._reserved_unalloc -= 1
                    self._bt_host[row, len(have)] = page
                    have.append(page)
                    grown += 1
                    changed = True
        if changed:
            self._sync_block_tables()
            if self.stats is not None and hasattr(self.stats,
                                                  "record_page_alloc"):
                self.stats.record_page_alloc(
                    grown, self.pages_in_use(), self.pages_free()
                )

    def _fixed_extra_widths(self, extras: dict) -> dict[str, int]:
        """Ragged extras the slot table preallocates at a FIXED width
        (enc-dec cross K/V at ``cfg.n_source_frames``): partial admissions
        must pad to it so their cache rows scatter into the table."""
        out: dict[str, int] = {}
        nsf = getattr(getattr(self.model, "cfg", None),
                      "n_source_frames", None)
        if nsf and "src_embeds" in extras:
            if int(np.asarray(extras["src_embeds"]).shape[1]) != int(nsf):
                out["src_embeds"] = int(nsf)
        return out

    def _validate_slices(self, slices: dict[int, StepSlice]) -> None:
        """Admission-time validation of DECODE-step slices (site scheduling
        errors surface as per-request admission failures, not step-time
        crashes that would take co-tenants down with them)."""
        for step, sl in slices.items():
            if step != PREFILL_STEP and not sl.is_empty():
                sl.graph.validate(self.schedule.order)

    # ------------------------------------------------------------ admission
    def admit(
        self,
        graph: InterventionGraph,
        batch: dict,
        max_new_tokens: int,
        *,
        request_id: Any = None,
        inputs: dict[str, Any] | None = None,
        pad_to: int | None = None,
    ) -> SlotRequest:
        """Admit one request (solo prefill).  See :meth:`admit_group`."""
        return self.admit_group(
            [(graph, batch, max_new_tokens, request_id)],
            inputs=[inputs] if inputs else None,
            pad_to=pad_to,
        )[0]

    def admit_group(
        self,
        items: list[tuple],
        *,
        inputs: list[dict | None] | None = None,
        pad_to: int | None = None,
    ) -> list[SlotRequest]:
        """Admit simultaneous arrivals through ONE (merged) prefill.

        ``items`` is ``[(graph, batch, max_new_tokens, request_id), ...]``;
        each item's rows land in their own slot run and retire independently
        (``max_new_tokens`` may differ).  Ragged prompt widths are
        right-padded to the group max — or to ``pad_to`` (the scheduler
        passes the length-bucket ceiling so REPEATED admissions share one
        compiled prefill shape).  Saves still come back at each request's
        true solo shape.  A single-token prompt (no prefill execution) must
        be admitted alone: its cache rows are initialized empty.
        """
        from repro.core.batching import RAGGED_INPUTS, merge_graphs, split_results

        if not items:
            return []
        parsed = []
        for graph, batch, n_new, req_id in items:
            batch = dict(batch)
            tokens = jnp.asarray(batch.pop("tokens"))
            lengths = batch.pop("lengths", None)
            if lengths is not None:
                lengths = jnp.asarray(lengths, jnp.int32)
            N = int(n_new)
            if N < 1:
                raise ValueError("max_new_tokens must be >= 1")
            parsed.append((graph, tokens, lengths, batch, N, req_id))

        widths = [t.shape[1] for _, t, *_ in parsed]
        if 1 in widths and len(items) > 1:
            raise ValueError(
                "single-token prompts have no prefill execution and must be "
                "admitted alone"
            )

        # ---- allocate slot rows up front (all-or-nothing) ----------------
        placed: list[list[int]] = []
        taken: set[int] = set()
        for _, tokens, *_ in parsed:
            size = tokens.shape[0]
            rows = self.alloc_rows(size, exclude=taken)
            placed.append(rows)
            taken.update(rows)
        # paged: prove the whole group's LIFETIME page need fits the
        # unreserved pool before any prefill work runs.  Nothing commits
        # until _install, so an early raise leaks neither rows nor pages.
        page_plan = None
        if self._paged:
            row_lens = [
                (np.asarray(lengths) if lengths is not None
                 else np.full((tokens.shape[0],), tokens.shape[1]))
                for _, tokens, lengths, *_ in parsed
            ]
            page_plan = self._plan_pages(row_lens,
                                         [p[4] for p in parsed])

        # ---- single-token prompt: empty cache, whole prompt is step 0 ----
        if widths[0] == 1:
            graph, tokens, lengths, extras, N, req_id = parsed[0]
            if N > self.max_len:
                raise ValueError(
                    f"request needs {N} cache slots, table has {self.max_len}"
                )
            slices = slice_steps(graph, N)
            if slices.get(PREFILL_STEP) is not None:
                raise GraphValidationError(
                    "prefill() taps require a prompt of >= 2 tokens; a "
                    "single-token prompt has no prefill execution"
                )
            self._validate_slices(slices)
            B = tokens.shape[0]
            if B != self.num_slots:
                # partial admission: fixed-width extras (enc-dec source
                # frames) must match the preallocated slot-table shape
                for k, w in self._fixed_extra_widths(extras).items():
                    a = np.asarray(extras[k])
                    if w > a.shape[1]:
                        lk = RAGGED_INPUTS.get(k)
                        if lk and lk not in extras:
                            extras[lk] = np.full(a.shape[0], a.shape[1],
                                                 np.int32)
                        extras[k] = np.pad(
                            a, ((0, 0), (0, w - a.shape[1]))
                            + ((0, 0),) * (a.ndim - 2))
            make_cache = self._empty_cache_fn or self.model.empty_cache
            src = make_cache(self.params, extras, B, self.max_len,
                             self.cache_kind)
            rows0 = placed[0]
            sr = SlotRequest(
                request_id=req_id, start=rows0[0], size=len(rows0),
                max_new_tokens=N, slices=slices,
                inputs=(inputs[0] if inputs else None),
                base_pos=jnp.zeros((B,), jnp.int32),
                row_list=_row_list_or_none(rows0),
            )
            self._install(sr, src, None, tokens,
                          page_plan[0] if page_plan else None)
            return [sr]

        # ---- pad prompts to the group max / bucket ceiling ---------------
        target = max(max(widths), pad_to or 0)
        tok_arrs, len_arrs, recs = [], [], []
        for _, tokens, lengths, _, _, _ in parsed:
            B, S = tokens.shape
            if lengths is None:
                lengths = jnp.full((B,), S, jnp.int32)
            if S < target:
                tokens = jnp.pad(tokens, ((0, 0), (0, target - S)))
            tok_arrs.append(tokens)
            len_arrs.append(lengths)
            recs.append({"tokens": S - 1})
        group_tokens = jnp.concatenate(tok_arrs)
        group_lengths = jnp.concatenate(len_arrs)
        # the model only needs per-row lengths when some row is actually
        # shorter than the padded width — a uniform unpadded prompt keeps
        # the legacy lengths-free prefill (bit-identical, and the path
        # pallas/window guards expect)
        needs_lengths = target > min(widths) or any(
            l is not None for _, _, l, _, _, _ in parsed
        )
        whole_table = (len(parsed) == 1
                       and parsed[0][1].shape[0] == self.num_slots)

        # extras must be shape-uniform across the group (the scheduler's
        # admission key guarantees it); ragged extras (src_embeds) merge by
        # right-padding with synthesized per-row lengths, like the burst
        # path.  PARTIAL admissions additionally pad ragged extras to the
        # slot table's fixed width (enc-dec cross K/V is preallocated at
        # cfg.n_source_frames) so their cache rows scatter cleanly.
        extra_recs = [dict(r) for r in recs]
        fixed_w = {} if whole_table else self._fixed_extra_widths(
            parsed[0][3]
        )
        if len(parsed) == 1 and not fixed_w:
            extras = dict(parsed[0][3])  # solo: pass through untouched
        else:
            extras = {}
            for k in parsed[0][3]:
                arrs = [np.asarray(p[3][k]) for p in parsed]
                if k in RAGGED_INPUTS and arrs[0].ndim >= 2:
                    kmax = max(max(a.shape[1] for a in arrs),
                               fixed_w.get(k, 0))
                    lk = RAGGED_INPUTS[k]
                    if any(a.shape[1] != kmax for a in arrs):
                        for rec, a in zip(extra_recs, arrs):
                            rec[k] = a.shape[1]
                        if lk not in parsed[0][3]:
                            extras[lk] = np.concatenate([
                                np.full(a.shape[0], a.shape[1], np.int32)
                                for a in arrs
                            ])
                    arrs = [
                        np.pad(a, ((0, 0), (0, kmax - a.shape[1]))
                               + ((0, 0),) * (a.ndim - 2))
                        for a in arrs
                    ]
                extras[k] = np.concatenate(arrs)

        for _, _, _, _, N, _ in parsed:
            need = target - 1 + N
            if need > self.max_len:
                raise ValueError(
                    f"request needs {need} cache slots "
                    f"(padded prompt {target} + {N} new tokens), table has "
                    f"{self.max_len}"
                )

        all_slices = [slice_steps(g, N) for g, _, _, _, N, _ in parsed]
        pre_slices = [sl.get(PREFILL_STEP) for sl in all_slices]
        # Reject bad DECODE-step graphs at admission (a clean per-request
        # error) instead of blowing up a later shared decode step with
        # innocent co-tenants resident; prefill slices are validated below
        # as part of the merged prefill graph.
        for sl in all_slices:
            self._validate_slices(sl)

        prompt = {"tokens": group_tokens[:, :-1], **extras}
        if needs_lengths:
            prompt["lengths"] = group_lengths - 1
        sizes = [t.shape[0] for t in tok_arrs]

        # Families whose prefill runs a Python layer loop must schedule
        # instrumented prefill slices unrolled (same rule as run_generation).
        pre_mode = self.mode
        pre_schedule = self.schedule
        if self.mode == "scan" and not getattr(self.model, "scan_prefill",
                                               True):
            pre_mode = "unrolled"
            pre_schedule = _step_order(self.model.site_schedule("unrolled"))

        # fault point: nothing is committed yet (rows/pages install below),
        # so an injected prefill failure fails the admission cleanly
        _fault("prefill.dispatch")
        if not any(sl is not None for sl in pre_slices):
            if self._prefill_fn is not None:
                _out, src = self._prefill_fn(self.params, prompt, self.max_len)
            else:
                _out, src = self.model.prefill(
                    self.params, prompt, mode=self.mode, kind=self.cache_kind,
                    max_len=self.max_len,
                )
            merged_saves = None
            merged = None
        else:
            unpad = needs_lengths or any(
                len(rec) > 1 for rec in extra_recs  # ragged extras padded
            )
            merged = merge_graphs(
                [sl.graph if sl is not None else InterventionGraph()
                 for sl in pre_slices],
                sizes,
                lengths=extra_recs if unpad else None,
                site_length_key=getattr(self.model, "site_length_key", None),
                length_pad_to={"tokens": target - 1} if unpad else None,
            )
            merged.graph.validate(pre_schedule.order)
            bound = {}
            for i, (sl, prefix) in enumerate(
                zip(pre_slices, merged.save_prefixes)
            ):
                user = inputs[i] if inputs else None
                if sl is None or not user:
                    continue
                for n in sl.graph.nodes:
                    if n.op == "input" and not n.args[0].startswith("__env"):
                        bound[f"{prefix}/{n.args[0]}"] = user[n.args[0]]

            def pre_fn(params_, batch_):
                return self.model.prefill(
                    params_, batch_, mode=pre_mode, kind=self.cache_kind,
                    max_len=self.max_len,
                )

            (_out, src), sl_saves, pre_logs = run_interleaved(
                pre_fn, merged.graph, pre_schedule, (self.params, prompt), {},
                mode=pre_mode, inputs=bound,
            )
            merged_saves = split_results(sl_saves, merged)

        # ---- install each request into its slots -------------------------
        out_srs = []
        src_row0 = 0
        for i, ((graph, tokens, lengths, _, N, req_id), rows_i) in (
            enumerate(zip(parsed, placed))
        ):
            row_lengths = len_arrs[i]
            size = len(rows_i)
            sr = SlotRequest(
                request_id=req_id, start=rows_i[0], size=size,
                max_new_tokens=N, slices=all_slices[i],
                inputs=(inputs[i] if inputs else None),
                base_pos=row_lengths - 1,
                row_list=_row_list_or_none(rows_i),
            )
            if merged_saves is not None:
                sl = pre_slices[i]
                if sl is not None:
                    _route_slice_saves(sr, sl, merged_saves[i])
                    # logs attributed by merged-graph node-id segment so one
                    # request never sees a co-tenant's logged values
                    sr.logs.extend(
                        entry for entry in pre_logs
                        if merged.owner_of(entry[0]) == i
                    )
            src_rows = np.arange(src_row0, src_row0 + size)
            token0 = jnp.take_along_axis(
                tok_arrs[i], (row_lengths - 1)[:, None], axis=1
            )
            self._install(sr, src, src_rows if len(parsed) > 1 else None,
                          token0, page_plan[i] if page_plan else None)
            out_srs.append(sr)
            src_row0 += size
        return out_srs

    def _install(self, sr: SlotRequest, src_cache, src_rows, token0,
                 rows_plan: list[tuple[int, int]] | None = None) -> None:
        if (not self._paged and sr.size == self.num_slots
                and src_rows is None and sr.row_list is None):
            # whole-table admission (e.g. run_generation running solo
            # through the stepper): adopt the prefilled cache directly
            # instead of scattering every row onto itself.  A paged loop
            # always scatters — the pool layout is not the dense layout.
            self.cache = src_cache
        else:
            if self.cache is None:
                if self._paged:
                    from repro.models.paged import build_paged_cache

                    self.cache = build_paged_cache(
                        self.model, self.num_slots, self.max_len,
                        self.cache_kind, page_size=self.page_size,
                        num_pages=self.num_pages,
                    )
                if self.cache is None:
                    self.cache = self.model.init_cache(
                        self.num_slots, self.max_len, kind=self.cache_kind
                    )
            if self._paged and rows_plan is not None:
                self._alloc_request_pages(sr, rows_plan)
                self._check_page_invariants(sr)
                self._sync_block_tables()
            rows = jnp.asarray(sr.rows)
            self.cache = self._write_rows_fn(self.cache, rows, src_cache,
                                             src_rows)
        self.token = self.token.at[jnp.asarray(sr.rows)].set(token0)
        self._free.difference_update(int(r) for r in sr.rows)
        self.resident.append(sr)
        if self.stats is not None:
            self.stats.record_admission(sr.size)

    def _check_page_invariants(self, sr: SlotRequest) -> None:
        """Prove the host block tables sound after an allocation: every
        referenced page in-bounds and non-reserved, no page shared across
        tenants (the static analyzer's checker doubles as the allocator's
        runtime invariant)."""
        from repro.core import analysis

        rows_list = [list(map(int, r.rows)) for r in self.resident]
        rows_list.append(list(map(int, sr.rows)))
        diags = analysis.check_page_plan(self._bt_host, rows_list,
                                         self.num_pages)
        errs = [d for d in diags if d.severity == "error"]
        if errs:
            raise RuntimeError(
                "paged allocator invariant violated: "
                + "; ".join(d.format() for d in errs)
            )

    # ----------------------------------------------------------------- step
    def step(self) -> list[SlotRequest]:
        """Decode ONE token for every resident request; returns the requests
        that retired this step (their slots are free again on return).

        With fusion enabled this is a length-1 fused window: single steps
        run the SAME compiled scan body as multi-step windows, so a
        request's numerics never depend on how co-tenancy happened to split
        the loop into windows (fused windows of any length are
        bit-identical; only the unjitted eager path — logs, cross-step
        exports with co-tenants, failures, ``fuse=False`` — differs at the
        float-rounding level)."""
        return self.step_fused(1)

    def _step_eager(self) -> list[SlotRequest]:
        """The uncompiled per-step path: one cached-jit decode dispatch for
        uninstrumented steps, the eager interleaver otherwise."""
        if not self.resident:
            return []
        from repro.core.batching import merge_graphs, split_results

        self._grow_pages(1)
        pos_np = np.full((self.num_slots,), _FREE_POS, np.int32)
        for sr in self.resident:
            pos_np[_rows_index(sr)] = np.asarray(sr.base_pos) + sr.t
        pos = jnp.asarray(pos_np)

        need = [
            (sr, sr.slices[sr.t]) for sr in self.resident
            if sr.t in sr.slices and not sr.slices[sr.t].is_empty()
        ]
        if not need:
            if self._decode_fn is not None:
                out, self.cache = self._decode_fn(
                    self.params, self.cache, self.token, pos
                )
            else:
                out, self.cache = self.model.decode_step(
                    self.params, self.cache,
                    {"token": self.token, "pos": pos}, mode=self.mode,
                )
        else:
            # Slot-scoped merge: each request's slice is rewritten against
            # its OWN slot rows; step coordinates are normalized so
            # co-tenants at different local steps share one getter/setter
            # chain per site.  Membership changes -> a new merged graph.
            # (This eager path re-merges and re-interleaves every step; it
            # now serves only the NON-uniform remainder — step-uniform
            # stretches run through step_fused as one compiled lax.scan.)
            merged = merge_graphs(
                [sl.graph for _, sl in need],
                [sr.size for sr, _ in need],
                starts=[sr.placement for sr, _ in need],
                normalize_steps=True,
            )
            merged.graph.validate(self.schedule.order)
            bound = {}
            for (sr, sl), prefix in zip(need, merged.save_prefixes):
                _bind_slice_inputs(sr, sl, prefix, bound)

            def step_fn(params_, cache_, token_, pos_):
                return self.model.decode_step(
                    params_, cache_, {"token": token_, "pos": pos_},
                    mode=self.mode,
                )

            try:
                (out, self.cache), sl_saves, sl_logs = run_interleaved(
                    step_fn, merged.graph, self.schedule,
                    (self.params, self.cache, self.token, pos), {},
                    mode=self.mode, inputs=bound,
                )
            except Exception as e:
                # A step-time failure of an intervention graph (admission
                # validation can't catch e.g. a broadcast error in a user
                # op) must not wedge the loop: identify the offending
                # request(s) by trial-running each slice alone (pure calls —
                # nothing is committed), evict only those, and retry the
                # step — the cache was not updated, so innocent co-tenants
                # lose nothing.
                offenders = self._isolate_offenders(need, pos, e)
                evicted = []
                for sr, err in offenders:
                    sr.error = err
                    self._retire(sr)
                    evicted.append(sr)
                if self.on_segment is not None and evicted:
                    # zero-step boundary: evictions surface immediately
                    # (their channels get the error without waiting for the
                    # retried step's segment)
                    self.on_segment(0, evicted)
                return evicted + self.step()
            for i, ((sr, sl), saves_r) in enumerate(
                zip(need, split_results(sl_saves, merged))
            ):
                _route_slice_saves(sr, sl, saves_r)
                # logs attributed by merged-graph node-id segment: a
                # request never sees a co-tenant's logged values
                sr.logs.extend(entry for entry in sl_logs
                               if merged.owner_of(entry[0]) == i)

        logits = out["logits"]
        self.token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[
            :, None
        ]
        retired = []
        for sr in self.resident:
            idx = _rows_index(sr)
            sr.new_tokens.append(self.token[idx, 0])
            sr.last_logits = logits[idx]
            sr.t += 1
            if sr.done():
                retired.append(sr)
        self.steps_run += 1
        self.eager_steps += 1
        if self.stats is not None:
            busy = self.num_slots - len(self._free)
            self.stats.record_slot_step(busy, self.num_slots)
            if hasattr(self.stats, "record_eager_step"):
                self.stats.record_eager_step()
        for sr in retired:
            self._retire(sr)
        if self.on_segment is not None:
            self.on_segment(1, retired)
        return retired

    def _isolate_offenders(self, need, pos, exc) -> list[tuple]:
        """Which of the instrumented co-tenants made the merged step fail?

        Each candidate's slice is trial-run ALONE against the current cache
        (run_interleaved is pure — results are discarded, nothing commits).
        Requests whose solo trial raises are the offenders; if every trial
        passes (the failure only manifests merged), all of ``need`` is
        evicted — never silently retried."""
        from repro.core.batching import merge_graphs

        if len(need) == 1:
            sr, _ = need[0]
            return [(sr, f"{type(exc).__name__}: {exc}")]

        def step_fn(params_, cache_, token_, pos_):
            return self.model.decode_step(
                params_, cache_, {"token": token_, "pos": pos_},
                mode=self.mode,
            )

        offenders = []
        for sr, sl in need:
            single = merge_graphs(
                [sl.graph], [sr.size], starts=[sr.placement],
                normalize_steps=True,
            )
            bound = {}
            _bind_slice_inputs(sr, sl, single.save_prefixes[0], bound)
            try:
                run_interleaved(
                    step_fn, single.graph, self.schedule,
                    (self.params, self.cache, self.token,
                     jnp.asarray(pos)), {},
                    mode=self.mode, inputs=bound,
                )
            except Exception as e2:
                offenders.append((sr, f"{type(e2).__name__}: {e2}"))
        if not offenders:
            # Every solo trial passed — the failure only manifests merged.
            # Instead of a blanket blame, attach the static analyzer's
            # per-request verdict alongside the original exception so each
            # ticket says what (if anything) is wrong with ITS graph.
            from repro.core import analysis

            for sr, sl in need:
                try:
                    rep = analysis.analyze(
                        sl.graph, site_order=list(self.schedule.order)
                    )
                    verdict = (
                        "; ".join(d.format() for d in rep.errors())
                        or "statically clean"
                    )
                except Exception:
                    verdict = "static analysis unavailable"
                offenders.append((
                    sr,
                    f"{type(exc).__name__}: {exc} (merged-step failure; "
                    f"solo trial passed; preflight verdict: {verdict})",
                ))
        return offenders

    # ---------------------------------------------------------- fused step
    def fusable_steps(self) -> int:
        """Decode steps until the next retirement boundary — the longest
        window over which slot membership is guaranteed constant."""
        if not self.resident:
            return 0
        return min(sr.max_new_tokens - sr.t for sr in self.resident)

    def _uniform_run(self, sr: SlotRequest, k: int) -> int:
        """Longest run of structurally-identical step slices for ``sr``
        starting at its current local step (0 = unfusable at all)."""
        fp0 = _slice_fingerprint(sr.slices.get(sr.t))
        if fp0 is None:
            return 0
        run = 1
        for j in range(1, k):
            if _slice_fingerprint(sr.slices.get(sr.t + j)) != fp0:
                break
            run += 1
        return run

    def _plan_fused(self, k: int) -> _FusedPlan | None:
        """Build the fused segment for the next ``k`` steps, or None when
        the eager per-step path must serve them (non-uniform slices,
        cross-step env flow, or a previously failed compile).  Log, grad,
        and forward cross-layer graphs plan like any other — the harvest
        interpreter lowers them into the compiled body."""
        from repro.core.batching import merge_graphs
        from repro.core.serialize import structural_key

        need_raw: list[tuple[SlotRequest, list[StepSlice]]] = []
        for sr in self.resident:
            sls = [sr.slices.get(sr.t + j) for j in range(k)]
            fps = [_slice_fingerprint(sl) for sl in sls]
            if any(fp is None for fp in fps):
                return None
            if any(fp != fps[0] for fp in fps[1:]):
                return None
            if fps[0] == _EMPTY_FP:
                continue  # uninstrumented rider
            if len(sls) > 1 and any(sl.exports for sl in sls):
                # defensive: cross-step env exports carry per-step names, so
                # fingerprint equality already keeps them out of multi-step
                # windows; a length-1 window routes them through the env
                return None
            need_raw.append((sr, sls))

        if need_raw:
            merged = merge_graphs(
                [sls[0].graph for _, sls in need_raw],
                [sr.size for sr, _ in need_raw],
                starts=[sr.placement for sr, _ in need_raw],
                normalize_steps=True,
            )
            graph = merged.graph
        else:
            merged = None
            graph = InterventionGraph()
        # bad keys are graph-structural only (no window length): a program
        # that failed to compile at one k would re-fail at every shrinking
        # k of the same structure, each retry paying a full XLA trace
        key = structural_key(graph)
        if key in self._fused_bad:
            self.last_fusion_reason = "failed-compile"
            return None
        if merged is not None:
            if self.mode == "scan":
                # static fusion lint (layer 4): a merged graph the scan
                # body cannot host is rejected HERE with a named reason —
                # the old path paid a failed XLA trace to learn this and
                # recorded an anonymous failure key
                from repro.core.analysis import scan_fusion_reason

                reason = scan_fusion_reason(graph, self.schedule)
                if reason is not None:
                    self.last_fusion_reason = reason
                    self._fused_bad.add(key)
                    return None
            graph.validate(self.schedule.order)
        self.last_fusion_reason = None

        inputs: dict[str, Any] = {}
        consts: dict[int, Any] = {}
        step_consts: dict[int, Any] = {}
        need: list[tuple] = []
        for i, (sr, sls) in enumerate(need_raw):
            prefix = merged.save_prefixes[i]
            tmpl = sls[0]
            _bind_slice_inputs(sr, tmpl, prefix, inputs)
            # Align this request's merged-graph constant nodes with each
            # step slice's constants: merge_graphs copies a slice's nodes
            # in order into the request's segment, so constants correspond
            # by position.  Values equal at every step fold into the shared
            # const env; differing values ride the scan as stacked inputs.
            lo, hi = merged.node_ranges[i]
            merged_cids = [
                n.id for n in graph.nodes[lo:hi] if n.op == "constant"
            ]
            per_step = [
                [n.args[0] for n in sl.graph.nodes if n.op == "constant"]
                for sl in sls
            ]
            for ci, mid in enumerate(merged_cids):
                vals = [step_vals[ci] for step_vals in per_step]
                if all(np.array_equal(vals[0], v) for v in vals[1:]):
                    consts[mid] = vals[0]
                else:
                    step_consts[mid] = jnp.stack(
                        [jnp.asarray(v) for v in vals]
                    )
            need.append((
                sr,
                sls,
                {nid: f"{prefix}/{name}"
                 for name, nid in tmpl.graph.saves.items()},
            ))
        island = any(n.op in ("log", "grad_get") for n in graph.nodes)
        if not island and self.mode == "scan" and graph.nodes:
            from repro.core.interleave import Interleaver

            island = bool(
                Interleaver(graph, self.schedule, mode="scan").cross_getters
            )
        return _FusedPlan(
            key=key, graph=graph, k=k, need=need,
            consts=consts, step_consts=step_consts, inputs=inputs,
            node_ranges=list(merged.node_ranges or ()) if merged else [],
            island=island,
        )

    def _fused_executable(self, graph: InterventionGraph, k: int) -> Callable:
        if self._fused_fn is not None:
            return self._fused_fn(graph, k)
        from repro.core.serialize import structural_key

        key = (structural_key(graph), k)
        fn = self._fused_cache.get(key)
        if fn is None:
            fn = jax.jit(make_fused_step(
                self.model, graph, self.schedule, k, mode=self.mode
            ))
            self._fused_cache[key] = fn
        return fn

    def step_fused(self, k: int) -> list[SlotRequest]:
        """Decode up to ``k`` tokens for every resident request in ONE
        compiled ``lax.scan`` dispatch; returns the requests that retired.

        The window is clipped to the next retirement boundary (slot
        membership must be constant inside the scan) and to the longest
        structurally-uniform run of every resident's step slices — so e.g.
        steps 3..5 of an otherwise-plain trace carrying a setter fuse as
        their own segment, and a single non-uniform step runs as a
        length-1 window of the same compiled machinery (keeping numerics
        independent of how co-tenancy split the loop).  ``log`` graphs
        fuse too: the compiled body emits through ``jax.debug.callback``
        into :data:`repro.core.interleave.LOG_SINK`, drained here after
        the dispatch and attributed per-request by merged node-id segment.
        Only graphs that fail to compile fall back to ONE eager per-step
        execution, after which fusion is retried.
        """
        if not self.resident:
            return []
        # fault point: fires BEFORE the window's try/except, so an injected
        # error escapes to the caller — in the live front door that is the
        # engine thread, i.e. the supervised crash-recovery surface
        _fault("decode.step")
        if not self.fuse:
            return self._step_eager()
        k = max(1, min(int(k), self.fusable_steps()))
        if k >= 2:
            k = min([k] + [
                self._uniform_run(sr, k) for sr in self.resident
            ])
        if k < 1:
            return self._step_eager()
        # extend block tables to the window's write frontier BEFORE the
        # dispatch (value-only refresh — never a recompile); an eager
        # fallback below re-runs growth harmlessly (idempotent)
        self._grow_pages(k)
        plan = self._plan_fused(k)
        if plan is None:
            return self._step_eager()

        pos_np = np.full((self.num_slots,), _FREE_POS, np.int32)
        for sr in self.resident:
            pos_np[_rows_index(sr)] = np.asarray(sr.base_pos) + sr.t
        has_log = any(n.op == "log" for n in plan.graph.nodes)
        if has_log:
            from repro.core.interleave import LOG_SINK

            # entries from an earlier failed dispatch must not be
            # attributed to this window
            LOG_SINK.drain()
        try:
            fn = self._fused_executable(plan.graph, plan.k)
            (self_cache, self_token), ys = fn(
                self.params, self.cache, self.token, jnp.asarray(pos_np),
                plan.consts, plan.step_consts, plan.inputs,
            )
        except Exception:
            # A fused compile/run failure must not wedge the loop: remember
            # the offending program and let the eager path (with its
            # per-request offender isolation) serve this window.
            self._fused_bad.add(plan.key)
            self.last_fusion_reason = "failed-compile"
            return self._step_eager()
        self.cache, self.token = self_cache, self_token

        # one host transfer for the whole token stack (k device slices per
        # request would rebuild the per-step dispatch cost being removed)
        tok_np = np.asarray(ys["token"])  # (k, num_slots, 1)
        if has_log:
            from repro.core.interleave import LOG_SINK

            # the token transfer above synced the dispatch; drain() adds an
            # effects barrier so every callback has landed.  Entries carry
            # MERGED node ids — route each to its owning request's segment
            # (a request never sees a co-tenant's logged values).
            for nid, val in LOG_SINK.drain():
                for i, (lo, hi) in enumerate(plan.node_ranges):
                    if lo <= nid < hi:
                        plan.need[i][0].logs.append((nid, val))
                        break
        for sr in self.resident:
            idx = _rows_index(sr)
            for j in range(plan.k):
                sr.new_tokens.append(tok_np[j, idx, 0])
            sr.last_logits = ys["logits"][plan.k - 1, idx]
            sr.t += plan.k
        for sr, sls, wire_by_nid in plan.need:
            # saves follow the NODE across steps: slice-local save node ids
            # are identical in every uniform slice, so step j's value is the
            # template channel of that id, named by step j's own slice
            # (cross-step env exports — length-1 windows only — route back
            # into the request's env exactly like the eager path)
            for j in range(plan.k):
                _route_slice_saves(sr, sls[j], {
                    name: ys["saves"][wire_by_nid[nid]][j]
                    for name, nid in sls[j].graph.saves.items()
                })

        self.steps_run += plan.k
        self.fused_segments += 1
        self.fused_steps += plan.k
        if plan.island:
            self.islands_compiled += 1
        if self.stats is not None:
            busy = self.num_slots - len(self._free)
            for _ in range(plan.k):
                self.stats.record_slot_step(busy, self.num_slots)
            if hasattr(self.stats, "record_fused_segment"):
                self.stats.record_fused_segment(plan.k)
            if plan.island and hasattr(self.stats, "record_islands_compiled"):
                self.stats.record_islands_compiled()
        retired = [sr for sr in self.resident if sr.done()]
        for sr in retired:
            self._retire(sr)
        if self.on_segment is not None:
            self.on_segment(plan.k, retired)
        return retired

    def _retire(self, sr: SlotRequest) -> None:
        self.cache = self._clear_rows_fn(self.cache, jnp.asarray(sr.rows))
        if self._paged and sr.pages:
            # return allocated pages AND drop the unallocated remainder of
            # the lifetime reservation (an evicted request never grew to
            # its full extent); host block-table rows go back to the null
            # page so retired rows read zeros until reused
            freed = 0
            for row, pages in sr.pages.items():
                self._free_pages.extend(pages)
                freed += len(pages)
                self._reserved_unalloc -= sr.page_need.get(row, len(pages)) \
                    - len(pages)
                self._bt_host[row, :] = 0
            self._free_pages.sort()
            sr.pages = {}
            sr.page_need = {}
            self._sync_block_tables()
            if self.stats is not None and hasattr(self.stats,
                                                  "record_page_free"):
                self.stats.record_page_free(
                    freed, self.pages_in_use(), self.pages_free()
                )
        self._free.update(int(r) for r in sr.rows)
        self.resident.remove(sr)
        if self.stats is not None:
            # sr.t, not max_new_tokens: an evicted request decoded fewer
            self.stats.record_retire(sr.size, sr.t)

    def evict(self, request_id: Any, error: str,
              code: str | None = None) -> SlotRequest | None:
        """Evict one resident request at a step boundary (deadline blown /
        cancelled / quarantined): its slot rows clear, its KV pages —
        allocated AND still-reserved — return to the pool immediately, and
        co-tenants keep decoding untouched.  Returns the evicted
        :class:`SlotRequest` (``error``/``error_code`` set, ``result()``
        unavailable) or ``None`` when the id is not resident.

        Callers are responsible for invoking this BETWEEN decode windows
        only (the live front door's engine thread does, before picking the
        next window) — mid-``step_fused`` the scan owns the slot rows."""
        for sr in list(self.resident):
            if sr.request_id == request_id:
                sr.error = str(error)
                sr.error_code = code
                self._retire(sr)
                return sr
        return None

    def run_to_completion(self) -> list[SlotRequest]:
        """Step until every resident request has retired (fused segments
        between retirement boundaries when the loop allows fusion)."""
        done: list[SlotRequest] = []
        while self.resident:
            done.extend(self.step_fused(self.fusable_steps()))
        return done


def _route_slice_saves(
    sr: SlotRequest, sl: StepSlice, saves_r: dict[str, Any]
) -> None:
    """Split a slice's saves into cross-step env exports and user saves."""
    for name, val in saves_r.items():
        if name in sl.exports:
            sr.env[sl.exports[name]] = val
        else:
            sr.saves[name] = val


def _bind_slice_inputs(
    sr: SlotRequest, sl: StepSlice, prefix: str, bound: dict[str, Any]
) -> None:
    """Bind one request's cross-step env imports and user experiment inputs
    under its merged-graph prefix — the one routing convention shared by
    the eager step, offender isolation, and the fused planner."""
    for name, nid in sl.imports.items():
        bound[f"{prefix}/{name}"] = sr.env[nid]
    if sr.inputs:
        for n in sl.graph.nodes:
            if n.op == "input" and not n.args[0].startswith("__env"):
                bound[f"{prefix}/{n.args[0]}"] = sr.inputs[n.args[0]]


def stack_step_saves(
    per_step: dict[int, Any], axis: int = 1
) -> Any:
    """Stack one save name's per-step values in step order.

    Values shaped ``(B, 1, ...)`` (token-axis singletons, the common case
    for decode-step activations) concatenate along the token axis to
    ``(B, n_steps, ...)``; anything else stacks along a new leading axis.
    """
    steps = sorted(per_step)
    vals = [per_step[s] for s in steps]

    def stack(*xs):
        if all(
            hasattr(x, "ndim") and x.ndim >= 2 and x.shape[1] == 1
            for x in xs
        ):
            return jnp.concatenate(xs, axis=axis)
        return jnp.stack(xs, axis=0)

    return jax.tree.map(stack, *vals)
