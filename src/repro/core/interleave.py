"""Interleaving: compile an intervention graph into a pure JAX function.

This is the hardware adaptation of the paper's core mechanism (§3.1 and
Appendix B.1).  The paper *interprets* the intervention graph at runtime from
PyTorch module hooks; XLA programs cannot call back into Python, so here the
graph is *executed at jit-trace time*: as the model function runs under
``jax.jit`` tracing, every ``taps.site(...)`` call hands its abstract value to
the :class:`InterleaveState`, which runs the graph segments scheduled at that
site.  The result is ONE compiled XLA program containing both the model and
the experiment — interventions fuse with model compute, inherit its sharding,
and cost zero host round-trips (a strict improvement over eager hooks,
measured in ``benchmarks/table1_framework_overhead.py``).

Gradient support (the paper's GradProtocol) uses the *perturbation trick*:
for every tapped value ``v`` with a ``.grad`` consumer we add a zeros
perturbation ``v + p`` and differentiate the in-graph loss w.r.t. ``p``;
``dL/dp == dL/dv`` and the whole thing stays jittable.

Multi-token generation reuses this machinery unchanged: a step-annotated
graph is sliced into one sub-graph per model execution (prefill / each
decode step) and every slice runs through :func:`run_interleaved`, so site
scheduling, scan mode, and setter validation apply per step — see
:mod:`repro.core.generation`.

The interpreter is *final-style* in the harvest mold (oryx's ``sow``/
``reap``): every graph feature lowers into the traced body instead of
escaping to the host, so there are no eager islands left —

* ``log`` nodes emit through ``jax.debug.callback`` into a host-side
  :class:`LogSink` (the value stays in the compiled program; only the
  flush crosses to the host);
* ``tracer.stop()`` raises :class:`EarlyStop` *at trace time*, so a jitted
  caller gets a program that is both truncated and compiled;
* ``.grad`` runs the perturbation driver inside the traced step body —
  state threads through function arguments and the scan carry, never
  through Python-side env mutation — so gradients ride ``lax.scan``;
* scan-mode cross-layer data flow threads the intervention env through the
  scan carry (``taps.scan_env_init``/``scan_env_provide``/
  ``scan_env_update``), lifting the same-iteration setter restriction for
  forward flow.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import taps
from repro.core.graph import (
    POST_SITE,
    PRE_SITE,
    GraphValidationError,
    InterventionGraph,
    Node,
    map_refs,
)
from repro.core.op_registry import resolve_op

__all__ = [
    "SiteSchedule",
    "Interleaver",
    "InterleaveState",
    "run_interleaved",
    "make_step_callable",
    "EarlyStop",
    "last_referenced_site",
    "LogSink",
    "LOG_SINK",
]


class EarlyStop(Exception):
    """Raised by the state to abandon model execution after the last site an
    intervention graph references (``tracer.stop()``).  Caught by
    :func:`run_interleaved`; saves are assembled from the partial execution.

    The raise happens at *trace time*, so a jitted caller that catches it
    inside its traced function lowers the partial trace: the resulting XLA
    program is simultaneously truncated and compiled.
    """


class LogSink:
    """Host-side sink for ``log()`` values emitted from compiled code.

    A ``log`` node inside a compiled body lowers to ``jax.debug.callback``
    targeting this sink, so log-carrying graphs fuse instead of forcing the
    eager per-step path.  The callback appends ``(node_id, value)`` from the
    runtime's host-callback thread; :meth:`drain` runs
    ``jax.effects_barrier()`` so every dispatched callback has landed before
    entries are handed back.

    Ordering caveat: entries arrive per *dispatch* — one fused scan segment
    flushes all of its per-step callbacks together when drained, not one
    Python line at a time.  Entries keep the merged graph's node ids, so
    per-request attribution maps them through
    ``MergedBatch.node_ranges``/``owner_of``.
    """

    def __init__(self) -> None:
        self.entries: list[tuple[int, Any]] = []

    def emit(self, node_id: int, value: Any) -> None:
        self.entries.append((int(node_id), np.asarray(value)))

    def drain(self) -> list[tuple[int, Any]]:
        jax.effects_barrier()
        out, self.entries = self.entries, []
        return out


#: Default sink used by :func:`make_step_callable` for graphs with ``log``
#: nodes when the caller does not supply its own ``log_cb``.
LOG_SINK = LogSink()


@dataclasses.dataclass
class SiteSchedule:
    """A model's tap-site execution order.

    ``order``      — full (name, layer) schedule, layer-expanded, in model
                     execution order.  Used for validation and unrolled mode.
    ``scan_sites`` — site names that live inside the ``lax.scan`` layer body
                     (scan mode only; empty for unlayered models).
    ``n_layers``   — number of scan iterations.
    """

    order: list[tuple[str, int | None]]
    scan_sites: tuple[str, ...] = ()
    n_layers: int | None = None

    def index(self) -> dict[tuple[str, int | None], int]:
        return {key: i for i, key in enumerate(self.order)}


class Interleaver:
    """Static plan: which graph nodes run where.  Reusable across calls."""

    def __init__(
        self,
        graph: InterventionGraph,
        schedule: SiteSchedule,
        mode: str = "unrolled",
    ) -> None:
        assert mode in ("unrolled", "scan"), mode
        self.graph = graph
        self.schedule = schedule
        self.mode = mode
        self.ready = graph.schedule(schedule.order)
        self.site_index = schedule.index()

        self.getters_at: dict[tuple[str, int | None], list[Node]] = {}
        self.setters_at: dict[tuple[str, int | None], list[Node]] = {}
        self.exec_at: dict[int, list[Node]] = {}
        self.grad_nodes: list[Node] = []
        self.post_nodes: list[Node] = []

        scan_set = set(schedule.scan_sites) if mode == "scan" else set()
        self.scan_getters: dict[str, list[Node]] = {}
        self.scan_setters: dict[str, list[Node]] = {}

        for n in graph.nodes:
            if n.op == "tap_get":
                if n.site in scan_set:
                    self.scan_getters.setdefault(n.site, []).append(n)
                else:
                    self.getters_at.setdefault((n.site, n.layer), []).append(n)
            elif n.op == "tap_set":
                if n.site in scan_set:
                    self.scan_setters.setdefault(n.site, []).append(n)
                else:
                    self.setters_at.setdefault((n.site, n.layer), []).append(n)
            elif n.op == "grad_get":
                self.grad_nodes.append(n)
            elif n.op in ("constant", "input"):
                pass  # bound by the state at start
            else:
                idx = self.ready[n.id]
                if idx >= POST_SITE:
                    self.post_nodes.append(n)
                elif mode == "scan" and self._in_scan(idx, scan_set):
                    pass  # handled below via setter closures / collection
                else:
                    self.exec_at.setdefault(idx, []).append(n)

        # Scan mode: compute the transitive dependency closure of in-scan
        # setters (those nodes execute inside the scan body); everything else
        # that depends on in-scan getters executes post-scan from collected
        # stacks.  Cross-layer *forward* flow (getter fires before the
        # consuming setter) threads through the scan carry; backward flow is
        # rejected.
        self.scan_exec: dict[str, list[Node]] = {}
        self.collect_sites: tuple[str, ...] = ()
        self.cross_getters: list[Node] = []
        if mode == "scan":
            self._plan_scan(scan_set)

        # Which sites need gradient perturbations.
        self.grad_keys: list[tuple[str, int | None]] = sorted(
            {(n.site, n.layer) for n in self.grad_nodes},
            key=lambda k: (k[0], -1 if k[1] is None else k[1]),
        )
        if self.grad_nodes and graph.backward_loss is None:
            raise GraphValidationError(
                ".grad was used but no backward loss was declared "
                "(call tracer.backward(loss))"
            )

    # ------------------------------------------------------------------ scan
    def _in_scan(self, idx: int, scan_set: set[str]) -> bool:
        if idx in (PRE_SITE,) or idx >= POST_SITE:
            return False
        name, _layer = self.schedule.order[idx]
        return name in scan_set

    def _plan_scan(self, scan_set: set[str]) -> None:
        by_id = {n.id: n for n in self.graph.nodes}
        in_scan_getter_ids = {
            g.id for gs in self.scan_getters.values() for g in gs
        }

        def transitive_deps(node: Node) -> set[int]:
            out: set[int] = set()
            stack = [r.node_id for r in node.refs()]
            while stack:
                nid = stack.pop()
                if nid in out:
                    continue
                out.add(nid)
                stack.extend(r.node_id for r in by_id[nid].refs())
            return out

        body_exec_ids: set[int] = set()
        cross_ids: set[int] = set()
        for site_name, setters in self.scan_setters.items():
            for s in setters:
                deps = transitive_deps(s)
                for nid in deps & in_scan_getter_ids:
                    g = by_id[nid]
                    if g.layer == s.layer:
                        continue  # same-iteration binding, as before
                    # Forward flow (the getter's site fires strictly before
                    # the setter's in the schedule) is carried through the
                    # scan carry; backward flow would need a value from a
                    # future iteration and stays rejected.
                    gi = self.site_index.get((g.site, g.layer))
                    si = self.site_index.get((s.site, s.layer))
                    if gi is None or si is None or gi > si:
                        raise GraphValidationError(
                            f"scan mode: setter %{s.id} (layer {s.layer}) "
                            f"depends on getter %{g.id} (layer {g.layer}); "
                            "backward cross-layer data flow requires "
                            "unrolled mode"
                        )
                    cross_ids.add(nid)
                for nid in deps:
                    n = by_id[nid]
                    if (
                        n.op not in ("tap_get", "tap_set", "constant", "input")
                        and self.ready[nid] != PRE_SITE
                        and self._in_scan(self.ready[nid], scan_set)
                    ):
                        body_exec_ids.add(nid)

        # Getters whose value must survive past their own iteration: the
        # model threads them through the scan carry (taps.scan_env_*).
        self.cross_getters = [by_id[nid] for nid in sorted(cross_ids)]

        # Assign each in-body op node to the site at which it becomes ready.
        for nid in sorted(body_exec_ids):
            idx = self.ready[nid]
            name, _ = self.schedule.order[idx]
            self.scan_exec.setdefault(name, []).append(by_id[nid])

        # Every in-scan getter's site is collected (stacked over layers) so
        # post-scan consumers see all layers.
        self.collect_sites = tuple(sorted(self.scan_getters.keys()))

        # EVERY in-scan op node re-executes post-scan against the collected
        # (stacked) getter values: setter-closure nodes ran in-body with
        # iteration-local tracers that must not escape the scan, so their env
        # entries are recomputed from the delivered stacks for any post-scan
        # consumer (e.g. a .save() of a written-back value).
        for n in self.graph.nodes:
            idx = self.ready[n.id]
            if (
                n.op not in ("tap_get", "tap_set", "grad_get", "constant", "input")
                and idx not in (PRE_SITE,)
                and idx < POST_SITE
                and self._in_scan(idx, scan_set)
            ):
                self.exec_at.setdefault(_POST_SCAN, []).append(n)

    # ------------------------------------------------------------------ API
    def has_interventions(self) -> bool:
        return len(self.graph.nodes) > 0


_POST_SCAN = POST_SITE - 1  # pseudo-index: runs right after scan delivery


def last_referenced_site(
    graph: InterventionGraph, schedule: SiteSchedule
) -> int:
    """Index into ``schedule.order`` of the LAST site any tap node touches.

    The truncation point for ``tracer.stop()``: model execution past this
    site cannot affect any getter, setter, or save, so the interleaver may
    abandon the forward there.  ``.grad`` graphs truncate too: every
    perturbation site is referenced by its ``grad_get`` node (counted
    here), and the in-graph loss only reads tapped values, so the
    differentiated forward is cut strictly past everything the loss — and
    therefore the backward pass — can depend on.
    """
    site_index = schedule.index()
    idx = [
        site_index[(n.site, n.layer)]
        for n in graph.nodes
        if n.op in ("tap_get", "tap_set", "grad_get")
        and (n.site, n.layer) in site_index
    ]
    return max(idx, default=PRE_SITE)


class InterleaveState:
    """Per-execution runtime: env of node values, fired sites, logs."""

    def __init__(
        self,
        plan: Interleaver,
        inputs: dict[str, Any] | None = None,
        perts: dict[Any, Any] | None = None,
        const_env: dict[int, Any] | None = None,
        stop_after: int | None = None,
        log_cb: Callable[[int, Any], None] | None = None,
        cross_shapes: dict[str, Any] | None = None,
    ) -> None:
        self.plan = plan
        self.env: dict[int, Any] = {}
        self.logs: list[tuple[int, Any]] = []
        self.perts = perts or {}
        # Early termination (tracer.stop()): after processing the site at
        # this schedule index, abandon the model forward via EarlyStop.
        # Scan-mode sites cannot interrupt a running lax.scan, so the stop
        # fires at the first NON-scan site at/past the index instead.
        self.stop_after = stop_after
        # With a log callback, `log` nodes lower to jax.debug.callback so
        # the body stays compilable; without one they append traced values
        # to self.logs at trace time (the eager contract).
        self.log_cb = log_cb
        # Abstract specs (by site name) for zero-initialising the scan-carry
        # slots of cross-layer getters whose value is not yet in the env.
        self.cross_shapes = cross_shapes or {}
        self._cross_ids = {g.id for g in plan.cross_getters}
        self._scan_record: dict[str, Any] = {}
        self._executed: set[int] = set()
        inputs = inputs or {}
        const_env = const_env or {}
        for n in plan.graph.nodes:
            if n.op == "constant":
                # const_env lets the serving engine pass constant VALUES as
                # runtime arguments so structurally-identical graphs share one
                # compiled executable (no recompile per patched value).
                self.env[n.id] = const_env.get(n.id, n.args[0])
            elif n.op == "input":
                name = n.args[0]
                if name not in inputs:
                    raise KeyError(f"experiment input {name!r} not provided")
                self.env[n.id] = inputs[name]
        # Pre-site ops (pure functions of constants/inputs) run up front.
        for node in self.plan.exec_at.get(PRE_SITE, []):
            self._exec_node(node)

    # ------------------------------------------------------------- resolve
    def _resolve(self, obj: Any) -> Any:
        return map_refs(obj, lambda r: self.env[r.node_id])

    def _exec_node(self, node: Node) -> None:
        if node.id in self._executed:
            return
        self._executed.add(node.id)
        args = self._resolve(node.args)
        kwargs = self._resolve(node.kwargs)
        if node.op == "save":
            self.env[node.id] = args[0]
        elif node.op == "log":
            self.env[node.id] = args[0]
            if self.log_cb is not None:
                jax.debug.callback(
                    partial(self.log_cb, node.id), args[0], ordered=True
                )
            else:
                self.logs.append((node.id, args[0]))
        else:
            self.env[node.id] = resolve_op(node.op)(*args, **kwargs)

    # ------------------------------------------------------------- on_site
    def on_site(self, name: str, value: Any, layer: Any = None) -> Any:
        plan = self.plan
        if plan.mode == "scan" and name in plan.schedule.scan_sites:
            return self._on_scan_site(name, value, layer)

        key = (name, layer)
        for g in plan.getters_at.get(key, []):
            self.env[g.id] = value
        if key in self.perts:
            value = jax.tree.map(lambda v, p: v + p, value, self.perts[key])
            for g in plan.getters_at.get(key, []):
                self.env[g.id] = value
        idx = plan.site_index.get(key)
        if idx is None:
            return value
        for node in plan.exec_at.get(idx, []):
            self._exec_node(node)
        for s in plan.setters_at.get(key, []):
            self._executed.add(s.id)
            value = self._resolve(s.args)[0]
            self.env[s.id] = value
        if self.stop_after is not None and idx >= self.stop_after:
            # everything the graph references has fired — abandon the rest
            # of the model forward (run_interleaved catches this)
            raise EarlyStop(key)
        return value

    def _on_scan_site(self, name: str, value: Any, layer: Any) -> Any:
        plan = self.plan
        if name in self.perts:
            # pert is stacked (n_layers, *shape): pick this iteration's slab.
            pert = jax.tree.map(lambda p: p[layer], self.perts[name])
            value = jax.tree.map(lambda v, p: v + p, value, pert)
        if name in plan.collect_sites:
            # grouped scan bodies (VLM super-layers, Zamba2 groups) fire a
            # site several times per iteration: keep every fire, in order.
            self._scan_record.setdefault(name, []).append(value)
        for g in plan.scan_getters.get(name, []):
            if g.id in self._cross_ids and g.id in self.env:
                # Cross-layer getter: latch the value at its own iteration,
                # keep the carried value everywhere else.  The env slot was
                # seeded by scan_env_provide from the scan carry.
                cond = jnp.asarray(layer == g.layer)
                self.env[g.id] = jax.tree.map(
                    lambda v_, p_: jnp.where(cond, v_, p_),
                    value, self.env[g.id],
                )
            else:
                # Per-iteration symbolic binding; only same-layer setter
                # closures consume it, under a layer-index mask.
                self.env[g.id] = value
        for node in plan.scan_exec.get(name, []):
            self._exec_node(node)
            self._executed.discard(node.id)  # may re-run post-scan
        for s in plan.scan_setters.get(name, []):
            new = self._resolve(s.args)[0]
            cond = jnp.asarray(layer == s.layer)
            value = jax.tree.map(
                lambda n_, v_: jnp.where(cond, n_, v_), new, value
            )
            self._executed.add(s.id)
        return value

    # -------------------------------------------------------- scan plumbing
    def scan_collect_values(self) -> dict:
        """Sites recorded THIS scan body (multi-scan models — e.g. an
        encoder scan then a decoder scan — each collect their own sites)."""
        out = {}
        for name, fires in self._scan_record.items():
            out[name] = (
                fires[0] if len(fires) == 1
                else jax.tree.map(lambda *xs: jnp.stack(xs), *fires)
            )
        self._scan_record = {}
        return out

    def scan_env_init(self) -> dict[int, Any]:
        """Initial scan-carry slots for cross-layer getters.

        Values already in the env (delivered by an earlier scan of a
        multi-scan model) seed their slot; otherwise the slot starts as
        zeros from the abstract site spec — it is latched with the real
        value at the getter's own iteration, before any consumer reads it.
        """
        out: dict[int, Any] = {}
        for g in self.plan.cross_getters:
            if g.id in self.env:
                out[g.id] = self.env[g.id]
                continue
            spec = self.cross_shapes.get(g.site)
            if spec is None:
                raise GraphValidationError(
                    f"scan mode: no shape captured for cross-layer getter "
                    f"%{g.id} at site {g.site!r}; the caller must pass "
                    "cross_shapes from capture_site_shapes"
                )
            out[g.id] = jax.tree.map(
                lambda s: jnp.zeros(tuple(s.shape), s.dtype), spec
            )
        return out

    def scan_env_provide(self, env_c: dict[int, Any]) -> None:
        """Bind the carried intervention env at the top of a scan body."""
        for gid, v in env_c.items():
            self.env[gid] = v

    def scan_env_update(self, env_c: dict[int, Any]) -> dict[int, Any]:
        """New carry at the bottom of a scan body (same structure as init)."""
        return {gid: self.env[gid] for gid in env_c}

    def _site_layers(self, name: str) -> list[int]:
        return [l for (n, l) in self.plan.schedule.order if n == name]

    def deliver_scan(self, ys: dict) -> None:
        """Model calls this right after ``lax.scan`` with the stacked ys.

        ys[name] is (n_iter, ...) for once-per-iteration sites or
        (n_iter, fires_per_iter, ...) for grouped bodies; a getter's global
        layer maps to (iteration, slot) via the site schedule."""
        for name, stacked in ys.items():
            getters = self.plan.scan_getters.get(name, [])
            if not getters:
                continue
            layers = self._site_layers(name)
            n_iter = jax.tree.leaves(stacked)[0].shape[0]
            fires = max(len(layers) // max(n_iter, 1), 1)
            for g in getters:
                pos = layers.index(g.layer)
                if fires == 1:
                    self.env[g.id] = jax.tree.map(
                        lambda s: s[pos], stacked
                    )
                else:
                    self.env[g.id] = jax.tree.map(
                        lambda s: s[pos // fires, pos % fires], stacked
                    )
        for node in self.plan.exec_at.get(_POST_SCAN, []):
            # drop any iteration-local value computed inside the scan body
            # and recompute from the collected stacks.  Multi-scan models
            # (enc-dec) deliver per scan: skip nodes whose getters belong to
            # a scan that has not run yet.
            deps_ready = all(
                r.node_id in self.env or
                self.plan.graph.node(r.node_id).op in ("constant", "input")
                for r in node.refs()
            )
            if not deps_ready:
                continue
            self._executed.discard(node.id)
            self.env.pop(node.id, None)
            self._exec_node(node)

    # ------------------------------------------------------------- finalize
    def finalize(self, include_grad_dependents: bool = False) -> None:
        """Execute everything not yet run (post-output nodes)."""
        grad_ids = {n.id for n in self.plan.grad_nodes}
        closure = self._grad_dependent_ids() if not include_grad_dependents else set()
        for node in self.plan.graph.nodes:
            if node.id in self._executed or node.id in self.env:
                continue
            if node.op in ("tap_get", "tap_set", "grad_get"):
                if node.id not in grad_ids:
                    raise GraphValidationError(
                        f"node %{node.id} taps site ({node.site!r}, "
                        f"{node.layer}) which never fired during execution"
                    )
                continue
            if node.id in closure:
                continue  # needs gradients; the grad driver runs it later
            self._exec_node(node)

    def _grad_dependent_ids(self) -> set[int]:
        grad_ids = {n.id for n in self.plan.grad_nodes}
        out: set[int] = set()
        for node in self.plan.graph.nodes:
            deps = {r.node_id for r in node.refs()}
            if deps & (grad_ids | out):
                out.add(node.id)
        return out

    def saves(self) -> dict[str, Any]:
        return {
            name: self.env[nid]
            for name, nid in self.plan.graph.saves.items()
            if nid in self.env
        }


# ----------------------------------------------------------------- capture
class _ShapeCaptureState:
    """Minimal state used under jax.eval_shape to learn tap-site shapes."""

    def __init__(self, keys: set[Any], scan_sites: set[str]) -> None:
        self.keys = keys
        self.scan_sites = scan_sites
        self.shapes: dict[Any, Any] = {}

    def on_site(self, name: str, value: Any, layer: Any = None) -> Any:
        spec = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(jnp.shape(v), jnp.result_type(v)),
            value,
        )
        if name in self.scan_sites:
            if name in self.keys:
                self.shapes[name] = spec
        else:
            key = (name, layer)
            if key in self.keys:
                self.shapes[key] = spec
            # All requested keys captured: abandon the abstract forward
            # (mirrors tracer.stop() truncation; never inside a scan body).
            if self.keys <= set(self.shapes):
                raise EarlyStop((name, layer))
        return value

    def scan_collect_values(self) -> dict:
        return {}

    def deliver_scan(self, ys: dict) -> None:  # pragma: no cover - trivial
        pass


def capture_site_shapes(
    model_fn: Callable[..., Any],
    args: tuple,
    kwargs: dict,
    keys: set[Any],
    scan_sites: tuple[str, ...],
) -> dict[Any, Any]:
    cap = _ShapeCaptureState(keys, set(scan_sites))

    def run(a, k):
        taps.push_state(cap)  # type: ignore[arg-type]
        try:
            return model_fn(*a, **k)
        except EarlyStop:
            return None  # every requested key already captured
        finally:
            taps.pop_state()

    jax.eval_shape(run, args, kwargs)
    missing = keys - set(cap.shapes)
    if missing:
        raise GraphValidationError(f"tap sites never fired: {missing}")
    return cap.shapes


# ------------------------------------------------------------------ fused
def make_step_callable(
    model_fn: Callable[..., Any],
    graph: InterventionGraph,
    schedule: SiteSchedule,
    *,
    mode: str = "unrolled",
    log_cb: Callable[[int, Any], None] | None = None,
) -> Callable[..., tuple[Any, dict[str, Any]]]:
    """Emit a jit-able interleaved step function with the plan built ONCE.

    The returned ``step(args, kwargs, inputs=None, const_env=None)`` runs
    ``model_fn`` with ``graph``'s getters/setters applied inside the traced
    body and returns ``(model_output, saves)`` — a pure function of array
    inputs, safe to trace inside ``jax.lax.scan`` (the fused decode loop of
    :mod:`repro.core.generation` uses it as the scan body, so per-step saves
    come back as stacked scan ys).

    Every graph feature lowers into the traced body (the final-style
    interpreter): ``log`` nodes emit through ``jax.debug.callback`` to
    ``log_cb`` (default: the module-level :data:`LOG_SINK`), ``.grad``
    graphs run the perturbation driver inside the step — the loss and its
    gradients are part of the traced program, so the step still scans — and
    scan-mode cross-layer flow rides the intervention-env carry.  Nothing
    is rejected up front any more.
    """
    plan = Interleaver(graph, schedule, mode=mode)
    if log_cb is None and any(n.op == "log" for n in graph.nodes):
        log_cb = LOG_SINK.emit
    cross_sites = {g.site for g in plan.cross_getters}

    def step(
        args: tuple,
        kwargs: dict | None = None,
        inputs: dict[str, Any] | None = None,
        const_env: dict[int, Any] | None = None,
    ) -> tuple[Any, dict[str, Any]]:
        kwargs_ = kwargs or {}
        if plan.grad_nodes:
            out, saves, _ = _run_grad(
                plan, model_fn, args, kwargs_,
                inputs=inputs, const_env=const_env, log_cb=log_cb,
            )
            return out, saves
        cross_shapes = None
        if cross_sites:
            cross_shapes = capture_site_shapes(
                model_fn, args, kwargs_, set(cross_sites),
                schedule.scan_sites,
            )
        state = InterleaveState(plan, inputs=inputs, const_env=const_env,
                                log_cb=log_cb, cross_shapes=cross_shapes)
        taps.push_state(state)
        try:
            out = model_fn(*args, **kwargs_)
        finally:
            taps.pop_state()
        state.finalize(include_grad_dependents=True)
        return out, state.saves()

    return step


# ------------------------------------------------------------------ driver
def run_interleaved(
    model_fn: Callable[..., Any],
    graph: InterventionGraph,
    schedule: SiteSchedule,
    args: tuple = (),
    kwargs: dict | None = None,
    *,
    mode: str = "unrolled",
    inputs: dict[str, Any] | None = None,
    const_env: dict[int, Any] | None = None,
    stop_after_site: int | None = None,
    log_cb: Callable[[int, Any], None] | None = None,
) -> tuple[Any, dict[str, Any], list[tuple[int, Any]]]:
    """Run ``model_fn(*args, **kwargs)`` with ``graph`` interleaved.

    Pure function of its inputs — safe to wrap in ``jax.jit`` (the serving
    engine does).  Returns ``(model_output, saves, logs)``.

    ``log_cb`` lowers ``log`` nodes to ``jax.debug.callback`` so the body
    stays compilable under an outer ``jax.jit`` — the callback fires on
    every EXECUTION (cache hits included), not just at trace time; the
    returned ``logs`` list is then empty and the caller drains its sink
    (see :class:`LogSink`).  Without it, logs are traced values appended at
    trace time — correct only for unjitted callers.

    ``stop_after_site`` (``tracer.stop()``) abandons the model forward right
    after the schedule index fires — typically
    :func:`last_referenced_site` — returning ``None`` as the model output;
    saves are assembled from the partial execution.  The EarlyStop raise
    happens at trace time, so a jitted caller lowers a program that is both
    truncated and compiled.  ``.grad`` composes with it: the perturbation
    driver differentiates the truncated forward (every grad site is
    referenced, so it fires before the stop).
    """
    kwargs = kwargs or {}
    plan = Interleaver(graph, schedule, mode=mode)

    if plan.grad_nodes:
        return _run_grad(
            plan, model_fn, args, kwargs, inputs=inputs,
            const_env=const_env, stop_after=stop_after_site,
            log_cb=log_cb,
        )

    cross_shapes = None
    if plan.cross_getters:
        cross_shapes = capture_site_shapes(
            model_fn, args, kwargs, {g.site for g in plan.cross_getters},
            schedule.scan_sites,
        )
    state = InterleaveState(plan, inputs=inputs, const_env=const_env,
                            stop_after=stop_after_site,
                            log_cb=log_cb,
                            cross_shapes=cross_shapes)
    taps.push_state(state)
    try:
        out = model_fn(*args, **kwargs)
    except EarlyStop:
        out = None  # truncated: sites past the last referenced one
    finally:
        taps.pop_state()
    state.finalize(include_grad_dependents=True)
    return out, state.saves(), state.logs


# ---------------------------------------------------------------- gradients
def _run_grad(
    plan: Interleaver,
    model_fn: Callable[..., Any],
    args: tuple,
    kwargs: dict,
    *,
    inputs: dict[str, Any] | None = None,
    const_env: dict[int, Any] | None = None,
    stop_after: int | None = None,
    log_cb: Callable[[int, Any], None] | None = None,
) -> tuple[Any, dict[str, Any], list[tuple[int, Any]]]:
    """Perturbation-trick gradient driver, shared by :func:`run_interleaved`
    and :func:`make_step_callable`.

    Pure function of its array inputs: the loss, gradients, and the
    grad-dependent subgraph all execute inside the caller's trace (no
    Python-side env mutation escapes), so the whole thing jits and scans.
    """
    graph, schedule, mode = plan.graph, plan.schedule, plan.mode
    if mode == "scan":
        pert_keys = {k[0] for k in plan.grad_keys}  # site names
    else:
        pert_keys = set(plan.grad_keys)
    cross_sites = {g.site for g in plan.cross_getters}
    shapes = capture_site_shapes(
        model_fn, args, kwargs, pert_keys | cross_sites, schedule.scan_sites
    )

    def zeros_for(key: Any) -> Any:
        spec = shapes[key]
        if mode == "scan" and key in schedule.scan_sites:
            n = schedule.n_layers
            return jax.tree.map(
                lambda s: jnp.zeros((n,) + tuple(s.shape), s.dtype), spec
            )
        return jax.tree.map(
            lambda s: jnp.zeros(tuple(s.shape), s.dtype), spec
        )

    perts0 = {key: zeros_for(key) for key in pert_keys}

    def fwd(perts):
        state = InterleaveState(plan, inputs=inputs, perts=perts,
                                const_env=const_env, stop_after=stop_after,
                                log_cb=log_cb, cross_shapes=shapes)
        taps.push_state(state)
        try:
            out = model_fn(*args, **kwargs)
        except EarlyStop:
            out = None  # truncated past the last referenced site
        finally:
            taps.pop_state()
        state.finalize(include_grad_dependents=False)
        loss = state.env[graph.backward_loss]
        # Everything grad-dependent nodes will need, keyed by node id.
        needed = _env_needed_post_grad(plan)
        carried = {nid: state.env[nid] for nid in needed if nid in state.env}
        return loss, (out, state.saves(), state.logs, carried)

    (_loss, (out, saves, logs, carried)), grads = jax.value_and_grad(
        fwd, has_aux=True
    )(perts0)

    # Bind grad_get nodes and run the remaining (grad-dependent) subgraph.
    state = InterleaveState.__new__(InterleaveState)
    state.plan = plan
    state.env = dict(carried)
    state.logs = list(logs)
    state.perts = {}
    state.log_cb = log_cb
    state.cross_shapes = {}
    state._cross_ids = set()
    state._scan_record = {}
    state._executed = set(carried.keys())
    for n in plan.grad_nodes:
        if mode == "scan" and n.site in schedule.scan_sites:
            g = jax.tree.map(lambda p: p[n.layer], grads[n.site])
        else:
            g = grads[(n.site, n.layer)]
        state.env[n.id] = g
        state._executed.add(n.id)
    for n in graph.nodes:
        if n.op == "constant":
            state.env.setdefault(n.id, (const_env or {}).get(n.id, n.args[0]))
        elif n.op == "input":
            state.env.setdefault(n.id, (inputs or {})[n.args[0]])
    dep_ids = _grad_dependent_closure(graph, {n.id for n in plan.grad_nodes})
    for node in graph.nodes:
        if node.id in dep_ids and node.id not in state._executed:
            state._exec_node(node)
    saves = dict(saves)
    saves.update(
        {
            name: state.env[nid]
            for name, nid in graph.saves.items()
            if nid in state.env and name not in saves
        }
    )
    return out, saves, state.logs


def _grad_dependent_closure(
    graph: InterventionGraph, grad_ids: set[int]
) -> set[int]:
    out: set[int] = set()
    for node in graph.nodes:
        deps = {r.node_id for r in node.refs()}
        if deps & (grad_ids | out):
            out.add(node.id)
    return out


def _env_needed_post_grad(plan: Interleaver) -> set[int]:
    """Node ids whose values the post-grad subgraph reads from the fwd env."""
    graph = plan.graph
    grad_ids = {n.id for n in plan.grad_nodes}
    closure = _grad_dependent_closure(graph, grad_ids)
    needed: set[int] = set()
    for node in graph.nodes:
        if node.id in closure:
            for r in node.refs():
                if r.node_id not in closure and r.node_id not in grad_ids:
                    needed.add(r.node_id)
    return needed
