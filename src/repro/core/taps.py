"""Tap sites — the JAX replacement for PyTorch module hooks.

Models in this framework are pure functions that call ``taps.site(name, v)``
wherever the paper's NNsight would expose a module ``.input``/``.output``.
With no interleave state active the call is the identity (and costs nothing
after XLA DCE).  During an interleaved execution it hands the value to the
active :class:`~repro.core.interleave.InterleaveState`, which may read it
(getters), replace it (setters), or record it for collection.

Layered models come in two flavours:

* **unrolled** — a Python loop over layers; ``layer=i`` is a concrete int.
  Fully general interventions (any cross-layer data flow).
* **scan** — ``jax.lax.scan`` over stacked layer params; ``layer`` is a traced
  index.  Compile time is O(1) in depth (required for the 62–100 layer
  production configs).  A setter inside the scan may consume getters from
  the same layer iteration or any *earlier* one: forward cross-layer values
  thread through the scan carry, which models expose by bracketing their
  scan body with ``scan_env_init``/``scan_env_provide``/``scan_env_update``.
  Backward flow (a getter from a later iteration) is rejected up front.
  Per-layer getter values are emitted as stacked scan outputs
  (``taps.scan_outputs()``) so post-scan nodes see every layer.
"""
from __future__ import annotations

from typing import Any, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.interleave import InterleaveState

__all__ = [
    "site", "scan_outputs", "push_state", "pop_state", "active_state",
    "scan_env_init", "scan_env_provide", "scan_env_update",
]

_ACTIVE: list["InterleaveState | None"] = []


def push_state(state: "InterleaveState | None") -> None:
    _ACTIVE.append(state)


def pop_state() -> None:
    _ACTIVE.pop()


def active_state() -> "InterleaveState | None":
    return _ACTIVE[-1] if _ACTIVE else None


def site(name: str, value: Any, layer: Any = None) -> Any:
    """Declare a tap site. Returns ``value``, possibly intervened upon."""
    state = active_state()
    if state is None:
        return value
    return state.on_site(name, value, layer)


def deliver_scan(ys: dict) -> None:
    """Model calls this right after ``lax.scan`` with the stacked ys dict."""
    state = active_state()
    if state is not None:
        state.deliver_scan(ys)


def scan_env_init() -> dict:
    """Before a ``lax.scan``: initial carry for the intervention env.

    Models thread the returned dict through their scan carry so forward
    cross-layer data flow survives iteration boundaries.  With no active
    state (or no cross-layer getters) it is ``{}`` — zero extra carry
    leaves, the scan signature is unchanged.
    """
    state = active_state()
    fn = getattr(state, "scan_env_init", None)
    return fn() if fn is not None else {}


def scan_env_provide(env_c: dict) -> None:
    """Top of a scan body: bind the carried intervention env slots."""
    state = active_state()
    fn = getattr(state, "scan_env_provide", None)
    if fn is not None:
        fn(env_c)


def scan_env_update(env_c: dict) -> dict:
    """Bottom of a scan body: the new env carry (same structure as init)."""
    state = active_state()
    fn = getattr(state, "scan_env_update", None)
    return fn(env_c) if fn is not None else env_c


def scan_outputs() -> dict:
    """Inside a scan body: per-iteration site values the executor collects.

    Models in scan mode must include this dict in their ``lax.scan`` ys.
    The structure is static (derived from the intervention graph), so with no
    interventions it is ``{}`` and the scan signature is unchanged.
    """
    state = active_state()
    if state is None:
        return {}
    return state.scan_collect_values()
