"""Static analysis (preflight) for intervention graphs.

The serving promise of the paper (§3.3/B.2) is that a user-authored
intervention graph runs safely on shared infrastructure next to strangers'
requests.  Before this module, every error class was discovered
*dynamically*: a bad user op crashed a shared compiled decode step and was
attributed after the fact by solo trial-runs, fused ineligibility was
learned by paying a failed XLA trace, and merge conflicts threw
mid-``drain()``.  This module is the front door instead — a static pass
over the graph IR that runs **zero model forwards**:

  * :func:`infer_shapes` — an abstract interpreter.  Tap-site shapes
    (learned once per batch signature via ``jax.eval_shape`` of the model,
    see :func:`capture_forward_avals` / :func:`capture_generation_avals`)
    seed per-node ``ShapeDtypeStruct``s which propagate through every
    registry op with ``jax.eval_shape`` — the exact abstraction JIT tracing
    applies at runtime, so a broadcast/dtype/rank error in a user op is
    caught *here*, with the offending node (and the user's source line)
    named, instead of inside a shared step with innocent co-tenants
    resident.
  * :func:`check_merge_plan` — the co-tenant conflict detector: given the
    row starts/sizes a merge would assign, proves the plan's row ranges
    are disjoint and in-bounds, and reports cross-tenant read/write
    relationships on the same ``(site, layer, step)`` — "merge and hope"
    becomes a checked merge plan.
  * :func:`lint_fusion` / :func:`scan_fusion_reason` — fusion-eligibility
    lints with machine-readable reasons (``cross-step-flow``,
    ``non-uniform``, ``scan-cross-layer`` for backward flow only), so the
    fused planner consults verdicts instead of burning failed XLA traces
    into failure keys.  ``log``/``grad``/forward-cross-layer graphs now
    lint ``ok`` — the harvest-style interpreter compiles them.
  * :func:`dead_nodes` / :func:`eliminate_dead` / :func:`infer_stop_site`
    — dead-node elimination and stop inference as analysis facts.

Every finding is a structured :class:`Diagnostic` (code, severity, node
id, user source line captured at trace time — see ``repro.core.tracer``).
Severity calibration is deliberate: ``error`` means "this graph WILL fail
at runtime" (enforcing mode rejects it), anything the statics cannot prove
is at most a ``warning`` — a clean verdict must never reject a graph that
would have run (the zero-false-positive contract).  Unknown values
propagate as unknown and disable downstream checks rather than guessing.

Enforcement is controlled by ``REPRO_PREFLIGHT`` (``enforce`` [default] |
``warn`` | ``off``) and wired into four layers: tracer exit,
``serving.client`` (before a request ships), ``serving.scheduler`` /
``serving.engine`` admission (before a graph touches the slot loop), and
the fused planner in ``core.generation``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (
    ALL_STEPS,
    PRE_SITE,
    PRE_STEP,
    PREFILL_STEP,
    SOURCE_META_KEY,
    GraphValidationError,
    InterventionGraph,
    Node,
    Ref,
    map_refs,
)
from repro.core.op_registry import resolve_op

__all__ = [
    "Diagnostic",
    "AnalysisReport",
    "FusionVerdict",
    "PreflightError",
    "ERROR",
    "WARNING",
    "NOTE",
    "preflight_mode",
    "infer_shapes",
    "analyze",
    "check_merge_plan",
    "check_page_plan",
    "lint_fusion",
    "scan_fusion_reason",
    "dead_nodes",
    "eliminate_dead",
    "infer_stop_site",
    "capture_forward_avals",
    "capture_generation_avals",
    "aval_signature",
    "source_of",
]

ERROR = "error"
WARNING = "warning"
NOTE = "note"

_PROTOCOL_OPS = frozenset(
    ["tap_get", "tap_set", "grad_get", "save", "log", "constant", "input"]
)


# --------------------------------------------------------------- diagnostics
@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of the static pass.

    ``code`` is machine-readable (stable across message rewording);
    ``source`` is the user source line captured at trace time (or None for
    graphs built directly / received over the wire).
    """

    code: str
    severity: str
    message: str
    node: int | None = None
    site: str | None = None
    step: int | None = None
    source: str | None = None

    def format(self) -> str:
        loc = f" %{self.node}" if self.node is not None else ""
        at = f" @{self.site}" if self.site else ""
        if self.step is not None and self.step >= 0:
            at += f"[step {self.step}]"
        src = f"  ({self.source})" if self.source else ""
        return f"{self.severity}[{self.code}]{loc}{at}: {self.message}{src}"


class PreflightError(GraphValidationError):
    """Raised in enforcing mode when the analyzer finds definite errors."""

    def __init__(self, diagnostics: list[Diagnostic]) -> None:
        self.diagnostics = list(diagnostics)
        errs = [d for d in self.diagnostics if d.severity == ERROR]
        super().__init__(
            "preflight failed: "
            + "; ".join(d.format() for d in errs or self.diagnostics)
        )


@dataclasses.dataclass(frozen=True)
class FusionVerdict:
    """Fusion eligibility of one decode step slice (machine-readable)."""

    step: int
    fusable: bool
    # ok|empty|cross-step-flow|non-uniform|scan-cross-layer (backward flow);
    # log/grad/forward-cross-layer slices are "ok" — they compile
    reason: str
    detail: str = ""


@dataclasses.dataclass
class AnalysisReport:
    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    # node id -> ShapeDtypeStruct pytree, or None when statically unknown
    avals: dict[int, Any] = dataclasses.field(default_factory=dict)
    dead: tuple[int, ...] = ()
    stop_site: int | None = None
    fusion: list[FusionVerdict] = dataclasses.field(default_factory=list)

    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    def ok(self) -> bool:
        return not self.errors()

    def format(self) -> str:
        return "\n".join(d.format() for d in self.diagnostics) or "clean"

    def enforce(self, mode: str | None = None) -> "AnalysisReport":
        """Apply the preflight policy: raise on errors when enforcing."""
        mode = mode or preflight_mode()
        if mode == "enforce" and not self.ok():
            raise PreflightError(self.diagnostics)
        return self


def preflight_mode() -> str:
    """``REPRO_PREFLIGHT``: ``enforce`` (default) | ``warn`` | ``off``."""
    mode = os.environ.get("REPRO_PREFLIGHT", "enforce").lower()
    return mode if mode in ("off", "warn", "enforce") else "enforce"


def source_of(node: Node) -> str | None:
    """The user source line stamped at trace time (None if unavailable)."""
    src = node.meta.get(SOURCE_META_KEY)
    return src if isinstance(src, str) else None


def _diag(
    code: str, severity: str, message: str, node: Node | None = None
) -> Diagnostic:
    return Diagnostic(
        code=code,
        severity=severity,
        message=message,
        node=node.id if node is not None else None,
        site=node.site if node is not None else None,
        step=node.step if node is not None else None,
        source=source_of(node) if node is not None else None,
    )


# ------------------------------------------------------- site-aval capture
class _CaptureAllSites:
    """taps-state shim: record EVERY site's aval under jax.eval_shape.

    Unlike ``interleave.capture_site_shapes`` this captures everything that
    fires (no required-keys contract) and tolerates traced layer indices
    (scan mode) by falling back to a by-name record.
    """

    def __init__(self) -> None:
        self.avals: dict[Any, Any] = {}

    def on_site(self, name: str, value: Any, layer: Any = None) -> Any:
        spec = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(jnp.shape(v), jnp.result_type(v)),
            value,
        )
        try:
            key = (name, int(layer) if layer is not None else None)
        except Exception:  # traced layer index inside lax.scan
            key = (name, None)
            self.avals.setdefault(name, spec)
        self.avals.setdefault(key, spec)
        self.avals.setdefault(name, spec)
        return value

    def scan_collect_values(self) -> dict:
        return {}

    def deliver_scan(self, ys: dict) -> None:  # pragma: no cover - trivial
        pass


def _abstract_tree(tree: Any) -> Any:
    return jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(jnp.shape(v), jnp.result_type(v)), tree
    )


def aval_signature(*trees: Any) -> tuple:
    """A hashable (shape, dtype) signature of pytrees — the cache key for
    captured site avals (same signature ⇒ same avals, no re-capture)."""
    sig = []
    for t in trees:
        for leaf in jax.tree.leaves(t):
            sig.append((tuple(jnp.shape(leaf)), str(jnp.result_type(leaf))))
    return tuple(sig)


def capture_forward_avals(
    model_fn: Callable[..., Any], args: tuple, kwargs: dict | None = None
) -> dict[Any, Any]:
    """Avals of every tap site fired by ONE abstract model evaluation.

    Zero FLOPs — ``jax.eval_shape`` only; params/batch may be concrete
    arrays or ``ShapeDtypeStruct``s (a weightless client passes abstract
    params from ``jax.eval_shape(model.init, ...)``).
    """
    from repro.core import taps

    cap = _CaptureAllSites()

    def run(a, k):
        taps.push_state(cap)  # type: ignore[arg-type]
        try:
            return model_fn(*a, **k)
        finally:
            taps.pop_state()

    jax.eval_shape(run, args, kwargs or {})
    return cap.avals


def capture_generation_avals(
    model: Any,
    params: Any,
    batch: dict,
    *,
    max_len: int,
    mode: str = "unrolled",
    cache_kind: str = "full",
) -> tuple[dict[Any, Any], dict[Any, Any]]:
    """(prefill_avals, decode_avals) for a generation request — no FLOPs.

    Prefill sites see ``(B, S, ...)`` activations, decode-step sites see
    ``(B, 1, ...)``; an analyzed generation graph checks each node against
    the avals of the execution it is scheduled on.  Single-token prompts
    have no prefill execution (empty-cache init), so their prefill avals
    are empty.
    """
    from repro.core import taps

    batch = dict(batch)
    tokens = batch.pop("tokens")
    batch.pop("lengths", None)
    tok_aval = _abstract_tree(tokens)
    B, S = int(tok_aval.shape[0]), int(tok_aval.shape[1])
    extras = {k: _abstract_tree(v) for k, v in batch.items()}
    cap_pre = _CaptureAllSites()

    def run_prefill(p, b):
        taps.push_state(cap_pre)  # type: ignore[arg-type]
        try:
            _out, cache = model.prefill(
                p, b, mode=mode, kind=cache_kind, max_len=max_len
            )
            return cache
        finally:
            taps.pop_state()

    if S > 1:
        cache_aval = jax.eval_shape(
            run_prefill, params, {"tokens": tok_aval, **extras}
        )
    else:  # S == 1 decodes from an empty cache; no prefill sites fire
        cache_aval = jax.eval_shape(
            lambda p, b: model.empty_cache(p, b, B, max_len, kind=cache_kind),
            params,
            {"tokens": tok_aval, **extras},
        )
        cap_pre.avals.clear()

    cap_dec = _CaptureAllSites()

    def run_decode(p, cache, token, pos):
        taps.push_state(cap_dec)  # type: ignore[arg-type]
        try:
            return model.decode_step(
                p, cache, {"token": token, "pos": pos}, mode=mode
            )
        finally:
            taps.pop_state()

    jax.eval_shape(
        run_decode,
        params,
        cache_aval,
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
    )
    # decode logits are a site in spirit: the loop reads out["logits"]
    return cap_pre.avals, cap_dec.avals


# --------------------------------------------------------- shape inference
class _Concrete:
    """A value the abstract interpreter keeps CONCRETE (constants).

    Closing constants over the ``eval_shape`` body reproduces runtime
    semantics exactly — weak-typed Python scalars stay weak, ints used as
    static indices stay static."""

    __slots__ = ("value",)

    def __init__(self, value: Any) -> None:
        self.value = value


class _Leaf:
    __slots__ = ("i",)

    def __init__(self, i: int) -> None:
        self.i = i


# eval_shape failures that mean "statically undecidable", not "broken":
# the op needs concrete VALUES (boolean masks, traced python control flow)
# that runtime has but the abstract interpreter does not.
_UNDECIDABLE = (
    jax.errors.ConcretizationTypeError,
    jax.errors.NonConcreteBooleanIndexError,
    jax.errors.TracerArrayConversionError,
    jax.errors.TracerBoolConversionError,
    jax.errors.TracerIntegerConversionError,
)


# eval_shape is pure in (op, arg avals, concrete closure values), so its
# results memoize across nodes, graphs, and repeated traces — the repeated
# identically-shaped request is the serving steady state, and per-node
# tracing is the whole cost of an analyze pass.
_EVAL_CACHE: dict[Any, Any] = {}
_EVAL_CACHE_MAX = 4096


def _eval_cache_key(op_fn: Callable, args: tuple, kwargs: dict,
                    env: dict) -> Any:
    from repro.core.graph import _freeze_value

    def fz(o: Any) -> Any:
        if isinstance(o, Ref):
            v = env[o.node_id]
            if isinstance(v, _Concrete):
                return ("__c__", _freeze_value(np.asarray(v.value)))
            return (
                "__aval__",
                str(jax.tree.structure(v)),
                tuple((tuple(l.shape), str(l.dtype))
                      for l in jax.tree.leaves(v)),
            )
        if isinstance(o, (tuple, list)):
            return ("__seq__", type(o).__name__) + tuple(fz(x) for x in o)
        if isinstance(o, dict):
            return ("__map__",) + tuple(
                sorted((str(k), fz(v)) for k, v in o.items())
            )
        if isinstance(o, slice):
            return ("__slice__", fz(o.start), fz(o.stop), fz(o.step))
        return _freeze_value(o)

    return (op_fn, fz(args), fz(kwargs))


def _eval_op_aval(op_fn: Callable, args: tuple, kwargs: dict, env: dict) -> Any:
    """Abstractly evaluate one registry op: Refs become leaves fed to
    ``jax.eval_shape``; concrete values (constants, static paths) close
    over the body exactly as at runtime."""
    try:
        key = _eval_cache_key(op_fn, args, kwargs, env)
        hash(key)
    except Exception:
        key = None  # unhashable closure value: evaluate uncached
    if key is not None and key in _EVAL_CACHE:
        return _EVAL_CACHE[key]
    result = _eval_op_aval_uncached(op_fn, args, kwargs, env)
    if key is not None:
        if len(_EVAL_CACHE) >= _EVAL_CACHE_MAX:
            _EVAL_CACHE.clear()
        _EVAL_CACHE[key] = result
    return result


def _eval_op_aval_uncached(
    op_fn: Callable, args: tuple, kwargs: dict, env: dict
) -> Any:
    leaves: list[Any] = []

    def sub(o: Any) -> Any:
        if isinstance(o, Ref):
            v = env[o.node_id]
            if isinstance(v, _Concrete):
                return v.value
            leaves.append(v)
            return _Leaf(len(leaves) - 1)
        if isinstance(o, tuple):
            return tuple(sub(x) for x in o)
        if isinstance(o, list):
            return [sub(x) for x in o]
        if isinstance(o, dict):
            return {k: sub(v) for k, v in o.items()}
        if isinstance(o, slice):
            return slice(sub(o.start), sub(o.stop), sub(o.step))
        return o

    sargs = sub(args)
    skwargs = sub(kwargs)

    def fill(o: Any, vals: tuple) -> Any:
        if isinstance(o, _Leaf):
            return vals[o.i]
        if isinstance(o, tuple):
            return tuple(fill(x, vals) for x in o)
        if isinstance(o, list):
            return [fill(x, vals) for x in o]
        if isinstance(o, dict):
            return {k: fill(v, vals) for k, v in o.items()}
        if isinstance(o, slice):
            return slice(
                fill(o.start, vals), fill(o.stop, vals), fill(o.step, vals)
            )
        return o

    def runner(*vals):
        return op_fn(*fill(sargs, vals), **fill(skwargs, vals))

    return jax.eval_shape(runner, *leaves)


def _shape_str(v: Any) -> str:
    if isinstance(v, _Concrete):
        arr = np.asarray(v.value)
        return f"{arr.dtype}{list(arr.shape)}"
    try:
        return " ".join(
            f"{l.dtype}{list(l.shape)}" for l in jax.tree.leaves(v)
        ) or "?"
    except Exception:  # pragma: no cover - defensive
        return "?"


def _same_spec(a: Any, b: Any) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(
        tuple(x.shape) == tuple(y.shape) and x.dtype == y.dtype
        for x, y in zip(la, lb)
    )


def infer_shapes(
    graph: InterventionGraph,
    *,
    site_avals: dict[Any, Any] | None = None,
    decode_avals: dict[Any, Any] | None = None,
    input_avals: dict[str, Any] | None = None,
    site_order: list[tuple[str, int | None]] | None = None,
    node_steps: dict[int, int] | None = None,
) -> tuple[dict[int, Any], list[Diagnostic]]:
    """Abstract interpretation of ``graph``: node id -> aval (or None).

    ``site_avals`` seeds tap values for the single-forward execution (or
    the PREFILL of a generation trace); ``decode_avals`` (with
    ``node_steps`` from :func:`repro.core.graph.assign_steps`) seeds
    decode-step taps.  Emits ``op-shape`` ERRORs when a registry op is
    certain to fail under jit tracing, ``setter-shape`` WARNINGs when a
    setter's value cannot be proven site-shaped.  Setters that *change* a
    site's spec poison every later site's aval (set to unknown) — the
    clean-forward avals no longer describe the intervened run, and an
    unknown never produces a diagnostic.
    """
    site_avals = site_avals or {}
    decode_avals = decode_avals if decode_avals is not None else site_avals
    input_avals = input_avals or {}
    diags: list[Diagnostic] = []

    site_index = (
        {key: i for i, key in enumerate(site_order)} if site_order else {}
    )

    def tap_aval(n: Node) -> Any:
        step = node_steps.get(n.id) if node_steps else None
        pool = (
            site_avals
            if step in (None, PREFILL_STEP, PRE_STEP)
            else decode_avals
        )
        v = pool.get((n.site, n.layer))
        if v is None:
            v = pool.get(n.site)
        return v

    def tap_idx(n: Node) -> int | None:
        idx = site_index.get((n.site, n.layer))
        if idx is None and n.layer is not None:
            idx = site_index.get((n.site, None))
        return idx

    def run_pass(taint: dict[Any, int], emit: bool) -> dict[int, Any]:
        env: dict[int, Any] = {}

        def dep_avals_known(n: Node) -> bool:
            return all(
                env.get(r.node_id) is not None for r in n.refs()
            )

        def threshold(step: Any) -> int:
            big = 1 << 40
            if not taint:
                return big
            if node_steps is None or step in (None, PREFILL_STEP, PRE_STEP):
                return taint.get("prefill", big)
            return taint.get("decode", big)

        for n in graph.nodes:
            if n.op == "constant":
                env[n.id] = _Concrete(n.args[0])
            elif n.op == "input":
                env[n.id] = input_avals.get(n.args[0])
            elif n.op in ("tap_get", "grad_get"):
                aval = tap_aval(n)
                idx = tap_idx(n)
                step = node_steps.get(n.id) if node_steps else None
                if idx is not None and idx > threshold(step):
                    aval = None  # downstream of a spec-changing setter
                env[n.id] = aval
            elif n.op == "tap_set":
                v = (
                    env.get(n.args[0].node_id)
                    if n.args and isinstance(n.args[0], Ref)
                    else None
                )
                site = tap_aval(n)
                if isinstance(v, _Concrete):
                    v = _abstract_tree(v.value)
                if v is not None and site is not None and emit:
                    if not _same_spec(v, site):
                        diags.append(_diag(
                            "setter-shape", WARNING,
                            f"setter value {_shape_str(v)} does not match "
                            f"site spec {_shape_str(site)}; downstream "
                            "shape checking is disabled for later sites",
                            n,
                        ))
                env[n.id] = v if v is not None else site
            elif n.op in ("save", "log"):
                v = (
                    env.get(n.args[0].node_id)
                    if n.args and isinstance(n.args[0], Ref)
                    else None
                )
                env[n.id] = (
                    _abstract_tree(v.value) if isinstance(v, _Concrete) else v
                )
            else:
                try:
                    op_fn = resolve_op(n.op)
                except KeyError:
                    if emit:
                        diags.append(_diag(
                            "unknown-op", ERROR,
                            f"op {n.op!r} is not in the registry", n,
                        ))
                    env[n.id] = None
                    continue
                if not dep_avals_known(n):
                    env[n.id] = None
                    continue
                try:
                    env[n.id] = _eval_op_aval(op_fn, n.args, n.kwargs, env)
                except _UNDECIDABLE:
                    env[n.id] = None  # needs concrete values: undecidable
                except Exception as e:
                    if emit:
                        ins = ", ".join(
                            _shape_str(env[r.node_id]) for r in n.refs()
                        )
                        msg = str(e).split("\n")[0]
                        diags.append(_diag(
                            "op-shape", ERROR,
                            f"{n.op} on ({ins}) fails under jit tracing: "
                            f"{type(e).__name__}: {msg}",
                            n,
                        ))
                    env[n.id] = None
        return env

    # Pass A: candidate avals, no diagnostics.  Pass B: taint thresholds
    # from spec-changing setters.  Pass C: final avals + diagnostics with
    # taps past a taint threshold demoted to unknown.
    env_a = run_pass({}, emit=False)
    taint: dict[Any, int] = {}
    for n in graph.nodes:
        if n.op != "tap_set":
            continue
        v = env_a.get(n.args[0].node_id) if n.args else None
        if isinstance(v, _Concrete):
            v = _abstract_tree(v.value)
        site = tap_aval(n)
        idx = tap_idx(n)
        if idx is None:
            continue
        if v is None or site is None or not _same_spec(v, site):
            step = node_steps.get(n.id) if node_steps else None
            bucket = (
                "prefill"
                if node_steps is None or step in (PREFILL_STEP, PRE_STEP)
                else "decode"
            )
            taint[bucket] = min(taint.get(bucket, 1 << 40), idx)
    env = run_pass(taint, emit=True)
    avals = {
        nid: (_abstract_tree(v.value) if isinstance(v, _Concrete) else v)
        for nid, v in env.items()
    }
    return avals, diags


# ------------------------------------------------------------- structural
def _structural_diags(
    graph: InterventionGraph,
    site_order: list[tuple[str, int | None]] | None,
    decode_order: list[tuple[str, int | None]] | None = None,
) -> list[Diagnostic]:
    """Unknown ops / unknown sites, mirroring what runtime validation
    raises (``graph.schedule`` at admission, slice validation for decode
    steps) — but per-node, named, and without executing anything."""
    diags: list[Diagnostic] = []
    known = set(site_order or [])
    known_names = {s for s, _ in known}
    dec = set(decode_order if decode_order is not None else (site_order or []))
    dec_names = {s for s, _ in dec}
    for n in graph.nodes:
        if n.op not in _PROTOCOL_OPS:
            try:
                resolve_op(n.op)
            except KeyError:
                diags.append(_diag(
                    "unknown-op", ERROR,
                    f"op {n.op!r} is not in the registry", n,
                ))
            continue
        if n.op not in ("tap_get", "tap_set", "grad_get") or not site_order:
            continue
        key = (n.site, n.layer)
        is_decode = n.step is not None and n.step != PREFILL_STEP
        pool, names = (dec, dec_names) if is_decode else (known, known_names)
        if key not in pool and n.site not in names:
            verb = "targets" if n.op == "tap_set" else "taps"
            where = "decode schedule" if is_decode else "site schedule"
            diags.append(_diag(
                "unknown-site", ERROR,
                f"node %{n.id} {verb} unknown site {key!r} "
                f"(not in the {where})",
                n,
            ))
    return diags


# ------------------------------------------------------------- dead nodes
def dead_nodes(graph: InterventionGraph) -> tuple[int, ...]:
    """Node ids unreachable from any save, setter, log, or backward loss.

    Dead nodes execute for nothing — they cost compute inside the jitted
    program and can even force the eager path (a dead ``log``)."""
    roots = set(graph.saves.values())
    for n in graph.nodes:
        if n.op in ("tap_set", "save", "log"):
            roots.add(n.id)
    if graph.backward_loss is not None:
        roots.add(graph.backward_loss)
    live: set[int] = set()
    stack = list(roots)
    while stack:
        nid = stack.pop()
        if nid in live:
            continue
        live.add(nid)
        stack.extend(r.node_id for r in graph.node(nid).refs())
    return tuple(n.id for n in graph.nodes if n.id not in live)


def eliminate_dead(
    graph: InterventionGraph,
) -> tuple[InterventionGraph, dict[int, int]]:
    """A copy of ``graph`` with dead nodes pruned (ids renumbered dense).

    Returns ``(pruned, old_id -> new_id)``.  Saves/backward_loss are
    remapped; the pruned graph is observably equivalent (same saves, same
    setters, same logs) with strictly less work."""
    dead = set(dead_nodes(graph))
    out = InterventionGraph()
    idmap: dict[int, int] = {}
    for n in graph.nodes:
        if n.id in dead:
            continue
        new = out.add(
            n.op,
            *map_refs(n.args, lambda r: Ref(idmap[r.node_id])),
            site=n.site,
            layer=n.layer,
            step=n.step,
            invoke=n.invoke,
            meta=dict(n.meta),
            **map_refs(n.kwargs, lambda r: Ref(idmap[r.node_id])),
        )
        idmap[n.id] = new.id
    out.saves = {
        name: idmap[nid] for name, nid in graph.saves.items() if nid in idmap
    }
    if graph.backward_loss is not None and graph.backward_loss in idmap:
        out.backward_loss = idmap[graph.backward_loss]
    return out, idmap


def infer_stop_site(graph: InterventionGraph, schedule: Any) -> int | None:
    """``last_referenced_site`` as an analysis fact: index into the site
    order past which the model forward cannot affect the graph, or None
    when nothing is tapped.  ``.grad`` graphs truncate too — the
    perturbation driver differentiates the truncated forward, and every
    site the loss (and therefore the backward) can read is counted."""
    from repro.core.interleave import last_referenced_site

    idx = last_referenced_site(graph, schedule)
    return None if idx == PRE_SITE else int(idx)


# ------------------------------------------------------------ fusion lint
def scan_fusion_reason(
    graph: InterventionGraph, schedule: Any
) -> str | None:
    """Why a (merged) step graph cannot compile in scan mode, or None.

    Mirrors the rejections ``make_step_callable`` / ``Interleaver`` raise
    at trace time — consulted by the fused planner so an ineligible graph
    never pays a failed XLA trace.  ``log`` and ``grad`` graphs compile
    (``jax.debug.callback`` / the in-trace perturbation driver), and
    FORWARD cross-layer flow threads through the scan carry — only
    backward flow (a setter consuming a later layer's getter) remains
    impossible, because the value does not exist yet at the setter's
    site."""
    scan_set = set(getattr(schedule, "scan_sites", ()) or ())
    if not scan_set:
        return None
    site_index = {
        key: i for i, key in enumerate(getattr(schedule, "order", ()) or ())
    }
    by_id = {n.id: n for n in graph.nodes}
    getters = {
        n.id: n
        for n in graph.nodes
        if n.op == "tap_get" and n.site in scan_set
    }
    for s in graph.nodes:
        if s.op != "tap_set" or s.site not in scan_set:
            continue
        seen: set[int] = set()
        stack = [r.node_id for r in s.refs()]
        while stack:
            nid = stack.pop()
            if nid in seen:
                continue
            seen.add(nid)
            g = getters.get(nid)
            if g is not None and g.layer != s.layer:
                gi = site_index.get((g.site, g.layer))
                si = site_index.get((s.site, s.layer))
                if gi is None or si is None or gi > si:
                    return "scan-cross-layer"
            stack.extend(r.node_id for r in by_id[nid].refs())
    return None


def lint_fusion(
    graph: InterventionGraph,
    n_steps: int,
    schedule: Any = None,
) -> list[FusionVerdict]:
    """Classify every decode step of a generation graph as fusable/eager
    with a machine-readable reason (no compile, no trace)."""
    from repro.core.generation import _EMPTY_FP, _slice_fingerprint, slice_steps

    slices = slice_steps(graph, n_steps)
    verdicts: list[FusionVerdict] = []
    fps: list[Any] = []
    for s in range(n_steps):
        sl = slices.get(s)
        if sl is None or sl.is_empty():
            verdicts.append(FusionVerdict(s, True, "empty"))
            fps.append(_EMPTY_FP)
            continue
        if sl.exports:
            verdicts.append(FusionVerdict(
                s, False, "cross-step-flow",
                f"exports {sorted(sl.exports)} feed later steps",
            ))
            fps.append(None)
            continue
        if schedule is not None:
            reason = scan_fusion_reason(sl.graph, schedule)
            if reason == "scan-cross-layer":
                verdicts.append(FusionVerdict(
                    s, False, reason,
                    "backward cross-layer setter data flow cannot compile "
                    "in scan mode (the value does not exist yet)",
                ))
                fps.append(None)
                continue
        verdicts.append(FusionVerdict(s, True, "ok"))
        fps.append(_slice_fingerprint(sl))
    # uniformity: steps whose structure differs from step 0 cannot share
    # its compiled body — each run boundary is an eager re-merge
    base = next((fp for fp in fps if fp is not None), None)
    for s, (v, fp) in enumerate(zip(verdicts, fps)):
        if v.fusable and fp is not None and base is not None and fp != base:
            verdicts[s] = FusionVerdict(
                s, True, "non-uniform",
                "structurally distinct from step 0: fusable only within "
                "its own uniform run",
            )
    return verdicts


# ------------------------------------------------------------- merge plan
def _shared_set_sites(ga, gb) -> list:
    return sorted(
        {
            (n.site, n.layer, n.step)
            for n in ga.nodes
            if n.op == "tap_set"
        }
        & {
            (n.site, n.layer, n.step)
            for n in gb.nodes
            if n.op == "tap_set"
        }
    )


def check_merge_plan(
    graphs: list[InterventionGraph],
    sizes: list[int],
    starts: list | None = None,
    *,
    num_rows: int | None = None,
) -> list[Diagnostic]:
    """Statically verify a co-tenant merge plan (the row starts/sizes a
    merge would assign) BEFORE building the merged graph.

    A start is either an int (a contiguous span of ``size`` rows, the
    ``dynamic_slice`` rewrite) or a sequence of row indices (an arbitrary
    row set, the paged allocator's gather/scatter rewrite).

    Proves: (1) every tenant's row set is in-bounds (and, for index-array
    starts, duplicate-free and of the declared size), (2) row sets are
    pairwise disjoint — each request's setters are row-confined by
    construction (``merge_graphs`` rewrites them through row-sliced or
    row-scattered updates), so disjointness of the assigned sets IS the
    write-write safety proof; (3) reports (as notes) cross-tenant
    getter/setter pairs on the same ``(site, layer, step)`` — safe
    because merged getters read the PRISTINE shared value (getters fire
    before setters at a site), but worth surfacing in a lint.
    """
    diags: list[Diagnostic] = []
    if starts is None:
        acc = 0
        starts = []
        for b in sizes:
            starts.append(acc)
            acc += b
    if len(starts) != len(graphs) or len(sizes) != len(graphs):
        diags.append(Diagnostic(
            "merge-plan", ERROR,
            f"plan arity mismatch: {len(graphs)} graphs, "
            f"{len(sizes)} sizes, {len(starts)} starts",
        ))
        return diags
    indexed = any(not isinstance(s, (int, np.integer)) for s in starts)
    if indexed:
        # index-array path: each tenant holds an explicit row SET
        row_sets: list[set[int]] = []
        for i, (s, b) in enumerate(zip(starts, sizes)):
            if isinstance(s, (int, np.integer)):
                rows = list(range(int(s), int(s) + b))
            else:
                rows = [int(r) for r in np.asarray(s).reshape(-1)]
                if len(rows) != b:
                    diags.append(Diagnostic(
                        "merge-plan", ERROR,
                        f"tenant {i} declares {b} rows but its index "
                        f"array names {len(rows)}",
                    ))
                if len(set(rows)) != len(rows):
                    diags.append(Diagnostic(
                        "row-bounds", ERROR,
                        f"tenant {i} row set {sorted(rows)} contains "
                        "duplicates",
                    ))
            if b < 1:
                diags.append(Diagnostic(
                    "row-bounds", ERROR,
                    f"tenant {i} has {b} rows (must be >= 1)",
                ))
            bad = [
                r for r in rows
                if r < 0 or (num_rows is not None and r >= num_rows)
            ]
            if bad:
                diags.append(Diagnostic(
                    "row-bounds", ERROR,
                    f"tenant {i} rows {sorted(bad)} escape the table "
                    f"(0..{num_rows})",
                ))
            row_sets.append(set(rows))
        for a in range(len(row_sets)):
            for b in range(a + 1, len(row_sets)):
                shared = row_sets[a] & row_sets[b]
                if shared:
                    sites = _shared_set_sites(graphs[a], graphs[b])
                    extra = f"; both write {sites}" if sites else ""
                    diags.append(Diagnostic(
                        "row-overlap", ERROR,
                        f"tenants {a} and {b} overlap: share rows "
                        f"{sorted(shared)}{extra}",
                    ))
    else:
        spans = list(zip(starts, sizes))
        for i, (lo, b) in enumerate(spans):
            if b < 1:
                diags.append(Diagnostic(
                    "row-bounds", ERROR,
                    f"tenant {i} has {b} rows (must be >= 1)",
                ))
            if lo < 0 or (num_rows is not None and lo + b > num_rows):
                diags.append(Diagnostic(
                    "row-bounds", ERROR,
                    f"tenant {i} rows [{lo}, {lo + b}) escape the table "
                    f"(0..{num_rows})",
                ))
        order = sorted(range(len(spans)), key=lambda i: spans[i][0])
        for a, b in zip(order, order[1:]):
            lo_a, n_a = spans[a]
            lo_b, n_b = spans[b]
            if lo_a + n_a > lo_b:
                sites = _shared_set_sites(graphs[a], graphs[b])
                extra = f"; both write {sites}" if sites else ""
                diags.append(Diagnostic(
                    "row-overlap", ERROR,
                    f"tenants {a} and {b} overlap: rows "
                    f"[{lo_a}, {lo_a + n_a})"
                    f" vs [{lo_b}, {lo_b + n_b}){extra}",
                ))
    # cross-tenant read/write relationships (informational: isolation
    # holds by construction — merged getters read the pristine value)
    set_sites = [
        {(n.site, n.layer, n.step) for n in g.nodes if n.op == "tap_set"}
        for g in graphs
    ]
    get_sites = [
        {(n.site, n.layer, n.step) for n in g.nodes if n.op == "tap_get"}
        for g in graphs
    ]
    for i in range(len(graphs)):
        for j in range(len(graphs)):
            if i == j:
                continue
            shared = set_sites[i] & get_sites[j]
            if shared:
                key = sorted(shared)[0]
                diags.append(Diagnostic(
                    "cross-tenant-read", NOTE,
                    f"tenant {j} reads {key} which tenant {i} writes; "
                    "merged getters read the pristine (pre-setter) value, "
                    "so tenant isolation holds",
                ))
    return diags


# -------------------------------------------------------------- page plan
def check_page_plan(
    block_tables: Any,
    rows_list: list,
    num_pages: int,
    *,
    reserved_pages: tuple[int, ...] = (0, 1),
) -> list[Diagnostic]:
    """Statically verify a paged-cache placement: given the slot table's
    ``block_tables`` (rows x blocks of page ids, 0 = unallocated) and the
    row set each tenant owns, prove (1) every referenced page id is
    in-bounds for the pool, (2) no tenant's block table references a
    reserved page (the null/trash pages are allocator-internal), and
    (3) no two tenants share a page — page disjointness is the paged
    analogue of the row-disjointness proof: a tenant's decode writes land
    only in its own pages, so disjointness IS cache isolation.
    """
    diags: list[Diagnostic] = []
    bt = np.asarray(block_tables)
    owners: dict[int, int] = {}
    for i, rows in enumerate(rows_list):
        rows = np.asarray(rows).reshape(-1)
        bad_rows = [int(r) for r in rows if r < 0 or r >= bt.shape[0]]
        if bad_rows:
            diags.append(Diagnostic(
                "row-bounds", ERROR,
                f"tenant {i} rows {bad_rows} escape the block table "
                f"(0..{bt.shape[0]})",
            ))
            continue
        pages = [int(p) for p in bt[rows].reshape(-1) if p != 0]
        oob = sorted({p for p in pages if p < 0 or p >= num_pages})
        if oob:
            diags.append(Diagnostic(
                "page-bounds", ERROR,
                f"tenant {i} references pages {oob} outside the pool "
                f"(0..{num_pages})",
            ))
        res = sorted({p for p in pages if p in reserved_pages})
        if res:
            diags.append(Diagnostic(
                "page-bounds", ERROR,
                f"tenant {i} references reserved pages {res} "
                "(null/trash pages are allocator-internal)",
            ))
        for p in pages:
            if p in reserved_pages or p < 0 or p >= num_pages:
                continue
            if p in owners and owners[p] != i:
                diags.append(Diagnostic(
                    "page-overlap", ERROR,
                    f"tenants {owners[p]} and {i} share page {p}",
                ))
            owners[p] = i
    return diags


# ---------------------------------------------------------------- analyze
def analyze(
    graph: InterventionGraph,
    *,
    site_order: list[tuple[str, int | None]] | None = None,
    decode_order: list[tuple[str, int | None]] | None = None,
    site_avals: dict[Any, Any] | None = None,
    decode_avals: dict[Any, Any] | None = None,
    input_avals: dict[str, Any] | None = None,
    n_steps: int | None = None,
    schedule: Any = None,
) -> AnalysisReport:
    """The full preflight pass over one intervention graph.

    Single forward: pass ``site_order`` (and ``site_avals`` when known).
    Generation: additionally pass ``n_steps`` (and ``decode_order`` /
    ``decode_avals`` — decode-step activations have different shapes).
    Everything is optional: with no model facts the pass still lints
    structure (ops, sites, dead nodes, step flow).
    """
    report = AnalysisReport()
    report.diagnostics.extend(
        _structural_diags(graph, site_order, decode_order)
    )

    node_steps: dict[int, int] | None = None
    if n_steps is not None:
        from repro.core.graph import assign_steps

        try:
            node_steps = assign_steps(graph, n_steps)
        except GraphValidationError as e:
            report.diagnostics.append(Diagnostic(
                "step-flow", ERROR, str(e),
            ))
            return report

    # Shape inference only when the structural pass is clean — unknown
    # sites have no avals, and emitting follow-on op errors for them
    # would be noise.
    if not any(d.severity == ERROR for d in report.diagnostics):
        avals, diags = infer_shapes(
            graph,
            site_avals=site_avals,
            decode_avals=decode_avals,
            input_avals=input_avals,
            site_order=site_order,
            node_steps=node_steps,
        )
        report.avals = avals
        report.diagnostics.extend(diags)

    dead = dead_nodes(graph)
    report.dead = dead
    for nid in dead:
        n = graph.node(nid)
        if n.op in ("tap_get", "constant", "input"):
            continue  # a bare tap/constant costs nothing worth flagging
        report.diagnostics.append(_diag(
            "dead-node", NOTE,
            f"{n.op} node %{nid} is unreachable from every save/"
            "setter/log; it executes for nothing",
            n,
        ))

    if schedule is not None:
        report.stop_site = infer_stop_site(graph, schedule)
        if n_steps is not None:
            try:
                report.fusion = lint_fusion(graph, n_steps, schedule)
            except GraphValidationError:
                pass  # step-flow errors already reported above
    return report
