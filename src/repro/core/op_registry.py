"""Registry of pure ops an intervention graph may contain.

The paper wraps "all 217 fundamental PyTorch tensor operations"; the JAX
analogue is this extensible table of pure jnp/lax functions.  Keeping ops in a
closed, named registry is what makes graphs (a) serializable, (b) safe to run
co-tenant (no arbitrary code execution, unlike Garçon — see paper §5), and
(c) jittable, since every entry is a pure JAX function.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["OPS", "register_op", "resolve_op", "update_path", "apply_path"]

OPS: dict[str, Callable[..., Any]] = {}


def register_op(name: str, fn: Callable[..., Any] | None = None):
    """Register ``fn`` under ``name``. Usable as a decorator."""

    def _inner(f: Callable[..., Any]) -> Callable[..., Any]:
        if name in OPS:
            raise ValueError(f"op {name!r} already registered")
        OPS[name] = f
        return f

    if fn is not None:
        return _inner(fn)
    return _inner


def resolve_op(name: str) -> Callable[..., Any]:
    try:
        return OPS[name]
    except KeyError:
        raise KeyError(
            f"unknown intervention op {name!r}; register it with register_op"
        ) from None


# --------------------------------------------------------------------- paths
def apply_path(value: Any, path: tuple) -> Any:
    """Follow a chain of getitem keys into a (possibly pytree) value."""
    for key in path:
        value = value[key]
    return value


def update_path(value: Any, path: tuple, new: Any) -> Any:
    """Functionally write ``new`` at ``path`` inside ``value``.

    Arrays use ``.at[key].set``; tuples/lists are rebuilt.  This implements
    the NNsight idiom ``layer.output[0][1, tok, :] = x`` without mutation.
    """
    if not path:
        return new
    key, rest = path[0], path[1:]
    if isinstance(value, (tuple, list)):
        if isinstance(key, int):
            items = list(value)
            items[key] = update_path(items[key], rest, new)
            return type(value)(items)
        raise TypeError(f"cannot index {type(value).__name__} with {key!r}")
    # Array leaf: remaining path keys collapse into one .at index.
    if rest:
        inner = update_path(value[key], rest, new)
        return value.at[key].set(inner)
    return value.at[key].set(new)


# ----------------------------------------------------------------- operators
register_op("getitem", lambda x, k: x[k])
register_op("update_path", update_path)
register_op("apply_path", apply_path)

register_op("add", lambda a, b: a + b)
register_op("sub", lambda a, b: a - b)
register_op("rsub", lambda a, b: b - a)
register_op("mul", lambda a, b: a * b)
register_op("truediv", lambda a, b: a / b)
register_op("rtruediv", lambda a, b: b / a)
register_op("floordiv", lambda a, b: a // b)
register_op("mod", lambda a, b: a % b)
register_op("pow", lambda a, b: a**b)
register_op("matmul", lambda a, b: a @ b)
register_op("rmatmul", lambda a, b: b @ a)
register_op("neg", lambda a: -a)
register_op("abs", lambda a: jnp.abs(a))
register_op("eq", lambda a, b: a == b)
register_op("ne", lambda a, b: a != b)
register_op("lt", lambda a, b: a < b)
register_op("le", lambda a, b: a <= b)
register_op("gt", lambda a, b: a > b)
register_op("ge", lambda a, b: a >= b)
register_op("and", lambda a, b: a & b)
register_op("or", lambda a, b: a | b)
register_op("invert", lambda a: ~a)

# ------------------------------------------------------------- jnp functions
_JNP_FUNCS = [
    "sum", "mean", "max", "min", "argmax", "argmin", "prod", "var", "std",
    "exp", "log", "log2", "sqrt", "tanh", "sin", "cos", "sign",
    "reshape", "transpose", "squeeze", "expand_dims", "ravel",
    "concatenate", "stack", "split", "where", "clip", "take",
    "zeros_like", "ones_like", "full_like", "broadcast_to",
    "cumsum", "sort", "argsort", "flip", "roll", "tile", "repeat",
    "maximum", "minimum", "dot", "einsum", "tensordot", "outer",
    "isnan", "isinf", "allclose", "array_equal", "diag", "tril", "triu",
    "linalg.norm",
]
for _name in _JNP_FUNCS:
    _obj = jnp
    for part in _name.split("."):
        _obj = getattr(_obj, part)
    register_op(f"jnp.{_name}", _obj)

register_op("astype", lambda x, dtype: x.astype(dtype))
register_op("topk", lambda x, k: jax.lax.top_k(x, k))
register_op("softmax", jax.nn.softmax)
register_op("log_softmax", jax.nn.log_softmax)
register_op("relu", jax.nn.relu)
register_op("gelu", jax.nn.gelu)
register_op("silu", jax.nn.silu)
register_op("sigmoid", jax.nn.sigmoid)
register_op("one_hot", jax.nn.one_hot)
register_op("stop_gradient", jax.lax.stop_gradient)
register_op(
    "dynamic_slice_in_dim",
    lambda x, start, size, axis=0: jax.lax.dynamic_slice_in_dim(
        x, start, size, axis
    ),
)
register_op(
    "dynamic_update_slice_in_dim",
    lambda x, upd, start, axis=0: jax.lax.dynamic_update_slice_in_dim(
        x, upd, start, axis
    ),
)


def _batch_update_slice(x, upd, start):
    """Write ``upd`` into ``x`` at batch-row ``start``, position 0 on every
    other axis.  Rank-polymorphic so the batch merger can confine a ragged
    request's setter to its real rows AND real positions without knowing the
    tap value's rank."""
    upd = jnp.asarray(upd, dtype=jnp.result_type(x))
    return jax.lax.dynamic_update_slice(
        x, upd, (start,) + (0,) * (upd.ndim - 1)
    )


register_op("batch_update_slice", _batch_update_slice)


# ------------------------------------------------- non-contiguous row plans
# Paged slot allocation can place one tenant on ANY free rows, not a
# contiguous run; the batch merger then rewrites getters/setters through
# these gather/scatter ops instead of dynamic_slice (which only expresses
# contiguous windows).  ``rows`` arrives as a static tuple of ints so the
# placement is part of the graph's structural key, exactly like an int
# ``start`` is for the contiguous rewrites.
def _take_rows(x, rows):
    return jnp.take(x, jnp.asarray(rows, dtype=jnp.int32), axis=0)


def _scatter_rows(x, upd, rows):
    upd = jnp.asarray(upd, dtype=jnp.result_type(x))
    return x.at[jnp.asarray(rows, dtype=jnp.int32)].set(upd)


def _scatter_rows_prefix(x, upd, rows):
    """Ragged analogue of ``batch_update_slice`` for index-array rows:
    write ``upd`` into ``x`` at batch rows ``rows``, position 0 on every
    other axis, so a ragged tenant's setter touches only its real rows and
    real positions."""
    upd = jnp.asarray(upd, dtype=jnp.result_type(x))
    rows = jnp.asarray(rows, dtype=jnp.int32)
    cur = jnp.take(x, rows, axis=0)
    cur = jax.lax.dynamic_update_slice(cur, upd, (0,) * upd.ndim)
    return x.at[rows].set(cur)


register_op("take_rows", _take_rows)
register_op("scatter_rows", _scatter_rows)
register_op("scatter_rows_prefix", _scatter_rows_prefix)

# ------------------------------------------------------------------- metrics
# Server-side metrics (the Fig. 6c win: return a scalar, not hidden states).
register_op(
    "logit_diff",
    lambda logits, tok_a, tok_b: logits[..., tok_a] - logits[..., tok_b],
)
register_op(
    "nll",
    lambda logits, targets: -jnp.take_along_axis(
        jax.nn.log_softmax(logits, axis=-1), targets[..., None], axis=-1
    )[..., 0],
)
register_op(
    "mse",
    lambda a, b: jnp.mean((a - b) ** 2),
)
register_op("identity", lambda x: x)
