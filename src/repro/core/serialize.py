"""JSON wire format for intervention graphs (§3.1: "stored in JSON format,
version-controlled, optimized, and sent to or retrieved from remote systems").

The format is self-describing and versioned.  Only data ever crosses the
wire — ops are *names* resolved against the server's registry, which is what
makes co-tenancy safe (no arbitrary code execution, unlike Garçon; paper §5).

Encoding rules (chosen to be round-trip exact):
  Ref            {"__ref__": id}
  tuple          {"__tuple__": [...]}           (JSON arrays decode as lists)
  slice          {"__slice__": [start, stop, step]}
  Ellipsis       {"__ellipsis__": true}
  ndarray        {"__array__": {"dtype", "shape", "b64"}}
  np scalar      {"__scalar__": {"dtype", "value"}}
  dtype          {"__dtype__": "float32"}
  None/bool/int/float/str/list/dict   native JSON

Scheduling coordinates (``step`` for generation traces, ``invoke`` for
multi-invoke traces) are plain node fields and round-trip unchanged.

Ragged-length requests need no special encoding: per-row valid lengths
travel as ordinary ``(B,)`` int arrays under the reserved batch keys
``lengths`` / ``src_lengths`` (see repro.serving.server), and the merger's
unpadding ops (``dynamic_slice_in_dim`` / ``batch_update_slice``) are plain
registry ops, so padding-aware merged graphs round-trip unchanged.
"""
from __future__ import annotations

import base64
import json
from typing import Any

import numpy as np

from repro.core.graph import InterventionGraph, Node, Ref

__all__ = [
    "encode_value",
    "decode_value",
    "graph_to_json",
    "graph_from_json",
    "dumps",
    "loads",
]

WIRE_VERSION = 1


def encode_value(obj: Any) -> Any:
    if isinstance(obj, Ref):
        return {"__ref__": obj.node_id}
    if obj is Ellipsis:
        return {"__ellipsis__": True}
    if isinstance(obj, slice):
        return {"__slice__": [obj.start, obj.stop, obj.step]}
    if isinstance(obj, tuple):
        return {"__tuple__": [encode_value(o) for o in obj]}
    if isinstance(obj, list):
        return [encode_value(o) for o in obj]
    if isinstance(obj, dict):
        return {str(k): encode_value(v) for k, v in obj.items()}
    if isinstance(obj, np.dtype):
        return {"__dtype__": obj.name}
    if isinstance(obj, np.generic):
        return {"__scalar__": {"dtype": obj.dtype.name, "value": obj.item()}}
    if hasattr(obj, "__array__") and not isinstance(obj, (bool, int, float, str)):
        arr = np.asarray(obj)
        return {
            "__array__": {
                "dtype": arr.dtype.name,
                "shape": list(arr.shape),
                "b64": base64.b64encode(np.ascontiguousarray(arr).tobytes()).decode(
                    "ascii"
                ),
            }
        }
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"cannot serialize {type(obj).__name__} into a graph")


def decode_value(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__ref__" in obj:
            return Ref(obj["__ref__"])
        if "__ellipsis__" in obj:
            return Ellipsis
        if "__slice__" in obj:
            s = obj["__slice__"]
            return slice(s[0], s[1], s[2])
        if "__tuple__" in obj:
            return tuple(decode_value(o) for o in obj["__tuple__"])
        if "__dtype__" in obj:
            return np.dtype(obj["__dtype__"])
        if "__scalar__" in obj:
            d = obj["__scalar__"]
            return np.dtype(d["dtype"]).type(d["value"])
        if "__array__" in obj:
            d = obj["__array__"]
            data = base64.b64decode(d["b64"])
            return np.frombuffer(data, dtype=np.dtype(d["dtype"])).reshape(
                d["shape"]
            ).copy()
        return {k: decode_value(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [decode_value(o) for o in obj]
    return obj


def graph_to_json(graph: InterventionGraph) -> dict:
    return {
        "version": WIRE_VERSION,
        "nodes": [
            {
                "id": n.id,
                "op": n.op,
                "args": encode_value(n.args),
                "kwargs": encode_value(n.kwargs),
                "site": n.site,
                "layer": n.layer,
                "step": n.step,
                "invoke": n.invoke,
                "meta": encode_value(n.meta),
            }
            for n in graph.nodes
        ],
        "saves": dict(graph.saves),
        "backward_loss": graph.backward_loss,
    }


def graph_from_json(payload: dict) -> InterventionGraph:
    if payload.get("version") != WIRE_VERSION:
        raise ValueError(
            f"unsupported wire version {payload.get('version')!r} "
            f"(expected {WIRE_VERSION})"
        )
    graph = InterventionGraph()
    for spec in payload["nodes"]:
        node = Node(
            id=spec["id"],
            op=spec["op"],
            args=tuple(decode_value(spec["args"])),
            kwargs=decode_value(spec["kwargs"]),
            site=spec.get("site"),
            layer=spec.get("layer"),
            step=spec.get("step"),
            invoke=spec.get("invoke"),
            meta=decode_value(spec.get("meta", {})),
        )
        if node.id != len(graph.nodes):
            raise ValueError("node ids must be dense and ordered")
        for ref in node.refs():
            if not 0 <= ref.node_id < node.id:
                raise ValueError(
                    f"node %{node.id} references %{ref.node_id} (forward or "
                    "dangling reference — graph is not topologically ordered)"
                )
        graph.nodes.append(node)
    graph.saves = {str(k): int(v) for k, v in payload["saves"].items()}
    graph.backward_loss = payload.get("backward_loss")
    return graph


def structural_key(graph: InterventionGraph) -> bytes:
    """Graph identity with constant VALUES abstracted to (shape, dtype).

    The serving engine keys its compile cache on this: two activation-patch
    requests differing only in the patched values share one XLA executable.
    """
    from repro.core.graph import SOURCE_META_KEY

    payload = graph_to_json(graph)
    for spec, node in zip(payload["nodes"], graph.nodes):
        # source provenance is not structure: two users running the same
        # experiment from different files share one executable
        if SOURCE_META_KEY in node.meta:
            spec["meta"] = encode_value({
                k: v for k, v in node.meta.items() if k != SOURCE_META_KEY
            })
        if node.op == "constant":
            val = node.args[0]
            arr = np.asarray(val)
            spec["args"] = {
                "__const_spec__": [arr.dtype.name, list(arr.shape)]
            }
    return json.dumps(payload, separators=(",", ":"), sort_keys=True).encode()


def dumps(graph: InterventionGraph) -> bytes:
    return json.dumps(graph_to_json(graph), separators=(",", ":")).encode()


def loads(data: bytes) -> InterventionGraph:
    return graph_from_json(json.loads(data.decode()))
