"""Parallel co-tenancy: merge many users' intervention graphs into ONE
forward pass (paper Appendix B.2 — listed there as future work; implemented
here as a beyond-paper feature and benchmarked in fig9 / cotenancy_ragged).

Each request owns a contiguous group of batch rows.  The merger rewrites
every getter into a batch-slice of the shared tap value and every setter into
a ``dynamic_update_slice`` confined to the request's rows, so experiments are
*structurally* isolated: a user's graph cannot read or write another user's
rows, and the model weights are untouched (pure function).  This is the
"extracts appropriate slices while preserving gradient propagation" design
the paper sketches, realized with JAX functional updates.

Multi-invoke traces reuse this machinery from the *client* side: the tracer
stamps each prompt's nodes with ``Node.invoke``, :func:`split_invokes`
partitions the shared graph back into per-invoke graphs, and the same
``merge_graphs`` lowers them into ONE merged forward — several prompts from
one user are structurally identical to several co-tenant users
(:mod:`repro.core.tracer`).  :func:`merge_invoke_batches` is the batch-side
counterpart (right-padding + synthesized length arrays), shared with the
scheduler's burst grouper.

Ragged lengths (pad-and-mask merging)
-------------------------------------
Requests do NOT need equal sequence lengths: the scheduler right-pads each
model input to the group maximum and passes a per-request ``lengths`` record
here.  For every tap site with a sequence axis (``site_length_key`` maps the
site to the input whose axis-1 length it follows), a shorter request's
getter is additionally sliced to its TRUE length — user ops downstream see
exactly the shapes a solo run would produce (so positional indexing like
``x[:, -1]`` grabs the real last token, never padding) — and its setter is
written back with ``batch_update_slice``, confined to its real rows AND real
positions.  Padded positions carry sentinel position ids which the model
side (``repro.models.common._mask_bias``, dt-masked SSD scans) proves inert,
so every unpadded save is identical to solo execution.

Limitations (documented, enforced):
  * requests must share dtypes and every non-batch dim EXCEPT the sequence
    axis of declared ragged inputs (the scheduler buckets lengths with a
    configurable ``pad_slack`` bounding wasted padding compute);
  * sites with no sequence axis (e.g. ``layers.ssm_state``) merge on batch
    rows only — their values are per-row, never per-position;
  * requests using ``.grad`` are executed solo (cross-user losses would have
    to be summed, entangling perturbation bookkeeping) — the scheduler falls
    back to sequential co-tenancy for those, exactly the paper's baseline;
  * ``all_steps()`` setters run solo (a merged setter is a read-modify-write
    and broadcast getters are invalid — expand to concrete steps instead).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.graph import (
    ALL_STEPS,
    PREFILL_STEP,
    GraphValidationError,
    InterventionGraph,
    Node,
    Ref,
    map_refs,
)

__all__ = [
    "MergedBatch",
    "CrossInvokeError",
    "merge_graphs",
    "split_results",
    "split_invokes",
    "merge_invoke_batches",
    "RAGGED_INPUTS",
]

BATCH_AXIS = 0
SEQ_AXIS = 1

# Model inputs whose axis 1 may differ across merged requests, and the
# batch key carrying per-row valid lengths for each.  Other 2D+ inputs
# (e.g. fixed-size image embeddings) still require an exact match.  Shared
# by the burst scheduler and the continuous-batching admission path.
RAGGED_INPUTS = {"tokens": "lengths", "src_embeds": "src_lengths"}


@dataclasses.dataclass
class MergedBatch:
    graph: InterventionGraph
    row_slices: list[tuple[int, int]]  # (start, size) per request
    save_prefixes: list[str]
    # per-request tap-site lengths (input key -> true length), None = uniform
    lengths: list[dict[str, int]] | None = None
    # per-request [start, end) ranges of merged-graph node ids — log entries
    # (node_id, value) are attributed back to their owning request with this
    node_ranges: list[tuple[int, int]] | None = None

    def owner_of(self, node_id: int) -> int | None:
        """Index of the request whose segment produced ``node_id``."""
        for r, (lo, hi) in enumerate(self.node_ranges or ()):
            if lo <= node_id < hi:
                return r
        return None


def merge_graphs(
    graphs: list[InterventionGraph],
    batch_sizes: list[int],
    *,
    lengths: list[dict[str, int]] | None = None,
    site_length_key: Callable[[str], str | None] | None = None,
    starts: list[int] | None = None,
    normalize_steps: bool = False,
    length_pad_to: dict[str, int] | None = None,
) -> MergedBatch:
    """Merge per-request graphs into one batched graph.

    ``lengths`` (optional) holds one dict per request mapping a ragged input
    key (e.g. ``"tokens"``) to that request's TRUE axis-1 length at tap
    sites; the model inputs are assumed right-padded to the group max.
    ``site_length_key(site)`` maps a tap-site name to the input key its
    value's axis 1 follows (``None`` = no sequence axis); defaults to
    ``"tokens"`` for every site.

    ``starts`` (optional) pins each request to an EXPLICIT batch-row offset
    instead of packing requests contiguously from row 0.  This is the
    slot-table form used by continuous batching: a request admitted into a
    running decode loop keeps its slot rows for its whole lifetime, so its
    getters/setters are rewritten against those rows while other slots (free,
    or owned by co-tenant requests at other decode steps) stay untouched.

    ``length_pad_to`` overrides the padded width the inputs were actually
    padded to (per ragged key) when it EXCEEDS the group's own maximum —
    continuous batching pads every admission to its length-bucket ceiling so
    repeated admissions share one compiled prefill, which means even the
    longest request of a group may be padded and need length slicing.

    ``normalize_steps=True`` strips the generation-step coordinate from tap
    nodes.  Per-execution slice graphs (:func:`repro.core.generation
    .slice_steps`) already encode WHICH execution they run in, but co-tenant
    requests inside one slot-table decode step sit at *different* local step
    indices; normalizing lets their taps share one getter and one
    read-modify-write setter chain per (site, layer).  ``ALL_STEPS`` setters
    are allowed in this form — the slicer has already replicated them into
    concrete executions, so the merged setter is an ordinary row-confined
    write.
    """
    if len(graphs) != len(batch_sizes):
        raise ValueError("one batch size per graph required")
    if lengths is not None and len(lengths) != len(graphs):
        raise ValueError("one lengths record per graph required")
    if starts is not None and len(starts) != len(graphs):
        raise ValueError("one row start per graph required")
    for g in graphs:
        if (any(n.op == "grad_get" for n in g.nodes)
                and g.backward_loss is None):
            # Each grad graph must bring its own loss: the merged loss is
            # the SUM of per-request losses, and a request without one
            # would silently differentiate a co-tenant's objective.
            raise ValueError(
                "graph uses .grad but declares no backward loss; "
                "cannot batch-merge"
            )
        for n in g.nodes:
            if (n.op == "tap_set" and n.step == ALL_STEPS
                    and not normalize_steps):
                # A merged setter is a read-modify-write, and ALL_STEPS
                # getters are invalid — expand to concrete steps client-side
                # or run solo.
                raise ValueError(
                    "graphs using all_steps() setters cannot be "
                    "batch-merged; schedule them sequentially"
                )

    if starts is not None:
        # Explicit row placement (the slot-table form): statically prove
        # the plan before building the merged graph — overlapping ranges
        # would silently interleave two tenants' rows.
        from repro.core.analysis import check_merge_plan

        errs = [
            d for d in check_merge_plan(graphs, batch_sizes, list(starts))
            if d.severity == "error"
        ]
        if errs:
            raise GraphValidationError(
                "merge plan rejected: "
                + "; ".join(d.format() for d in errs)
            )

    length_key = site_length_key or (lambda site: "tokens")
    group_max: dict[str, int] = {}
    if lengths is not None:
        for rec in lengths:
            for k, v in rec.items():
                group_max[k] = max(group_max.get(k, 0), int(v))
        for k, v in (length_pad_to or {}).items():
            group_max[k] = max(group_max.get(k, 0), int(v))

    def true_length(r: int, n: Node) -> int | None:
        """The request's tap-value length at this node, when it is SHORTER
        than the group max (i.e. the value is padded and needs slicing).

        Decode-step taps (step >= 0) are per-token — their axis 1 is the
        singleton decode axis, identical for every request — so only
        single-forward (step None) and prefill taps are length-sliced.
        """
        if lengths is None or n.site is None:
            return None
        if n.step is not None and n.step != PREFILL_STEP:
            return None
        key = length_key(n.site)
        if key is None or key not in lengths[r]:
            return None
        L = int(lengths[r][key])
        return L if L < group_max.get(key, L) else None

    merged = InterventionGraph()
    # Per (site, layer, step): the pristine shared getter and the current
    # (post-previous-setters) value node.  Step is part of the key so merged
    # generation requests tapping one site at different decode steps never
    # alias (None for single-forward graphs).
    shared_get: dict[tuple[str | None, int | None, int | None], Node] = {}
    current: dict[tuple[str | None, int | None, int | None], Node] = {}
    # Per (site, layer, step): the shared gradient read.  The merged loss
    # sums per-request losses, and each loss is confined to its own rows,
    # so slicing a tenant's rows out of the batched gradient recovers its
    # solo gradient exactly.
    shared_grad: dict[tuple[str | None, int | None, int | None], Node] = {}

    if starts is None:
        starts = []
        acc = 0
        for b in batch_sizes:
            starts.append(acc)
            acc += b

    row_slices = []
    prefixes = []
    node_ranges = []
    for r, (g, start, size) in enumerate(zip(graphs, starts, batch_sizes)):
        row_slices.append((start, size))
        prefix = f"r{r}"
        prefixes.append(prefix)
        range_start = len(merged.nodes)
        idmap: dict[int, int] = {}

        def remap(obj):
            return map_refs(obj, lambda ref: Ref(idmap[ref.node_id]))

        for n in g.nodes:
            n_step = None if normalize_steps else n.step
            key = (n.site, n.layer, n_step)
            indexed = not isinstance(start, (int, np.integer))
            if indexed:
                rows = tuple(int(x) for x in np.asarray(start).reshape(-1))
            if n.op == "tap_get":
                if key not in shared_get:
                    node = merged.add(
                        "tap_get", site=n.site, layer=n.layer, step=n_step
                    )
                    shared_get[key] = node
                    current.setdefault(key, node)
                if indexed:
                    # non-contiguous placement: gather the tenant's rows
                    sl = merged.add(
                        "take_rows", Ref(shared_get[key].id), rows
                    )
                else:
                    sl = merged.add(
                        "dynamic_slice_in_dim",
                        Ref(shared_get[key].id),
                        start,
                        size,
                        axis=BATCH_AXIS,
                    )
                L = true_length(r, n)
                if L is not None:
                    # unpad: the request's ops see its solo shapes
                    sl = merged.add(
                        "dynamic_slice_in_dim", Ref(sl.id), 0, L, axis=SEQ_AXIS
                    )
                idmap[n.id] = sl.id
            elif n.op == "tap_set":
                if key not in current:
                    node = merged.add(
                        "tap_get", site=n.site, layer=n.layer, step=n_step
                    )
                    shared_get.setdefault(key, node)
                    current[key] = node
                val_ref = remap(n.args[0])
                if indexed:
                    # non-contiguous placement: scatter back to the
                    # tenant's rows (prefix-confined when ragged)
                    op = ("scatter_rows_prefix"
                          if true_length(r, n) is not None
                          else "scatter_rows")
                    upd = merged.add(
                        op, Ref(current[key].id), val_ref, rows
                    )
                elif true_length(r, n) is not None:
                    # ragged write: confined to real rows AND real positions
                    # (the update value is solo-shaped, start = (row, 0, ...))
                    upd = merged.add(
                        "batch_update_slice",
                        Ref(current[key].id),
                        val_ref,
                        start,
                    )
                else:
                    upd = merged.add(
                        "dynamic_update_slice_in_dim",
                        Ref(current[key].id),
                        val_ref,
                        start,
                        axis=BATCH_AXIS,
                    )
                merged.add(
                    "tap_set", Ref(upd.id),
                    site=n.site, layer=n.layer, step=n_step,
                )
                current[key] = upd
                idmap[n.id] = upd.id
            elif n.op == "grad_get":
                if key not in shared_grad:
                    shared_grad[key] = merged.add(
                        "grad_get", site=n.site, layer=n.layer, step=n_step
                    )
                if indexed:
                    sl = merged.add(
                        "take_rows", Ref(shared_grad[key].id), rows
                    )
                else:
                    sl = merged.add(
                        "dynamic_slice_in_dim",
                        Ref(shared_grad[key].id),
                        start,
                        size,
                        axis=BATCH_AXIS,
                    )
                L = true_length(r, n)
                if L is not None:
                    sl = merged.add(
                        "dynamic_slice_in_dim", Ref(sl.id), 0, L, axis=SEQ_AXIS
                    )
                idmap[n.id] = sl.id
            elif n.op == "input":
                node = merged.add("input", f"{prefix}/{n.args[0]}")
                idmap[n.id] = node.id
            else:
                node = merged.add(
                    n.op,
                    *remap(n.args),
                    site=n.site,
                    layer=n.layer,
                    step=n.step,
                    meta=dict(n.meta),
                    **remap(n.kwargs),
                )
                idmap[n.id] = node.id

        for name, nid in g.saves.items():
            merged.saves[f"{prefix}/{name}"] = idmap[nid]
        if g.backward_loss is not None:
            loss_id = idmap[g.backward_loss]
            if merged.backward_loss is None:
                merged.backward_loss = loss_id
            else:
                total = merged.add(
                    "add", Ref(merged.backward_loss), Ref(loss_id)
                )
                merged.backward_loss = total.id
        node_ranges.append((range_start, len(merged.nodes)))

    return MergedBatch(
        graph=merged,
        row_slices=row_slices,
        save_prefixes=prefixes,
        lengths=lengths,
        node_ranges=node_ranges,
    )


def split_results(
    merged_saves: dict[str, object], batch: MergedBatch
) -> list[dict[str, object]]:
    out: list[dict[str, object]] = [dict() for _ in batch.save_prefixes]
    for name, value in merged_saves.items():
        prefix, _, rest = name.partition("/")
        idx = batch.save_prefixes.index(prefix)
        out[idx][rest] = value
    return out


# --------------------------------------------------------------------------
# Multi-invoke traces: one invoke-stamped graph -> per-invoke graphs.
# --------------------------------------------------------------------------

class CrossInvokeError(ValueError):
    """Cross-invoke value flow, with structured diagnostics attached.

    Stays a ``ValueError`` whose message contains "cross-invoke" (the
    contract callers and tests match on); ``diagnostics`` carries the
    machine-readable form (:class:`repro.core.analysis.Diagnostic`)."""

    def __init__(self, message: str, diagnostics: list) -> None:
        super().__init__(message)
        self.diagnostics = list(diagnostics)


def _saves_downstream(graph: InterventionGraph, nid: int) -> list[str]:
    """Save names whose value transitively consumes node ``nid``."""
    memo: dict[int, bool] = {}

    def reaches(x: int) -> bool:
        if x == nid:
            return True
        if x in memo:
            return memo[x]
        memo[x] = False
        memo[x] = any(reaches(r.node_id) for r in graph.node(x).refs())
        return memo[x]

    return sorted(n for n, sid in graph.saves.items() if reaches(sid))


def _cross_invoke_error(
    graph: InterventionGraph,
    node,
    invokes: list[int],
    producers: dict[int, int],
    lead: str,
) -> CrossInvokeError:
    """Build the rich rejection: offending node ids, both invoke indices,
    and the save names the flow would feed."""
    from repro.core.analysis import ERROR, Diagnostic, source_of

    saves = _saves_downstream(graph, node.id)
    prod = ", ".join(
        f"%{nid} (invoke {inv})" for nid, inv in sorted(producers.items())
    )
    msg = (
        f"{lead}; cross-invoke value flow is not allowed — invokes are "
        f"independent rows of one batch (consumes {prod}"
        + (f"; feeds saves {saves}" if saves else "")
        + ")"
    )
    diags = [Diagnostic(
        code="cross-invoke",
        severity=ERROR,
        message=msg,
        node=node.id,
        site=node.site,
        step=node.step,
        source=source_of(node),
    )]
    for nid, inv in sorted(producers.items()):
        p = graph.node(nid)
        diags.append(Diagnostic(
            code="cross-invoke",
            severity=ERROR,
            message=f"%{nid} ({p.op}) produced in invoke {inv}, consumed "
                    f"by %{node.id} in invoke set {invokes}",
            node=nid,
            site=p.site,
            step=p.step,
            source=source_of(p),
        ))
    return CrossInvokeError(msg, diags)


def split_invokes(graph: InterventionGraph, n_invokes: int
                  ) -> list[InterventionGraph]:
    """Partition an invoke-stamped graph into one graph per invoke.

    The tracer stamps every node built inside ``tr.invoke(k)`` with
    ``Node.invoke == k``; nodes built outside any invoke (shared constants,
    cross-trace inputs used by exactly one invoke, and pure functions of
    those) carry ``invoke is None`` and are *replicated* into each invoke
    that references them.  Tap nodes must carry an invoke; value flow
    between two different invokes is rejected — invokes are independent
    row-groups of one batched execution, exactly like co-tenant requests
    (:func:`merge_graphs` then lowers the per-invoke graphs).

    Save names qualified as ``i{k}/name`` (the tracer's collision guard for
    the shared save table) are dequalified back to ``name`` in invoke ``k``'s
    graph.  Saves of invoke-free nodes (pure constants) land on invoke 0.
    """
    if n_invokes < 1:
        raise ValueError("n_invokes must be >= 1")
    # Effective invoke per node: own stamp, else inherited from deps.
    eff: dict[int, int | None] = {}
    for n in graph.nodes:
        dep_invs = {eff[r.node_id] for r in n.refs()} - {None}
        if len(dep_invs) > 1:
            raise _cross_invoke_error(
                graph, n, sorted(dep_invs),
                {r.node_id: eff[r.node_id] for r in n.refs()
                 if eff[r.node_id] is not None},
                f"node %{n.id} ({n.op}) mixes values from invokes "
                f"{sorted(dep_invs)}",
            )
        dep_inv = next(iter(dep_invs)) if dep_invs else None
        if n.op in ("tap_get", "tap_set", "grad_get") and n.invoke is None:
            raise ValueError(
                f"node %{n.id} taps ({n.site!r}, layer={n.layer}) outside "
                "any invoke; taps in a multi-invoke trace must be made "
                "inside a `with tr.invoke(...)` context"
            )
        if n.invoke is not None:
            if dep_inv is not None and dep_inv != n.invoke:
                raise _cross_invoke_error(
                    graph, n, sorted({n.invoke, dep_inv}),
                    {r.node_id: eff[r.node_id] for r in n.refs()
                     if eff[r.node_id] not in (None, n.invoke)},
                    f"node %{n.id} in invoke {n.invoke} consumes a value "
                    f"from invoke {dep_inv}",
                )
            if not 0 <= n.invoke < n_invokes:
                raise ValueError(
                    f"node %{n.id} targets invoke {n.invoke}, outside "
                    f"[0, {n_invokes})"
                )
            eff[n.id] = n.invoke
        else:
            eff[n.id] = dep_inv

    # Which invoke-free nodes each invoke needs (transitive deps).
    shared_needed: dict[int, set[int]] = {k: set() for k in range(n_invokes)}

    def pull_shared(k: int, nid: int) -> None:
        if eff[nid] is not None or nid in shared_needed[k]:
            return
        shared_needed[k].add(nid)
        for r in graph.node(nid).refs():
            pull_shared(k, r.node_id)

    for n in graph.nodes:
        if eff[n.id] is None:
            continue
        for r in n.refs():
            pull_shared(eff[n.id], r.node_id)
    # Invoke-free SAVES (pure constants the user saved) execute on invoke 0.
    for name, nid in graph.saves.items():
        if eff[nid] is None:
            pull_shared(0, nid)
            for r in graph.node(nid).refs():
                pull_shared(0, r.node_id)

    subs: list[InterventionGraph] = []
    for k in range(n_invokes):
        sub = InterventionGraph()
        idmap: dict[int, int] = {}
        for n in graph.nodes:  # id order == topological order
            if eff[n.id] != k and n.id not in shared_needed[k]:
                continue
            new = sub.add(
                n.op,
                *map_refs(n.args, lambda ref: Ref(idmap[ref.node_id])),
                site=n.site,
                layer=n.layer,
                step=n.step,
                meta=dict(n.meta),
                **map_refs(n.kwargs, lambda ref: Ref(idmap[ref.node_id])),
            )
            idmap[n.id] = new.id
        qual = f"i{k}/"
        for name, nid in graph.saves.items():
            owner = eff[nid] if eff[nid] is not None else 0
            if owner == k and nid in idmap:
                plain = name[len(qual):] if name.startswith(qual) else name
                if plain in sub.saves:
                    # an invoke-free save (plain name) and an invoke save
                    # (``i{k}/name``) dequalify to one key — refusing beats
                    # silently dropping one of the results
                    raise ValueError(
                        f"save name {plain!r} is ambiguous in invoke {k}: "
                        "an invoke-free save collides with an invoke save "
                        "of the same name; use distinct names"
                    )
                sub.saves[plain] = idmap[nid]
        sub.backward_loss = (
            idmap.get(graph.backward_loss)
            if graph.backward_loss is not None else None
        )
        subs.append(sub)
    return subs


def merge_invoke_batches(
    batches: list[dict], *, generation: bool = False
) -> tuple[dict, list[dict[str, int]] | None, list[int], int, int]:
    """Right-pad per-invoke model inputs to the group max and stack rows.

    The batch-side counterpart of :func:`merge_graphs`, shared by the
    multi-invoke tracer and the scheduler's burst grouper.  Declared ragged
    inputs (:data:`RAGGED_INPUTS`) may differ along axis 1; shorter entries
    are right-padded and per-row valid-length arrays (``lengths`` /
    ``src_lengths``) are synthesized unless already present.  Every other
    key must be shape-uniform.

    Returns ``(batch, tap_lengths, sizes, real_cells, padded_cells)``:
    ``tap_lengths`` is the per-invoke true-length record driving save
    unpadding in :func:`merge_graphs` (``None`` when nothing was padded —
    the merged batch is then bit-identical to plain concatenation), and the
    cell counts feed padding-waste stats.  ``generation=True`` records
    prompt tap lengths as ``L - 1``: generation prefill taps see the prompt
    minus the step-0 token.
    """
    if not batches:
        raise ValueError("at least one invoke batch required")
    keys = set(batches[0])
    for b in batches[1:]:
        if set(b) != keys:
            raise ValueError(
                f"invoke batches carry different input keys: "
                f"{sorted(keys)} vs {sorted(b)}"
            )
    sizes = [int(np.asarray(next(iter(b.values()))).shape[0])
             for b in batches]
    ragged_keys = [
        k for k in batches[0]
        if k in RAGGED_INPUTS and np.asarray(batches[0][k]).ndim >= 2
    ]
    maxes = {
        k: max(int(np.asarray(b[k]).shape[1]) for b in batches)
        for k in ragged_keys
    }
    ragged = any(
        int(np.asarray(b[k]).shape[1]) != maxes[k]
        for b in batches for k in ragged_keys
    )
    batch: dict = {}
    for k in batches[0]:
        arrs = [np.asarray(b[k]) for b in batches]
        if any(a.shape[0] != s for a, s in zip(arrs, sizes)):
            raise ValueError(f"input {k!r} disagrees on batch rows")
        if k in maxes:
            arrs = [
                np.pad(a, ((0, 0), (0, maxes[k] - a.shape[1]))
                       + ((0, 0),) * (a.ndim - 2))
                for a in arrs
            ]
        batch[k] = np.concatenate(arrs)
    real = padded = 0
    for b, rows in zip(batches, sizes):
        for k in ragged_keys:
            L = int(np.asarray(b[k]).shape[1])
            real += rows * L
            padded += rows * (maxes[k] - L)
    tap_lengths = None
    if ragged:
        tap_lengths = []
        for b in batches:
            rec = {}
            for k in ragged_keys:
                L = int(np.asarray(b[k]).shape[1])
                rec[k] = L - 1 if (generation and k == "tokens") else L
            tap_lengths.append(rec)
        for k in ragged_keys:
            lk = RAGGED_INPUTS[k]
            if lk not in batch:
                batch[lk] = np.concatenate([
                    np.full(rows, np.asarray(b[k]).shape[1], np.int32)
                    for b, rows in zip(batches, sizes)
                ])
    return batch, tap_lengths, sizes, real, padded
