"""Parallel co-tenancy: merge many users' intervention graphs into ONE
forward pass (paper Appendix B.2 — listed there as future work; implemented
here as a beyond-paper feature and benchmarked in fig9).

Each request owns a contiguous group of batch rows.  The merger rewrites
every getter into a batch-slice of the shared tap value and every setter into
a ``dynamic_update_slice`` confined to the request's rows, so experiments are
*structurally* isolated: a user's graph cannot read or write another user's
rows, and the model weights are untouched (pure function).  This is the
"extracts appropriate slices while preserving gradient propagation" design
the paper sketches, realized with JAX functional updates.

Limitations (documented, enforced):
  * all requests must share non-batch input dims (the scheduler groups
    compatible requests);
  * requests using ``.grad`` are executed solo (cross-user losses would have
    to be summed, entangling perturbation bookkeeping) — the scheduler falls
    back to sequential co-tenancy for those, exactly the paper's baseline.
"""
from __future__ import annotations

import dataclasses

from repro.core.graph import ALL_STEPS, InterventionGraph, Node, Ref, map_refs

__all__ = ["MergedBatch", "merge_graphs", "split_results"]

BATCH_AXIS = 0


@dataclasses.dataclass
class MergedBatch:
    graph: InterventionGraph
    row_slices: list[tuple[int, int]]  # (start, size) per request
    save_prefixes: list[str]


def merge_graphs(
    graphs: list[InterventionGraph], batch_sizes: list[int]
) -> MergedBatch:
    if len(graphs) != len(batch_sizes):
        raise ValueError("one batch size per graph required")
    for g in graphs:
        for n in g.nodes:
            if n.op == "grad_get":
                raise ValueError(
                    "graphs using .grad cannot be batch-merged; "
                    "schedule them sequentially"
                )
            if n.op == "tap_set" and n.step == ALL_STEPS:
                # A merged setter is a read-modify-write, and ALL_STEPS
                # getters are invalid — expand to concrete steps client-side
                # or run solo.
                raise ValueError(
                    "graphs using all_steps() setters cannot be "
                    "batch-merged; schedule them sequentially"
                )

    merged = InterventionGraph()
    # Per (site, layer, step): the pristine shared getter and the current
    # (post-previous-setters) value node.  Step is part of the key so merged
    # generation requests tapping one site at different decode steps never
    # alias (None for single-forward graphs).
    shared_get: dict[tuple[str | None, int | None, int | None], Node] = {}
    current: dict[tuple[str | None, int | None, int | None], Node] = {}

    starts: list[int] = []
    acc = 0
    for b in batch_sizes:
        starts.append(acc)
        acc += b

    row_slices = []
    prefixes = []
    for r, (g, start, size) in enumerate(zip(graphs, starts, batch_sizes)):
        row_slices.append((start, size))
        prefix = f"r{r}"
        prefixes.append(prefix)
        idmap: dict[int, int] = {}

        def remap(obj):
            return map_refs(obj, lambda ref: Ref(idmap[ref.node_id]))

        for n in g.nodes:
            key = (n.site, n.layer, n.step)
            if n.op == "tap_get":
                if key not in shared_get:
                    node = merged.add(
                        "tap_get", site=n.site, layer=n.layer, step=n.step
                    )
                    shared_get[key] = node
                    current.setdefault(key, node)
                sl = merged.add(
                    "dynamic_slice_in_dim",
                    Ref(shared_get[key].id),
                    start,
                    size,
                    axis=BATCH_AXIS,
                )
                idmap[n.id] = sl.id
            elif n.op == "tap_set":
                if key not in current:
                    node = merged.add(
                        "tap_get", site=n.site, layer=n.layer, step=n.step
                    )
                    shared_get.setdefault(key, node)
                    current[key] = node
                val_ref = remap(n.args[0])
                upd = merged.add(
                    "dynamic_update_slice_in_dim",
                    Ref(current[key].id),
                    val_ref,
                    start,
                    axis=BATCH_AXIS,
                )
                merged.add(
                    "tap_set", Ref(upd.id),
                    site=n.site, layer=n.layer, step=n.step,
                )
                current[key] = upd
                idmap[n.id] = upd.id
            elif n.op == "input":
                node = merged.add("input", f"{prefix}/{n.args[0]}")
                idmap[n.id] = node.id
            else:
                node = merged.add(
                    n.op,
                    *remap(n.args),
                    site=n.site,
                    layer=n.layer,
                    step=n.step,
                    meta=dict(n.meta),
                    **remap(n.kwargs),
                )
                idmap[n.id] = node.id

        for name, nid in g.saves.items():
            merged.saves[f"{prefix}/{name}"] = idmap[nid]

    return MergedBatch(graph=merged, row_slices=row_slices, save_prefixes=prefixes)


def split_results(
    merged_saves: dict[str, object], batch: MergedBatch
) -> list[dict[str, object]]:
    out: list[dict[str, object]] = [dict() for _ in batch.save_prefixes]
    for name, value in merged_saves.items():
        prefix, _, rest = name.partition("/")
        idx = batch.save_prefixes.index(prefix)
        out[idx][rest] = value
    return out
