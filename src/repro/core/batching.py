"""Parallel co-tenancy: merge many users' intervention graphs into ONE
forward pass (paper Appendix B.2 — listed there as future work; implemented
here as a beyond-paper feature and benchmarked in fig9 / cotenancy_ragged).

Each request owns a contiguous group of batch rows.  The merger rewrites
every getter into a batch-slice of the shared tap value and every setter into
a ``dynamic_update_slice`` confined to the request's rows, so experiments are
*structurally* isolated: a user's graph cannot read or write another user's
rows, and the model weights are untouched (pure function).  This is the
"extracts appropriate slices while preserving gradient propagation" design
the paper sketches, realized with JAX functional updates.

Ragged lengths (pad-and-mask merging)
-------------------------------------
Requests do NOT need equal sequence lengths: the scheduler right-pads each
model input to the group maximum and passes a per-request ``lengths`` record
here.  For every tap site with a sequence axis (``site_length_key`` maps the
site to the input whose axis-1 length it follows), a shorter request's
getter is additionally sliced to its TRUE length — user ops downstream see
exactly the shapes a solo run would produce (so positional indexing like
``x[:, -1]`` grabs the real last token, never padding) — and its setter is
written back with ``batch_update_slice``, confined to its real rows AND real
positions.  Padded positions carry sentinel position ids which the model
side (``repro.models.common._mask_bias``, dt-masked SSD scans) proves inert,
so every unpadded save is identical to solo execution.

Limitations (documented, enforced):
  * requests must share dtypes and every non-batch dim EXCEPT the sequence
    axis of declared ragged inputs (the scheduler buckets lengths with a
    configurable ``pad_slack`` bounding wasted padding compute);
  * sites with no sequence axis (e.g. ``layers.ssm_state``) merge on batch
    rows only — their values are per-row, never per-position;
  * requests using ``.grad`` are executed solo (cross-user losses would have
    to be summed, entangling perturbation bookkeeping) — the scheduler falls
    back to sequential co-tenancy for those, exactly the paper's baseline;
  * ``all_steps()`` setters run solo (a merged setter is a read-modify-write
    and broadcast getters are invalid — expand to concrete steps instead).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.graph import (
    ALL_STEPS,
    PREFILL_STEP,
    InterventionGraph,
    Node,
    Ref,
    map_refs,
)

__all__ = ["MergedBatch", "merge_graphs", "split_results", "RAGGED_INPUTS"]

BATCH_AXIS = 0
SEQ_AXIS = 1

# Model inputs whose axis 1 may differ across merged requests, and the
# batch key carrying per-row valid lengths for each.  Other 2D+ inputs
# (e.g. fixed-size image embeddings) still require an exact match.  Shared
# by the burst scheduler and the continuous-batching admission path.
RAGGED_INPUTS = {"tokens": "lengths", "src_embeds": "src_lengths"}


@dataclasses.dataclass
class MergedBatch:
    graph: InterventionGraph
    row_slices: list[tuple[int, int]]  # (start, size) per request
    save_prefixes: list[str]
    # per-request tap-site lengths (input key -> true length), None = uniform
    lengths: list[dict[str, int]] | None = None
    # per-request [start, end) ranges of merged-graph node ids — log entries
    # (node_id, value) are attributed back to their owning request with this
    node_ranges: list[tuple[int, int]] | None = None

    def owner_of(self, node_id: int) -> int | None:
        """Index of the request whose segment produced ``node_id``."""
        for r, (lo, hi) in enumerate(self.node_ranges or ()):
            if lo <= node_id < hi:
                return r
        return None


def merge_graphs(
    graphs: list[InterventionGraph],
    batch_sizes: list[int],
    *,
    lengths: list[dict[str, int]] | None = None,
    site_length_key: Callable[[str], str | None] | None = None,
    starts: list[int] | None = None,
    normalize_steps: bool = False,
    length_pad_to: dict[str, int] | None = None,
) -> MergedBatch:
    """Merge per-request graphs into one batched graph.

    ``lengths`` (optional) holds one dict per request mapping a ragged input
    key (e.g. ``"tokens"``) to that request's TRUE axis-1 length at tap
    sites; the model inputs are assumed right-padded to the group max.
    ``site_length_key(site)`` maps a tap-site name to the input key its
    value's axis 1 follows (``None`` = no sequence axis); defaults to
    ``"tokens"`` for every site.

    ``starts`` (optional) pins each request to an EXPLICIT batch-row offset
    instead of packing requests contiguously from row 0.  This is the
    slot-table form used by continuous batching: a request admitted into a
    running decode loop keeps its slot rows for its whole lifetime, so its
    getters/setters are rewritten against those rows while other slots (free,
    or owned by co-tenant requests at other decode steps) stay untouched.

    ``length_pad_to`` overrides the padded width the inputs were actually
    padded to (per ragged key) when it EXCEEDS the group's own maximum —
    continuous batching pads every admission to its length-bucket ceiling so
    repeated admissions share one compiled prefill, which means even the
    longest request of a group may be padded and need length slicing.

    ``normalize_steps=True`` strips the generation-step coordinate from tap
    nodes.  Per-execution slice graphs (:func:`repro.core.generation
    .slice_steps`) already encode WHICH execution they run in, but co-tenant
    requests inside one slot-table decode step sit at *different* local step
    indices; normalizing lets their taps share one getter and one
    read-modify-write setter chain per (site, layer).  ``ALL_STEPS`` setters
    are allowed in this form — the slicer has already replicated them into
    concrete executions, so the merged setter is an ordinary row-confined
    write.
    """
    if len(graphs) != len(batch_sizes):
        raise ValueError("one batch size per graph required")
    if lengths is not None and len(lengths) != len(graphs):
        raise ValueError("one lengths record per graph required")
    if starts is not None and len(starts) != len(graphs):
        raise ValueError("one row start per graph required")
    for g in graphs:
        for n in g.nodes:
            if n.op == "grad_get":
                raise ValueError(
                    "graphs using .grad cannot be batch-merged; "
                    "schedule them sequentially"
                )
            if (n.op == "tap_set" and n.step == ALL_STEPS
                    and not normalize_steps):
                # A merged setter is a read-modify-write, and ALL_STEPS
                # getters are invalid — expand to concrete steps client-side
                # or run solo.
                raise ValueError(
                    "graphs using all_steps() setters cannot be "
                    "batch-merged; schedule them sequentially"
                )

    length_key = site_length_key or (lambda site: "tokens")
    group_max: dict[str, int] = {}
    if lengths is not None:
        for rec in lengths:
            for k, v in rec.items():
                group_max[k] = max(group_max.get(k, 0), int(v))
        for k, v in (length_pad_to or {}).items():
            group_max[k] = max(group_max.get(k, 0), int(v))

    def true_length(r: int, n: Node) -> int | None:
        """The request's tap-value length at this node, when it is SHORTER
        than the group max (i.e. the value is padded and needs slicing).

        Decode-step taps (step >= 0) are per-token — their axis 1 is the
        singleton decode axis, identical for every request — so only
        single-forward (step None) and prefill taps are length-sliced.
        """
        if lengths is None or n.site is None:
            return None
        if n.step is not None and n.step != PREFILL_STEP:
            return None
        key = length_key(n.site)
        if key is None or key not in lengths[r]:
            return None
        L = int(lengths[r][key])
        return L if L < group_max.get(key, L) else None

    merged = InterventionGraph()
    # Per (site, layer, step): the pristine shared getter and the current
    # (post-previous-setters) value node.  Step is part of the key so merged
    # generation requests tapping one site at different decode steps never
    # alias (None for single-forward graphs).
    shared_get: dict[tuple[str | None, int | None, int | None], Node] = {}
    current: dict[tuple[str | None, int | None, int | None], Node] = {}

    if starts is None:
        starts = []
        acc = 0
        for b in batch_sizes:
            starts.append(acc)
            acc += b

    row_slices = []
    prefixes = []
    node_ranges = []
    for r, (g, start, size) in enumerate(zip(graphs, starts, batch_sizes)):
        row_slices.append((start, size))
        prefix = f"r{r}"
        prefixes.append(prefix)
        range_start = len(merged.nodes)
        idmap: dict[int, int] = {}

        def remap(obj):
            return map_refs(obj, lambda ref: Ref(idmap[ref.node_id]))

        for n in g.nodes:
            n_step = None if normalize_steps else n.step
            key = (n.site, n.layer, n_step)
            if n.op == "tap_get":
                if key not in shared_get:
                    node = merged.add(
                        "tap_get", site=n.site, layer=n.layer, step=n_step
                    )
                    shared_get[key] = node
                    current.setdefault(key, node)
                sl = merged.add(
                    "dynamic_slice_in_dim",
                    Ref(shared_get[key].id),
                    start,
                    size,
                    axis=BATCH_AXIS,
                )
                L = true_length(r, n)
                if L is not None:
                    # unpad: the request's ops see its solo shapes
                    sl = merged.add(
                        "dynamic_slice_in_dim", Ref(sl.id), 0, L, axis=SEQ_AXIS
                    )
                idmap[n.id] = sl.id
            elif n.op == "tap_set":
                if key not in current:
                    node = merged.add(
                        "tap_get", site=n.site, layer=n.layer, step=n_step
                    )
                    shared_get.setdefault(key, node)
                    current[key] = node
                val_ref = remap(n.args[0])
                if true_length(r, n) is not None:
                    # ragged write: confined to real rows AND real positions
                    # (the update value is solo-shaped, start = (row, 0, ...))
                    upd = merged.add(
                        "batch_update_slice",
                        Ref(current[key].id),
                        val_ref,
                        start,
                    )
                else:
                    upd = merged.add(
                        "dynamic_update_slice_in_dim",
                        Ref(current[key].id),
                        val_ref,
                        start,
                        axis=BATCH_AXIS,
                    )
                merged.add(
                    "tap_set", Ref(upd.id),
                    site=n.site, layer=n.layer, step=n_step,
                )
                current[key] = upd
                idmap[n.id] = upd.id
            elif n.op == "input":
                node = merged.add("input", f"{prefix}/{n.args[0]}")
                idmap[n.id] = node.id
            else:
                node = merged.add(
                    n.op,
                    *remap(n.args),
                    site=n.site,
                    layer=n.layer,
                    step=n.step,
                    meta=dict(n.meta),
                    **remap(n.kwargs),
                )
                idmap[n.id] = node.id

        for name, nid in g.saves.items():
            merged.saves[f"{prefix}/{name}"] = idmap[nid]
        node_ranges.append((range_start, len(merged.nodes)))

    return MergedBatch(
        graph=merged,
        row_slices=row_slices,
        save_prefixes=prefixes,
        lengths=lengths,
        node_ranges=node_ranges,
    )


def split_results(
    merged_saves: dict[str, object], batch: MergedBatch
) -> list[dict[str, object]]:
    out: list[dict[str, object]] = [dict() for _ in batch.save_prefixes]
    for name, value in merged_saves.items():
        prefix, _, rest = name.partition("/")
        idx = batch.save_prefixes.index(prefix)
        out[idx][rest] = value
    return out
