"""Synthetic sharded token pipeline + byte tokenizer.

Deterministic, seeded, and host-side (numpy) so it composes with any mesh:
the launcher shards each global batch with ``jax.device_put`` against the
batch NamedSharding.  Two sources:

  * ``synthetic_lm_data``  — a mixture of (a) Zipf-distributed unigrams and
    (b) deterministic k-gram motifs, so a model trained on it has learnable
    structure (loss decreases measurably within a few hundred steps).
  * ``ByteTokenizer``      — reversible UTF-8 byte tokenizer for the examples
    and serving demos (vocab 256 + specials).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import numpy as np

__all__ = ["DataConfig", "synthetic_lm_data", "ByteTokenizer", "shard_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    motif_period: int = 16  # deterministic structure the model can learn


def synthetic_lm_data(cfg: DataConfig, extras: dict | None = None) -> Iterator[dict]:
    """Yields {tokens, labels} batches forever. labels = next token."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    # Zipf weights over the vocab.
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    motif = rng.integers(0, v, size=cfg.motif_period)
    while True:
        base = rng.choice(v, size=(cfg.batch_size, cfg.seq_len + 1), p=probs)
        # Overlay the motif on a random phase for half the rows: predictable.
        phase = rng.integers(0, cfg.motif_period, size=cfg.batch_size)
        t = (np.arange(cfg.seq_len + 1)[None, :] + phase[:, None]) % cfg.motif_period
        motif_rows = motif[t]
        use = rng.random(cfg.batch_size) < 0.5
        seqs = np.where(use[:, None], motif_rows, base).astype(np.int32)
        batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        if extras:
            for k, spec in extras.items():
                batch[k] = rng.standard_normal(spec["shape"]).astype(
                    spec.get("dtype", np.float32)
                )
        yield batch


class ByteTokenizer:
    """Reversible UTF-8 byte tokenizer. ids 0..255 bytes; 256=BOS, 257=EOS."""

    vocab_size = 258
    bos_id = 256
    eos_id = 257

    def encode(self, text: str, bos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.bos_id] + ids
        return np.asarray(ids, dtype=np.int32)

    def decode(self, ids) -> str:
        ids = [int(i) for i in np.asarray(ids).reshape(-1)]
        data = bytes(i for i in ids if i < 256)
        return data.decode("utf-8", errors="replace")

    def encode_batch(self, texts: list[str], pad_to: int | None = None) -> np.ndarray:
        encs = [self.encode(t) for t in texts]
        n = pad_to or max(len(e) for e in encs)
        out = np.full((len(encs), n), self.eos_id, dtype=np.int32)
        for i, e in enumerate(encs):
            out[i, : min(len(e), n)] = e[:n]
        return out


def shard_batch(batch: dict, mesh, pspec_fn) -> dict:
    """device_put a host batch against the mesh's batch shardings."""
    import jax
    from repro.distributed import named_sharding

    specs = pspec_fn(batch)
    return jax.tree.map(
        lambda x, s: jax.device_put(
            x, named_sharding(mesh, s, tuple(np.shape(x)))
        ),
        batch,
        specs,
    )
