"""Mesh registry + sharding-constraint helpers.

Models annotate activations with ``shard_hint(x, P(...))``.  When no mesh is
active (CPU unit tests) the hint is the identity; under the production mesh
(``launch/mesh.py``) it becomes ``with_sharding_constraint``.  Axis names not
present in the active mesh are dropped, so the same model code serves the
single-pod ("data","model") and multi-pod ("pod","data","model") meshes.

Divisibility guard: a dimension is only sharded if the named axes divide it —
otherwise the hint silently falls back to replication for that dim (e.g. 8 KV
heads cannot shard over 16 model devices; the cache stays head-replicated and
we shard batch instead — see DESIGN.md §5).
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "active_mesh",
    "use_mesh",
    "shard_hint",
    "named_sharding",
    "sanitize_spec",
    "BATCH_AXES",
    "MODEL_AXIS",
]

BATCH_AXES = ("pod", "data")  # batch shards over whichever of these exist
MODEL_AXIS = "model"

_MESH: list[Mesh | None] = []


def active_mesh() -> Mesh | None:
    return _MESH[-1] if _MESH else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None) -> Iterator[None]:
    """Register the mesh for shard_hint. NamedSharding carries the mesh
    explicitly, so no jax-level context is required."""
    _MESH.append(mesh)
    try:
        yield
    finally:
        _MESH.pop()


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def sanitize_spec(spec: P, dim_sizes: tuple[int, ...], mesh: Mesh) -> P:
    """Drop unknown axes; drop shardings that do not divide the dim."""
    sizes = _axis_sizes(mesh)
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in sizes)
        if not axes:
            out.append(None)
            continue
        total = 1
        for a in axes:
            total *= sizes[a]
        if i < len(dim_sizes) and dim_sizes[i] % total != 0:
            out.append(None)  # replicate rather than fail
            continue
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def shard_hint(x: Any, spec: P) -> Any:
    mesh = active_mesh()
    if mesh is None:
        return x
    safe = sanitize_spec(spec, tuple(getattr(x, "shape", ())), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, safe))


def named_sharding(
    mesh: Mesh, spec: P, shape: tuple[int, ...] | None = None
) -> NamedSharding:
    if shape is not None:
        spec = sanitize_spec(spec, shape, mesh)
    return NamedSharding(mesh, spec)
