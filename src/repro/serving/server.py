"""The NDIF server: preloaded models, request handling, safe co-tenancy.

Paper §3.3 / Figure 4.  Responsibilities implemented here:

  * **model service layer** — hosts named (model, params) pairs, preloaded
    once (the Fig. 6a win: setup time is ~constant for users);
  * **request processing** — decode JSON requests, validate the graph against
    the op registry and the model's site schedule *before* execution (safe
    co-tenancy: ops are registry names, never user code — contrast Garçon);
  * **object store** — results parked under a request id; the client pulls
    saved values only (the Fig. 6c win: server-side metrics, tiny replies);
  * **scheduling** — sequential or parallel co-tenancy per model.

The wire protocol is a dict (JSON-encodable via repro.core.serialize):
  {"kind": "trace",   "model": str, "graph": {...}, "batch": {...},
   "premerged": bool, "stop": bool}
  {"kind": "session", "model": str,
   "traces": [{graph, batch, premerged?, stop?, cross?}, ...]}
  {"kind": "generate","model": str, "batch": {...}, "max_new_tokens": int}
  {"kind": "generate","model": str,
   "invokes": [{graph?, batch, max_new_tokens}, ...]}
  {"kind": "stats",   "model": str}
Reply: {"ok": bool, "results": ... | "error": str}

Live serving (the threaded front door, repro.serving.frontdoor):
  {"kind": "submit", "model": str, "graph"?: {...}, "batch": {...},
   "max_new_tokens"?: int, "stream"?: bool, "slo_ms"?: float,
   "deadline_ms"?: float, "idempotency_key"?: str}
      -> {"ok": True, "ticket": id} immediately, or a structured refusal
         {"ok": False, "error": str, "code": "backpressure"|"capacity"|
          "slo"|"closed", "retry_after_ms"?: float, ...}
      ``deadline_ms`` is enforced server-side (expired tickets are
      evicted mid-decode, code="deadline"); ``idempotency_key`` dedupes
      a retried submit after an ambiguous transport failure to the
      ORIGINAL ticket.
  {"kind": "poll",   "model": str, "ticket": id, "since"?: int}
  {"kind": "stream", "model": str, "ticket": id, "timeout"?: float,
   "since"?: int}
      -> {"ok": True, "chunks": [{ticket, seq, kind, payload, final}...],
          "done": bool}; ``stream`` blocks (in the CLIENT's thread — the
          engine thread keeps stepping) until a chunk or termination.
      ``since`` switches to idempotent cursor reads: chunks with
      ``seq >= since`` are (re-)delivered from channel history, so a
      lost reply is never data loss — retry with the same cursor.
  {"kind": "cancel", "model": str, "ticket": id}
      -> {"ok": True, "cancelled": bool} — cooperative: the ticket's
         channel terminates with code="cancelled" at the next boundary;
         ``cancelled=False`` means it already finished.
The per-model FrontDoor is created lazily at the first ``submit`` and owns
its own decode loop; the synchronous kinds above keep their scheduler.

Multi-invoke traces arrive PRE-merged (the tracer lowered its invokes into
one row-sliced graph client-side): ``premerged=True`` makes the scheduler
run them as-is — re-merging with co-tenant requests would re-slice their
slices.  ``stop=True`` (tracer.stop()) truncates the forward after the last
referenced site; it runs solo on a compiled+cached truncated program.  A multi-invoke GENERATION
request ships its invokes as a list: under ``policy="continuous"`` each
invoke is admitted as a row-group of the persistent decode loop (retiring
at its own ``max_new_tokens``, co-tenants welcome); other policies serve
the list through one private engine-level slot loop.

Session traces may carry ``cross`` refs — ``[{input, trace, save}, ...]``
— binding an EARLIER trace's saved value as a constant input of this one
(the session value-flow DAG).  Traces with refs execute in order,
server-side; the intermediate values never cross the wire.

Ragged lengths cross the wire as ordinary batch arrays: a right-padded
``batch`` may carry ``lengths`` (B,) — per-row valid token counts — and,
for encoder-decoder models, ``src_lengths`` (B,).  The scheduler also pads
and synthesizes these itself when bucket-compatible requests of different
lengths merge (see repro.serving.scheduler), so clients never need to pad.
``stats`` returns the engine's EngineStats snapshot (compiles, generations,
merged-group sizes, padding waste) for capacity planning.
"""
from __future__ import annotations

import json
import threading
from typing import Any

import numpy as np

from repro.core.graph import GraphValidationError, InterventionGraph
from repro.core.op_registry import OPS
from repro.core.serialize import decode_value, encode_value, graph_from_json
from repro.serving.engine import InferenceEngine
from repro.serving.frontdoor import AdmissionError, FrontDoor
from repro.serving.scheduler import CoTenantScheduler, Request, _attach_logs

__all__ = ["NDIFServer"]

_PROTOCOL_OPS = {"tap_get", "tap_set", "grad_get", "save", "log", "constant",
                 "input"}


class NDIFServer:
    def __init__(self) -> None:
        self.engines: dict[str, InferenceEngine] = {}
        self.schedulers: dict[str, CoTenantScheduler] = {}
        self.object_store: dict[int, Any] = {}
        # live front doors, one per model, created lazily at first submit
        # (each owns an engine thread — synchronous-only servers never pay)
        self.frontdoors: dict[str, FrontDoor] = {}
        self._door_cfg: dict[str, dict] = {}
        self._door_lock = threading.Lock()

    # ------------------------------------------------------------- hosting
    def host(
        self,
        name: str,
        model: Any,
        params: Any,
        *,
        mode: str = "unrolled",
        policy: str = "sequential",
        max_batch_rows: int = 64,
        pad_slack: int = 16,
        max_batch_cells: int = 8192,
        num_slots: int = 8,
        slot_max_len: int = 160,
        max_queue_depth: int = 32,
        door_kwargs: dict | None = None,
    ) -> None:
        """Preload a model (the expensive step users never pay for).

        ``policy="continuous"`` serves generation through a persistent
        slot-table decode loop (``num_slots`` rows, ``slot_max_len`` cache
        positions) with in-flight admission; see repro.serving.scheduler.
        ``max_queue_depth`` bounds the live front door's backlog (the
        ``submit`` wire kind) — submissions beyond it are refused with
        structured backpressure.  ``door_kwargs`` passes extra FrontDoor
        knobs through (``max_restarts``, ``stall_timeout_s``,
        ``quarantine_after``, ``retry_after_bounds``, ...)."""
        engine = InferenceEngine(model, params, mode=mode, name=name)
        self.engines[name] = engine
        self.schedulers[name] = CoTenantScheduler(
            engine, policy=policy, max_batch_rows=max_batch_rows,
            pad_slack=pad_slack, max_batch_cells=max_batch_cells,
            num_slots=num_slots, slot_max_len=slot_max_len,
        )
        self._door_cfg[name] = dict(
            num_slots=num_slots, slot_max_len=slot_max_len,
            pad_slack=pad_slack, max_queue_depth=max_queue_depth,
            **(door_kwargs or {}),
        )

    def _frontdoor(self, name: str) -> FrontDoor:
        """The model's live front door, created on first use (engine
        thread + its own continuous scheduler/loop — the synchronous wire
        kinds never share state with it)."""
        with self._door_lock:
            door = self.frontdoors.get(name)
            if door is None:
                if getattr(self, "_doors_closed", False):
                    raise AdmissionError(
                        "server was shut down", "closed"
                    )
                door = FrontDoor(self.engines[name], **self._door_cfg[name])
                self.frontdoors[name] = door
            return door

    def shutdown(self) -> None:
        """Close every live front door: residents drain, queued work is
        rejected with a structured error, engine threads join.  Closed
        doors STAY registered — a submit afterwards gets the structured
        ``code="closed"`` refusal instead of silently opening a fresh
        door (and leaking its engine thread past the server's lifetime)."""
        with self._door_lock:
            self._doors_closed = True
            doors = list(self.frontdoors.values())
        for door in doors:
            door.close()

    def hosted(self) -> list[str]:
        return sorted(self.engines)

    # ------------------------------------------------------ graph security
    def _check_registry(self, graph: InterventionGraph) -> None:
        """Safe co-tenancy gate: every op must be a registry name."""
        for n in graph.nodes:
            if n.op not in OPS and n.op not in _PROTOCOL_OPS:
                raise GraphValidationError(
                    f"op {n.op!r} is not in the server op registry "
                    "(arbitrary code execution is not permitted)"
                )

    def _validate_graph(self, engine: InferenceEngine, graph: InterventionGraph):
        self._check_registry(graph)
        graph.validate(engine.schedule.order)

    def _validate_generation_graph(
        self, engine: InferenceEngine, graph: InterventionGraph
    ) -> None:
        """Registry check only; step/site scheduling is validated per step
        by the generation driver (repro.core.generation.slice_steps)."""
        self._check_registry(graph)

    # ----------------------------------------------------- session handling
    def _handle_session(self, sched, engine, msg: dict) -> dict:
        """Execute a session's traces as one request.

        Traces without ``cross`` refs submit together (they may co-tenant
        merge); a trace WITH refs needs its producers' results first, so
        sessions carrying refs execute strictly in declaration order and
        the referenced saves are patched in as constants before validation
        — the session value-flow DAG, evaluated server-side.
        """
        traces = msg["traces"]
        any_cross = any(tr.get("cross") for tr in traces)
        results: list = []
        if not any_cross:
            tickets = []
            for tr in traces:
                graph = graph_from_json(tr["graph"])
                self._validate_graph(engine, graph)
                batch = {k: np.asarray(v) for k, v in tr["batch"].items()}
                tickets.append(sched.submit(Request(
                    graph=graph, batch=batch,
                    premerged=bool(tr.get("premerged")),
                    stop=bool(tr.get("stop")),
                )))
            sched.drain()
            for t in tickets:
                if t.error:
                    return {"ok": False, "error": t.error}
                results.append(t.result)
            return {"ok": True, "results": results}
        for i, tr in enumerate(traces):
            graph = graph_from_json(tr["graph"])
            for ref in tr.get("cross") or []:
                src = int(ref["trace"])
                if not 0 <= src < i:
                    return {"ok": False, "error":
                            f"trace {i} references trace {src}; cross-"
                            "trace values only flow forward"}
                try:
                    value = results[src][ref["save"]]
                except KeyError:
                    return {"ok": False, "error":
                            f"trace {src} has no save {ref['save']!r} "
                            f"(trace {i} references it)"}
                self._patch_cross_input(graph, ref["input"], value)
            self._validate_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in tr["batch"].items()}
            ticket = sched.submit(Request(
                graph=graph, batch=batch,
                premerged=bool(tr.get("premerged")),
                stop=bool(tr.get("stop")),
            ))
            sched.drain()
            if ticket.error:
                return {"ok": False, "error": ticket.error}
            results.append(ticket.result)
        return {"ok": True, "results": results}

    @staticmethod
    def _patch_cross_input(graph, name: str, value) -> None:
        """Rewrite ``input`` nodes named ``name`` into constants carrying an
        earlier trace's saved value (in place: ids/edges are untouched, and
        the engine's structural key abstracts constant VALUES, so patched
        graphs still share compiled executables)."""
        hit = False
        for n in graph.nodes:
            if n.op == "input" and n.args[0] == name:
                n.op = "constant"
                n.args = (np.asarray(value),)
                hit = True
        if not hit:
            raise GraphValidationError(
                f"cross ref targets unknown input {name!r}"
            )

    def _handle_generate_invokes(self, sched, engine, msg: dict) -> dict:
        """One multi-invoke generation request -> one merged decode loop.

        Under ``policy="continuous"`` every invoke is submitted as its own
        scheduler request: all of them admit into the persistent slot-table
        loop at the same boundary (sharing a prefill when bucket-compatible)
        and retire independently — co-tenant requests ride along.  Other
        policies serve the invokes through one private engine-level loop
        (:meth:`InferenceEngine.generate_invokes`).
        """
        items = []
        for inv in msg["invokes"]:
            graph = (
                graph_from_json(inv["graph"]) if inv.get("graph")
                else InterventionGraph()
            )
            if graph.nodes:
                self._validate_generation_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in inv["batch"].items()}
            items.append((graph, batch,
                          int(inv.get("max_new_tokens", 16))))
        if sched.policy == "continuous":
            tickets = [
                sched.submit(Request(graph=g, batch=b, max_new_tokens=n))
                for g, b, n in items
            ]
            sched.drain()
            results = []
            for t in tickets:
                if t.error:
                    return {"ok": False, "error": t.error}
                results.append(t.result)
            return {"ok": True, "results": results}
        results = []
        for res in engine.generate_invokes(items):
            entry = {
                **res.saves,
                "tokens": np.asarray(res.tokens),
                "logits": np.asarray(res.logits),
            }
            _attach_logs(entry, res.logs)
            results.append(entry)
        return {"ok": True, "results": results}

    # ------------------------------------------------------------ handling
    def handle(self, payload: bytes) -> bytes:
        try:
            msg = decode_value(json.loads(payload.decode()))
            reply = self._dispatch(msg)
        except Exception as e:  # noqa: BLE001 — protocol boundary
            reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        return json.dumps(encode_value(reply), separators=(",", ":")).encode()

    def _dispatch(self, msg: dict) -> dict:
        kind = msg.get("kind")
        name = msg.get("model")
        if name not in self.engines:
            return {
                "ok": False,
                "error": f"model {name!r} is not hosted "
                         f"(available: {self.hosted()})",
            }
        engine = self.engines[name]
        sched = self.schedulers[name]
        if kind == "trace":
            graph = graph_from_json(msg["graph"])
            self._validate_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            ticket = sched.submit(Request(
                graph=graph, batch=batch,
                premerged=bool(msg.get("premerged")),
                stop=bool(msg.get("stop")),
            ))
            sched.drain()
            if ticket.error:
                return {"ok": False, "error": ticket.error}
            self.object_store[ticket.request_id] = ticket.result
            return {"ok": True, "results": self.object_store.pop(
                ticket.request_id), "request_id": ticket.request_id}
        if kind == "session":
            return self._handle_session(sched, engine, msg)
        if kind == "train_module":
            from repro.serving.remote_train import train_graph_inputs

            graph = graph_from_json(msg["graph"])
            self._validate_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            trained, history = train_graph_inputs(
                engine, graph, batch,
                trainable={k: np.asarray(v)
                           for k, v in msg["trainable"].items()},
                fixed_inputs={k: np.asarray(v)
                              for k, v in msg.get("fixed_inputs", {}).items()},
                loss_name=msg["loss"],
                steps=int(msg.get("steps", 50)),
                lr=float(msg.get("lr", 1e-2)),
            )
            return {"ok": True,
                    "results": {"params": trained, "losses": history}}
        if kind == "generate":
            if msg.get("invokes") is not None:
                return self._handle_generate_invokes(sched, engine, msg)
            # Routed through the scheduler so compatible generation
            # requests batch-merge exactly like single-forward traces.
            graph = (
                graph_from_json(msg["graph"]) if msg.get("graph")
                else InterventionGraph()
            )
            if graph.nodes:
                self._validate_generation_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            ticket = sched.submit(Request(
                graph=graph, batch=batch,
                max_new_tokens=int(msg.get("max_new_tokens", 16)),
            ))
            sched.drain()
            if ticket.error:
                return {"ok": False, "error": ticket.error}
            return {"ok": True, "results": ticket.result}
        if kind == "submit":
            graph = (
                graph_from_json(msg["graph"]) if msg.get("graph")
                else InterventionGraph()
            )
            n_new = msg.get("max_new_tokens")
            if graph.nodes:
                if n_new is None:
                    self._validate_graph(engine, graph)
                else:
                    self._validate_generation_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            req = Request(
                graph=graph, batch=batch,
                max_new_tokens=None if n_new is None else int(n_new),
                premerged=bool(msg.get("premerged")),
                stop=bool(msg.get("stop")),
            )
            slo = msg.get("slo_ms")
            dl = msg.get("deadline_ms")
            try:
                ticket = self._frontdoor(name).submit(
                    req, stream=bool(msg.get("stream")),
                    slo_ms=None if slo is None else float(slo),
                    deadline_ms=None if dl is None else float(dl),
                    idempotency_key=msg.get("idempotency_key"),
                )
            except AdmissionError as e:
                return {"ok": False, **e.payload}
            return {"ok": True, "ticket": ticket}
        if kind in ("poll", "stream"):
            door = self.frontdoors.get(name)
            if door is None:
                return {"ok": False,
                        "error": f"model {name!r} has no live front door "
                                 "(nothing was submitted)"}
            since = msg.get("since")
            try:
                chunks, done = door.take(
                    msg["ticket"], blocking=(kind == "stream"),
                    timeout=float(msg.get("timeout", 30.0)),
                    since=None if since is None else int(since),
                )
            except KeyError:
                return {"ok": False,
                        "error": f"unknown ticket {msg.get('ticket')!r}"}
            return {"ok": True, "chunks": chunks, "done": done}
        if kind == "cancel":
            door = self.frontdoors.get(name)
            if door is None:
                return {"ok": False,
                        "error": f"model {name!r} has no live front door "
                                 "(nothing was submitted)"}
            return {"ok": True,
                    "cancelled": door.cancel(msg["ticket"])}
        if kind == "stats":
            snap = engine.stats.snapshot()
            door = self.frontdoors.get(name)
            if door is not None:
                snap["queue_depth"] = door.queue_depth()
            return {"ok": True, "results": snap}
        if kind == "hidden_states":
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            tokens = batch.pop("tokens")
            return {
                "ok": True,
                "results": {"hidden": engine.hidden_states(tokens, **batch)},
            }
        return {"ok": False, "error": f"unknown request kind {kind!r}"}
