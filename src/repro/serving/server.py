"""The NDIF server: preloaded models, request handling, safe co-tenancy.

Paper §3.3 / Figure 4.  Responsibilities implemented here:

  * **model service layer** — hosts named (model, params) pairs, preloaded
    once (the Fig. 6a win: setup time is ~constant for users);
  * **request processing** — decode JSON requests, validate the graph against
    the op registry and the model's site schedule *before* execution (safe
    co-tenancy: ops are registry names, never user code — contrast Garçon);
  * **object store** — results parked under a request id; the client pulls
    saved values only (the Fig. 6c win: server-side metrics, tiny replies);
  * **scheduling** — sequential or parallel co-tenancy per model.

The wire protocol is a dict (JSON-encodable via repro.core.serialize):
  {"kind": "trace",   "model": str, "graph": {...}, "batch": {...}}
  {"kind": "session", "model": str, "traces": [{graph, batch}, ...]}
  {"kind": "generate","model": str, "batch": {...}, "max_new_tokens": int}
  {"kind": "stats",   "model": str}
Reply: {"ok": bool, "results": ... | "error": str}

Ragged lengths cross the wire as ordinary batch arrays: a right-padded
``batch`` may carry ``lengths`` (B,) — per-row valid token counts — and,
for encoder-decoder models, ``src_lengths`` (B,).  The scheduler also pads
and synthesizes these itself when bucket-compatible requests of different
lengths merge (see repro.serving.scheduler), so clients never need to pad.
``stats`` returns the engine's EngineStats snapshot (compiles, generations,
merged-group sizes, padding waste) for capacity planning.
"""
from __future__ import annotations

import json
from typing import Any

import numpy as np

from repro.core.graph import GraphValidationError, InterventionGraph
from repro.core.op_registry import OPS
from repro.core.serialize import decode_value, encode_value, graph_from_json
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request

__all__ = ["NDIFServer"]

_PROTOCOL_OPS = {"tap_get", "tap_set", "grad_get", "save", "log", "constant",
                 "input"}


class NDIFServer:
    def __init__(self) -> None:
        self.engines: dict[str, InferenceEngine] = {}
        self.schedulers: dict[str, CoTenantScheduler] = {}
        self.object_store: dict[int, Any] = {}

    # ------------------------------------------------------------- hosting
    def host(
        self,
        name: str,
        model: Any,
        params: Any,
        *,
        mode: str = "unrolled",
        policy: str = "sequential",
        max_batch_rows: int = 64,
        pad_slack: int = 16,
        max_batch_cells: int = 8192,
        num_slots: int = 8,
        slot_max_len: int = 160,
    ) -> None:
        """Preload a model (the expensive step users never pay for).

        ``policy="continuous"`` serves generation through a persistent
        slot-table decode loop (``num_slots`` rows, ``slot_max_len`` cache
        positions) with in-flight admission; see repro.serving.scheduler."""
        engine = InferenceEngine(model, params, mode=mode, name=name)
        self.engines[name] = engine
        self.schedulers[name] = CoTenantScheduler(
            engine, policy=policy, max_batch_rows=max_batch_rows,
            pad_slack=pad_slack, max_batch_cells=max_batch_cells,
            num_slots=num_slots, slot_max_len=slot_max_len,
        )

    def hosted(self) -> list[str]:
        return sorted(self.engines)

    # ------------------------------------------------------ graph security
    def _check_registry(self, graph: InterventionGraph) -> None:
        """Safe co-tenancy gate: every op must be a registry name."""
        for n in graph.nodes:
            if n.op not in OPS and n.op not in _PROTOCOL_OPS:
                raise GraphValidationError(
                    f"op {n.op!r} is not in the server op registry "
                    "(arbitrary code execution is not permitted)"
                )

    def _validate_graph(self, engine: InferenceEngine, graph: InterventionGraph):
        self._check_registry(graph)
        graph.validate(engine.schedule.order)

    def _validate_generation_graph(
        self, engine: InferenceEngine, graph: InterventionGraph
    ) -> None:
        """Registry check only; step/site scheduling is validated per step
        by the generation driver (repro.core.generation.slice_steps)."""
        self._check_registry(graph)

    # ------------------------------------------------------------ handling
    def handle(self, payload: bytes) -> bytes:
        try:
            msg = decode_value(json.loads(payload.decode()))
            reply = self._dispatch(msg)
        except Exception as e:  # noqa: BLE001 — protocol boundary
            reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        return json.dumps(encode_value(reply), separators=(",", ":")).encode()

    def _dispatch(self, msg: dict) -> dict:
        kind = msg.get("kind")
        name = msg.get("model")
        if name not in self.engines:
            return {
                "ok": False,
                "error": f"model {name!r} is not hosted "
                         f"(available: {self.hosted()})",
            }
        engine = self.engines[name]
        sched = self.schedulers[name]
        if kind == "trace":
            graph = graph_from_json(msg["graph"])
            self._validate_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            ticket = sched.submit(Request(graph=graph, batch=batch))
            sched.drain()
            if ticket.error:
                return {"ok": False, "error": ticket.error}
            self.object_store[ticket.request_id] = ticket.result
            return {"ok": True, "results": self.object_store.pop(
                ticket.request_id), "request_id": ticket.request_id}
        if kind == "session":
            results = []
            tickets = []
            for tr in msg["traces"]:
                graph = graph_from_json(tr["graph"])
                self._validate_graph(engine, graph)
                batch = {k: np.asarray(v) for k, v in tr["batch"].items()}
                tickets.append(sched.submit(Request(graph=graph, batch=batch)))
            sched.drain()
            for t in tickets:
                if t.error:
                    return {"ok": False, "error": t.error}
                results.append(t.result)
            return {"ok": True, "results": results}
        if kind == "train_module":
            from repro.serving.remote_train import train_graph_inputs

            graph = graph_from_json(msg["graph"])
            self._validate_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            trained, history = train_graph_inputs(
                engine, graph, batch,
                trainable={k: np.asarray(v)
                           for k, v in msg["trainable"].items()},
                fixed_inputs={k: np.asarray(v)
                              for k, v in msg.get("fixed_inputs", {}).items()},
                loss_name=msg["loss"],
                steps=int(msg.get("steps", 50)),
                lr=float(msg.get("lr", 1e-2)),
            )
            return {"ok": True,
                    "results": {"params": trained, "losses": history}}
        if kind == "generate":
            # Routed through the scheduler so compatible generation
            # requests batch-merge exactly like single-forward traces.
            graph = (
                graph_from_json(msg["graph"]) if msg.get("graph")
                else InterventionGraph()
            )
            if graph.nodes:
                self._validate_generation_graph(engine, graph)
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            ticket = sched.submit(Request(
                graph=graph, batch=batch,
                max_new_tokens=int(msg.get("max_new_tokens", 16)),
            ))
            sched.drain()
            if ticket.error:
                return {"ok": False, "error": ticket.error}
            return {"ok": True, "results": ticket.result}
        if kind == "stats":
            return {"ok": True, "results": engine.stats.snapshot()}
        if kind == "hidden_states":
            batch = {k: np.asarray(v) for k, v in msg["batch"].items()}
            tokens = batch.pop("tokens")
            return {
                "ok": True,
                "results": {"hidden": engine.hidden_states(tokens, **batch)},
            }
        return {"ok": False, "error": f"unknown request kind {kind!r}"}
