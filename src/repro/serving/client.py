"""NDIF client: the backend behind ``remote=True`` (paper Fig. 3b line 7).

Serializes the tracer's intervention graph + model inputs, ships them over a
transport, and inserts the returned ``.save()`` leaves back into the local
trace — the paper's "local WebSocket client pulls the final results from the
Object Store and inserts the result back into the local intervention graph".

Live serving: :meth:`NDIFClient.submit` posts work through the server's
threaded front door and returns a :class:`LiveTicket` immediately — poll
it, iterate its :meth:`~LiveTicket.chunks`, or block on
:meth:`~LiveTicket.result`.  A refused submission (queue full, SLO
infeasible, capacity) raises :class:`AdmissionRefused` carrying the
structured payload (``code``, ``retry_after_ms``, ...) so callers can
back off instead of string-matching error text.

Resilience: construct the client with a :class:`RetryPolicy` and every
roundtrip survives :class:`~repro.serving.transport.TransportError` —
exponential backoff with SEEDED jitter (reproducible schedules), the
server's ``retry_after_ms`` hint honored when present.  Retried submits
carry an auto-generated idempotency key, so the AMBIGUOUS failure (reply
lost after the server admitted) dedupes server-side instead of
double-executing; polls are cursor reads (``since`` = next expected
seq), so re-delivered chunks drop client-side and lost replies lose no
data.  ``deadline_ms`` rides submit for server-side enforcement;
:meth:`LiveTicket.cancel` requests cooperative cancellation.
"""
from __future__ import annotations

import json
import time
import uuid
from typing import Any, Iterator

import numpy as np

from repro.core.serialize import decode_value, encode_value, graph_to_json
from repro.serving.scheduler import LOGS_KEY
from repro.serving.stream import assemble_result, check_frames
from repro.serving.transport import TransportError

__all__ = ["AdmissionRefused", "LiveTicket", "NDIFClient", "RetryPolicy"]


class RetryPolicy:
    """Client-side retry schedule for lost messages and backpressure.

    ``delay_ms(attempt)`` grows exponentially from ``base_delay_ms`` and
    is jittered by a SEEDED rng — two clients with different seeds
    desynchronize their retries (no thundering herd), while one seed
    reproduces its schedule exactly.  A server-provided
    ``retry_after_ms`` hint (structured backpressure) wins whenever it
    is larger than the computed backoff.
    """

    def __init__(
        self,
        max_attempts: int = 4,
        *,
        base_delay_ms: float = 20.0,
        max_delay_ms: float = 2000.0,
        jitter: float = 0.25,
        seed: int = 0,
    ) -> None:
        self.max_attempts = int(max_attempts)
        self.base_delay_ms = float(base_delay_ms)
        self.max_delay_ms = float(max_delay_ms)
        self.jitter = float(jitter)
        self._rng = np.random.default_rng(seed)

    def delay_ms(self, attempt: int,
                 retry_after_ms: float | None = None) -> float:
        d = min(self.max_delay_ms, self.base_delay_ms * (2.0 ** attempt))
        d *= 1.0 + self.jitter * float(self._rng.random())
        if retry_after_ms is not None:
            d = max(d, float(retry_after_ms))
        return d

    def sleep(self, attempt: int,
              retry_after_ms: float | None = None) -> None:
        time.sleep(self.delay_ms(attempt, retry_after_ms) / 1000.0)


class AdmissionRefused(RuntimeError):
    """Structured front-door refusal; ``payload["code"]`` distinguishes
    ``backpressure`` / ``capacity`` / ``slo`` / ``closed`` and
    backpressure refusals carry ``retry_after_ms``."""

    def __init__(self, payload: dict) -> None:
        super().__init__(payload.get("error", "submission refused"))
        self.payload = dict(payload)
        self.code = payload.get("code")
        self.retry_after_ms = payload.get("retry_after_ms")


class LiveTicket:
    """Handle to one in-flight front-door submission.

    All messages for this ticket travel over one transport session (byte
    metering per conversation); chunks accumulate internally so
    :meth:`result` can frame-check the FULL sequence (gapless seqs, no
    cross-ticket chunks) before assembling.
    """

    def __init__(self, client: "NDIFClient", ticket_id: Any) -> None:
        self.client = client
        self.id = ticket_id
        session = getattr(client.transport, "session", None)
        self._transport = session() if session is not None else None
        self._chunks: list[dict] = []
        # next expected seq — polls are CURSOR reads (``since``) against
        # channel history, so a retried poll re-requests the same cursor
        # and duplicates from redelivery drop right here
        self._next_seq = 0
        self._done = False

    def _fetch(self, kind: str, timeout: float | None = None) -> list[dict]:
        msg = {"kind": kind, "model": self.client.model_name,
               "ticket": self.id, "since": self._next_seq}
        if timeout is not None:
            msg["timeout"] = timeout
        reply = self.client._roundtrip(msg, transport=self._transport)
        fresh = []
        for c in reply["chunks"]:
            if c["seq"] == self._next_seq:
                fresh.append(c)
                self._next_seq += 1
        self._chunks.extend(fresh)
        if reply["done"] and (not self._chunks
                              or self._chunks[-1]["final"]):
            self._done = True
            if self._transport is not None:
                self._transport.close()
        return fresh

    def cancel(self) -> bool:
        """Request cooperative cancellation server-side.  Returns True
        when the ticket was still live — its stream then terminates with
        a structured error (``code="cancelled"``); False means it
        already finished and the existing result stands."""
        if self._done:
            return False
        reply = self.client._roundtrip({
            "kind": "cancel", "model": self.client.model_name,
            "ticket": self.id,
        }, transport=self._transport)
        return bool(reply.get("cancelled"))

    def poll(self) -> list[dict]:
        """Non-blocking: whatever chunks arrived since the last call."""
        if self._done:
            return []
        return self._fetch("poll")

    def chunks(self, timeout: float = 30.0) -> Iterator[dict]:
        """Iterate chunks as the engine produces them (each wait blocks up
        to ``timeout`` on the server side, then retries)."""
        for c in list(self._chunks):
            yield c
        while not self._done:
            for c in self._fetch("stream", timeout=timeout):
                yield c

    @property
    def done(self) -> bool:
        return self._done

    def result(self, timeout: float = 120.0) -> dict:
        """Block until completion, verify framing, assemble the final
        result dict (identical to the synchronous ``generate``/``trace``
        form; streamed token chunks concatenate bit-exact)."""
        import time

        deadline = time.perf_counter() + timeout
        while not self._done:
            if time.perf_counter() > deadline:
                raise TimeoutError(f"ticket {self.id!r} still running")
            self._fetch("stream", timeout=5.0)
        check_frames(self._chunks, self.id)
        result, logs = assemble_result(self._chunks)
        if logs:
            result[LOGS_KEY] = logs
        return result


class NDIFClient:
    def __init__(self, transport: Any, model_name: str,
                 retry: RetryPolicy | None = None) -> None:
        self.transport = transport
        self.model_name = model_name
        # None = fail fast on the first TransportError (historic
        # behavior); a RetryPolicy makes every roundtrip resilient —
        # safe because polls are cursor reads and submits carry
        # idempotency keys
        self.retry = retry

    # ---------------------------------------------------------- preflight
    @staticmethod
    def _preflight_wire(graph, n_steps: int | None = None) -> None:
        """Layer-2 preflight: lint a graph BEFORE it ships.

        The client knows no site schedule or activation shapes — those
        facts live server-side — but op-registry membership, step-flow
        rules, and dead nodes are wire-graph facts, so a structurally
        broken request fails HERE (``PreflightError``) instead of costing
        a network roundtrip and a server rejection."""
        from repro.core import analysis

        mode = analysis.preflight_mode()
        if mode == "off" or graph is None or not graph.nodes:
            return
        analysis.analyze(graph, n_steps=n_steps).enforce(mode)

    # Tracer-facing API ------------------------------------------------
    def execute(self, tracer) -> dict[str, Any]:
        """Ship one trace.  Multi-invoke traces are lowered client-side
        (``tracer.execution_graph()`` is the merged row-sliced graph) and
        flagged ``premerged`` so the server runs them as-is; ``stop``
        carries tracer.stop() truncation to the server."""
        self._preflight_wire(tracer.execution_graph())
        msg = {
            "kind": "trace",
            "model": self.model_name,
            "graph": graph_to_json(tracer.execution_graph()),
            "batch": self._tracer_batch(tracer),
        }
        if tracer.invokes:
            msg["premerged"] = True
        if tracer._stop:
            msg["stop"] = True
        reply = self._roundtrip(msg)
        return reply["results"]

    def execute_session(self, session) -> list[dict[str, Any]]:
        """Ship a whole session as ONE request.

        Cross-trace value flow travels as ``cross`` refs — (input name,
        producing trace index, save name) triples — and is bound
        server-side; the intermediate values never cross the wire."""
        traces = []
        for t in session.tracers:
            entry = {
                "graph": graph_to_json(t.execution_graph()),
                "batch": self._tracer_batch(t),
            }
            if t.invokes:
                entry["premerged"] = True
            if t._stop:
                entry["stop"] = True
            cross = self._cross_refs(session, t)
            if cross:
                entry["cross"] = cross
            traces.append(entry)
        reply = self._roundtrip({
            "kind": "session",
            "model": self.model_name,
            "traces": traces,
        })
        return reply["results"]

    @staticmethod
    def _cross_refs(session, tracer) -> list[dict]:
        """Wire refs for this trace's cross-trace inputs.

        Names are translated to the forms the SERVER sees: a consuming
        multi-invoke trace exposes its bridged input replicated per invoke
        under the merge prefix (``r{k}/__xtrace...``); a producing
        multi-invoke trace's qualified save ``i{k}/name`` appears in its
        wire results as ``r{k}/name``."""
        refs = []
        for key, (src, save) in tracer._cross_inputs.items():
            src_idx = session.tracers.index(src)
            if src.invokes:
                k, sep, rest = save.partition("/")
                if sep and k.startswith("i") and k[1:].isdigit():
                    save = f"r{k[1:]}/{rest}"
                else:
                    # invoke-free saves execute on (and demux from) invoke 0
                    save = f"r0/{save}"
            if tracer.invokes:
                names = [m for m, o in tracer._merged_input_map.items()
                         if o == key]
            else:
                names = [key]
            refs.extend(
                {"input": n, "trace": src_idx, "save": save} for n in names
            )
        return refs

    # Remote module training (paper Code Example 5) ----------------------
    def train_module(self, graph, batch, *, trainable, loss="loss",
                     fixed_inputs=None, steps=50, lr=1e-2):
        """Ship an experiment whose ``input`` nodes are trainable; the
        server differentiates the interleaved program and optimizes them.
        Only the trained parameters + loss curve cross the wire back."""
        from repro.core.serialize import graph_to_json

        self._preflight_wire(graph)
        msg = {
            "kind": "train_module",
            "model": self.model_name,
            "graph": graph_to_json(graph),
            "batch": {k: np.asarray(v) for k, v in batch.items()},
            "trainable": {k: np.asarray(v) for k, v in trainable.items()},
            "fixed_inputs": {k: np.asarray(v)
                             for k, v in (fixed_inputs or {}).items()},
            "loss": loss,
            "steps": steps,
            "lr": lr,
        }
        return self._roundtrip(msg)["results"]

    # Plain-inference APIs (benchmark comparisons) ----------------------
    def generate(self, tokens, max_new_tokens: int = 16, *, graph=None,
                 lengths=None, **extras):
        """Server-side generation; ``graph`` may carry a step-annotated
        intervention graph (see repro.core.generation) to steer or record
        the decode loop remotely.  ``lengths`` (B,) marks per-row valid
        prefixes of a right-padded ``tokens`` batch — rows of different
        prompt lengths then share one prefill and one decode loop."""
        batch = {"tokens": np.asarray(tokens), **extras}
        if lengths is not None:
            batch["lengths"] = np.asarray(lengths, np.int32)
        self._preflight_wire(graph, n_steps=int(max_new_tokens))
        msg = {
            "kind": "generate",
            "model": self.model_name,
            "batch": batch,
            "max_new_tokens": max_new_tokens,
        }
        if graph is not None:
            msg["graph"] = graph_to_json(graph)
        return self._roundtrip(msg)["results"]

    def generate_invokes(self, invokes: list[dict]) -> list[dict]:
        """Ship a multi-invoke generation trace as ONE request.

        ``invokes`` is ``[{"graph": InterventionGraph | None, "batch":
        dict, "max_new_tokens": int}, ...]``; the server admits every
        invoke as a row-group of one decode loop (its persistent
        continuous-batching loop when hosted with ``policy="continuous"``,
        a private engine loop otherwise) and returns one result dict —
        saves plus reserved ``tokens``/``logits`` — per invoke, in order.
        """
        wire = []
        for inv in invokes:
            self._preflight_wire(
                inv.get("graph"),
                n_steps=int(inv.get("max_new_tokens", 16)),
            )
            entry = {
                "batch": {k: np.asarray(v)
                          for k, v in inv["batch"].items()},
                "max_new_tokens": int(inv.get("max_new_tokens", 16)),
            }
            if inv.get("graph") is not None and inv["graph"].nodes:
                entry["graph"] = graph_to_json(inv["graph"])
            wire.append(entry)
        msg = {
            "kind": "generate",
            "model": self.model_name,
            "invokes": wire,
        }
        return self._roundtrip(msg)["results"]

    # Live serving (the threaded front door) ----------------------------
    def submit(self, tokens=None, max_new_tokens: int | None = None, *,
               graph=None, batch: dict | None = None, stream: bool = False,
               slo_ms: float | None = None, deadline_ms: float | None = None,
               idempotency_key: str | None = None, lengths=None,
               **extras) -> LiveTicket:
        """Post work through the live front door; returns a
        :class:`LiveTicket` as soon as the server admits it (the decode
        loop keeps stepping co-tenants while this request queues).

        ``stream=True`` asks for incremental chunks — tokens per fused
        segment, saves and ``log()`` values as they flush; the default
        delivers one ``done`` chunk at retirement.  ``slo_ms`` opts into
        SLO-aware admission: the server refuses (:class:`AdmissionRefused`,
        ``code="slo"``) when the projected completion already blows the
        budget.  Raises :class:`AdmissionRefused` on structured refusals.

        ``deadline_ms`` is a hard budget the SERVER enforces (the ticket
        is evicted mid-decode past it, ``code="deadline"``).  With a
        :class:`RetryPolicy` on the client, lost submits retry under an
        ``idempotency_key`` (auto-generated unless given) — the retry
        after an ambiguous failure returns the ORIGINAL ticket instead
        of admitting twice — and structured backpressure refusals retry
        after the server's ``retry_after_ms`` hint.
        """
        if batch is None:
            batch = {"tokens": np.asarray(tokens), **extras}
            if lengths is not None:
                batch["lengths"] = np.asarray(lengths, np.int32)
        n_steps = None if max_new_tokens is None else int(max_new_tokens)
        self._preflight_wire(graph, n_steps=n_steps)
        msg = {
            "kind": "submit",
            "model": self.model_name,
            "batch": {k: np.asarray(v) for k, v in batch.items()},
            "stream": bool(stream),
        }
        if n_steps is not None:
            msg["max_new_tokens"] = n_steps
        if graph is not None and graph.nodes:
            msg["graph"] = graph_to_json(graph)
        if slo_ms is not None:
            msg["slo_ms"] = float(slo_ms)
        if deadline_ms is not None:
            msg["deadline_ms"] = float(deadline_ms)
        if idempotency_key is None and self.retry is not None:
            # retried submits MUST dedupe: without a key, a reply lost
            # after admission would double-execute on retry
            idempotency_key = uuid.uuid4().hex
        if idempotency_key is not None:
            msg["idempotency_key"] = idempotency_key
        payload = json.dumps(encode_value(msg),
                             separators=(",", ":")).encode()
        attempt = 0
        while True:
            try:
                raw = self.transport.request(payload)
                reply = decode_value(json.loads(raw.decode()))
            except TransportError:
                if (self.retry is None
                        or attempt + 1 >= self.retry.max_attempts):
                    raise
                self.retry.sleep(attempt)
                attempt += 1
                continue
            if reply.get("ok"):
                return LiveTicket(self, reply["ticket"])
            if reply.get("code") is None:
                raise RuntimeError(f"NDIF error: {reply.get('error')}")
            if (reply["code"] == "backpressure" and self.retry is not None
                    and attempt + 1 < self.retry.max_attempts):
                self.retry.sleep(attempt, reply.get("retry_after_ms"))
                attempt += 1
                continue
            raise AdmissionRefused(reply)

    def stats(self) -> dict:
        """The hosted engine's EngineStats snapshot (compiles, generations,
        merged-group sizes, padding waste, live front-door counters —
        queue depth, rejected submissions, stream chunks, per-ticket
        queue_wait / time_to_first_token records — and the fault-tolerance
        counters: faults_injected, engine_restarts, tickets_requeued,
        cancellations, deadline_evictions) for capacity planning."""
        return self._roundtrip(
            {"kind": "stats", "model": self.model_name}
        )["results"]

    def hidden_states(self, tokens, **extras):
        msg = {
            "kind": "hidden_states",
            "model": self.model_name,
            "batch": {"tokens": np.asarray(tokens), **extras},
        }
        return self._roundtrip(msg)["results"]["hidden"]

    # -------------------------------------------------------------- wires
    def _tracer_batch(self, tracer) -> dict:
        # model_args = (params, tokens, ...) — params never leave the server.
        args = tracer.model_args[1:]
        batch = {}
        if args:
            batch["tokens"] = np.asarray(args[0])
        for k, v in tracer.model_kwargs.items():
            batch[k] = np.asarray(v)
        return batch

    def _roundtrip(self, msg: dict, transport: Any | None = None) -> dict:
        payload = json.dumps(encode_value(msg), separators=(",", ":")).encode()
        attempt = 0
        while True:
            try:
                raw = (transport or self.transport).request(payload)
                break
            except TransportError:
                # safe to retry blindly: every kind routed through here is
                # idempotent — polls/streams are cursor reads against
                # channel history, stats/cancel re-apply harmlessly
                if (self.retry is None
                        or attempt + 1 >= self.retry.max_attempts):
                    raise
                self.retry.sleep(attempt)
                attempt += 1
        reply = decode_value(json.loads(raw.decode()))
        if not reply.get("ok"):
            raise RuntimeError(f"NDIF error: {reply.get('error')}")
        return reply
