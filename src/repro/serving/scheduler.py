"""Request scheduler: sequential and parallel co-tenancy.

The paper ships *sequential* co-tenancy (one queue per model instance,
Appendix D.2 — response time grows linearly with concurrent users) and
sketches *parallel* co-tenancy via batch grouping (Appendix B.2, future
work).  Both are implemented here; fig9 benchmarks them against each other.

Grouping rule for parallel mode: requests are batch-mergeable when they
share every non-batch input dim and dtype and use no ``.grad`` — the merger
(:mod:`repro.core.batching`) then rewrites getters/setters into row slices
and ONE forward serves the whole group.

Ragged lengths (padding-aware merging): for the declared ragged inputs
(``tokens``, ``src_embeds``) requests only need to land in the same LENGTH
BUCKET — lengths within ``pad_slack`` of each other merge; shorter requests
are right-padded to the group max and a per-request lengths record drives
position-aware unpadding of saves (see :mod:`repro.core.batching`) plus
sentinel-masked model execution, so results are identical to solo runs.
``pad_slack=0`` degenerates to the old exact-shape match.

Generation requests (``max_new_tokens`` set) merge the same way: groups
additionally require an equal step count, their graphs merge with the step
coordinate preserved, and ONE prefill + decode loop serves the whole group —
ragged prompts included (each row's last real token decodes as step 0 at its
own position; per-request rows split back out of tokens and saves).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any

import numpy as np

from repro.core.batching import merge_graphs, split_results
from repro.core.graph import ALL_STEPS, InterventionGraph

__all__ = ["Request", "Ticket", "CoTenantScheduler", "RAGGED_INPUTS"]

_ids = itertools.count()

# Model inputs whose axis 1 may differ across merged requests, and the
# batch key carrying per-row valid lengths for each.  Other 2D+ inputs
# (e.g. fixed-size image embeddings) still require an exact match.
RAGGED_INPUTS = {"tokens": "lengths", "src_embeds": "src_lengths"}


@dataclasses.dataclass
class Request:
    graph: InterventionGraph
    batch: dict  # model inputs; leading dim of each array = this user's rows
    # None => single interleaved forward; an int => generation request
    # (prefill + that many decode steps, graph nodes carry step coords).
    max_new_tokens: int | None = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))


@dataclasses.dataclass
class Ticket:
    request_id: int
    submit_time: float
    start_time: float | None = None
    finish_time: float | None = None
    result: dict | None = None
    error: str | None = None

    @property
    def response_time(self) -> float:
        return (self.finish_time or time.perf_counter()) - self.submit_time


def _merge_key(req: Request, pad_slack: int = 0) -> tuple | None:
    for n in req.graph.nodes:
        if n.op == "grad_get":
            return None  # grads never merge — sequential fallback
        if n.op == "tap_set" and n.step == ALL_STEPS:
            return None  # broadcast setters run solo (see merge_graphs)
    items = []
    for k in sorted(req.batch):
        v = np.asarray(req.batch[k])
        if v.ndim == 0:
            return None
        if k in RAGGED_INPUTS and v.ndim >= 2 and pad_slack > 0:
            # length-bucketed: lengths within one bucket merge (padding a
            # request wastes at most pad_slack positions per row)
            bucket = v.shape[1] // (pad_slack + 1)
            items.append((k, ("bucket", bucket) + v.shape[2:], str(v.dtype)))
        else:
            items.append((k, v.shape[1:], str(v.dtype)))
    if req.max_new_tokens is not None:
        t = req.batch.get("tokens")
        if t is not None and np.asarray(t).shape[1] == 1:
            # S == 1 decodes from an EMPTY cache (no prefill execution);
            # merged into a longer-prompt group it would get a zero-length
            # prefill instead of the solo path's clear error/eager init.
            return None
    # generation requests only merge with equal step counts
    return (req.max_new_tokens, tuple(items))


class CoTenantScheduler:
    def __init__(
        self,
        engine: Any,
        *,
        policy: str = "parallel",
        max_batch_rows: int = 64,
        pad_slack: int = 16,
    ) -> None:
        """``pad_slack`` bounds the wasted padding compute per merged row:
        requests whose ragged-input lengths fall in one bucket of width
        ``pad_slack + 1`` merge (0 = exact-length match only)."""
        assert policy in ("sequential", "parallel")
        assert pad_slack >= 0
        self.engine = engine
        self.policy = policy
        self.max_batch_rows = max_batch_rows
        self.pad_slack = pad_slack
        self.queue: list[tuple[Request, Ticket]] = []
        self.completed: list[Ticket] = []

    def submit(self, req: Request) -> Ticket:
        ticket = Ticket(req.request_id, submit_time=time.perf_counter())
        self.queue.append((req, ticket))
        return ticket

    # ------------------------------------------------------------- draining
    def drain(self) -> list[Ticket]:
        """Process the whole queue; returns finished tickets in order."""
        done: list[Ticket] = []
        while self.queue:
            if self.policy == "sequential":
                done.append(self._run_one(*self.queue.pop(0)))
            else:
                done.extend(self._run_group(self._take_group()))
        self.completed.extend(done)
        return done

    def _run_one(self, req: Request, ticket: Ticket) -> Ticket:
        ticket.start_time = time.perf_counter()
        try:
            if req.max_new_tokens is not None:
                res = self.engine.generate_interleaved(
                    req.graph, req.batch, req.max_new_tokens
                )
                # reserved keys win: "tokens"/"logits" always mean the
                # generated output, never a same-named user save
                ticket.result = {
                    **res.saves,
                    "tokens": np.asarray(res.tokens),
                    "logits": np.asarray(res.logits),
                }
            else:
                saves, _ = self.engine.execute(req.graph, req.batch)
                ticket.result = saves
        except Exception as e:  # surface per-request, keep serving
            ticket.error = f"{type(e).__name__}: {e}"
        ticket.finish_time = time.perf_counter()
        return ticket

    def _take_group(self) -> list[tuple[Request, Ticket]]:
        head_req, _ = self.queue[0]
        key = _merge_key(head_req, self.pad_slack)
        if key is None:
            return [self.queue.pop(0)]
        group = []
        rows = 0
        remaining = []
        for item in self.queue:
            req, _t = item
            b = int(np.asarray(next(iter(req.batch.values()))).shape[0])
            if (_merge_key(req, self.pad_slack) == key
                    and rows + b <= self.max_batch_rows):
                group.append(item)
                rows += b
            else:
                remaining.append(item)
        self.queue = remaining
        return group

    def _merge_batch(
        self, reqs: list[Request], sizes: list[int]
    ) -> tuple[dict, list[dict[str, int]] | None, int, int]:
        """Right-pad ragged inputs to the group max and concatenate rows.

        Returns ``(batch, tap_lengths, real_cells, padded_cells)`` where
        ``tap_lengths`` is the per-request record driving save unpadding
        (None when the group is shape-uniform).  Per-row valid-length arrays
        (``lengths`` / ``src_lengths``) are synthesized for the model unless
        the requests already carry them.
        """
        ragged_keys = [
            k for k in reqs[0].batch
            if k in RAGGED_INPUTS and np.asarray(reqs[0].batch[k]).ndim >= 2
        ]
        maxes = {
            k: max(int(np.asarray(r.batch[k]).shape[1]) for r in reqs)
            for k in ragged_keys
        }
        ragged = any(
            int(np.asarray(r.batch[k]).shape[1]) != maxes[k]
            for r in reqs for k in ragged_keys
        )
        batch = {}
        for k in reqs[0].batch:
            arrs = [np.asarray(r.batch[k]) for r in reqs]
            if k in maxes:
                arrs = [
                    np.pad(a, ((0, 0), (0, maxes[k] - a.shape[1]))
                           + ((0, 0),) * (a.ndim - 2))
                    for a in arrs
                ]
            batch[k] = np.concatenate(arrs)
        real = padded = 0
        for r, rows in zip(reqs, sizes):
            for k in ragged_keys:
                L = int(np.asarray(r.batch[k]).shape[1])
                real += rows * L
                padded += rows * (maxes[k] - L)
        tap_lengths = None
        if ragged:
            is_gen = reqs[0].max_new_tokens is not None
            tap_lengths = []
            for r in reqs:
                rec = {}
                for k in ragged_keys:
                    L = int(np.asarray(r.batch[k]).shape[1])
                    # generation prefill taps see the prompt MINUS the
                    # step-0 token, so prefill saves unpad to L - 1
                    rec[k] = L - 1 if (is_gen and k == "tokens") else L
                tap_lengths.append(rec)
            for k in ragged_keys:
                lk = RAGGED_INPUTS[k]
                if lk not in batch:
                    batch[lk] = np.concatenate([
                        np.full(rows, np.asarray(r.batch[k]).shape[1],
                                np.int32)
                        for r, rows in zip(reqs, sizes)
                    ])
        return batch, tap_lengths, real, padded

    def _run_group(self, group: list[tuple[Request, Ticket]]) -> list[Ticket]:
        if len(group) == 1:
            return [self._run_one(*group[0])]
        t0 = time.perf_counter()
        reqs = [r for r, _ in group]
        tickets = [t for _, t in group]
        for t in tickets:
            t.start_time = t0
        try:
            sizes = [
                int(np.asarray(next(iter(r.batch.values()))).shape[0])
                for r in reqs
            ]
            batch, tap_lengths, real, padded = self._merge_batch(reqs, sizes)
            merged = merge_graphs(
                [r.graph for r in reqs], sizes,
                lengths=tap_lengths,
                site_length_key=getattr(
                    self.engine.model, "site_length_key", None
                ),
            )
            self.engine.stats.record_group(len(group), padded, real)
            n_new = reqs[0].max_new_tokens
            if n_new is not None:
                res = self.engine.generate_interleaved(
                    merged.graph, batch, n_new
                )
                per_req = split_results(res.saves, merged)
                toks = np.asarray(res.tokens)
                logits = np.asarray(res.logits)
                for t, (start, size), saves_r in zip(
                    tickets, merged.row_slices, per_req
                ):
                    t.result = {
                        **saves_r,
                        "tokens": toks[start:start + size],
                        "logits": logits[start:start + size],
                    }
            else:
                saves, _ = self.engine.execute(merged.graph, batch)
                per_req = split_results(saves, merged)
                for t, res in zip(tickets, per_req):
                    t.result = res
        except Exception as e:
            for t in tickets:
                t.error = f"{type(e).__name__}: {e}"
        t1 = time.perf_counter()
        for t in tickets:
            t.finish_time = t1
        return tickets
