"""Request scheduler: sequential and parallel co-tenancy.

The paper ships *sequential* co-tenancy (one queue per model instance,
Appendix D.2 — response time grows linearly with concurrent users) and
sketches *parallel* co-tenancy via batch grouping (Appendix B.2, future
work).  Both are implemented here; fig9 benchmarks them against each other.

Grouping rule for parallel mode: requests are batch-mergeable when they
share every non-batch input dim and dtype and use no ``.grad`` — the merger
(:mod:`repro.core.batching`) then rewrites getters/setters into row slices
and ONE forward serves the whole group.

Generation requests (``max_new_tokens`` set) merge the same way: groups
additionally require an equal step count, their graphs merge with the step
coordinate preserved, and ONE prefill + decode loop serves the whole group
(per-request rows split back out of the generated tokens and saves).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any

import numpy as np

from repro.core.batching import merge_graphs, split_results
from repro.core.graph import ALL_STEPS, InterventionGraph

__all__ = ["Request", "Ticket", "CoTenantScheduler"]

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    graph: InterventionGraph
    batch: dict  # model inputs; leading dim of each array = this user's rows
    # None => single interleaved forward; an int => generation request
    # (prefill + that many decode steps, graph nodes carry step coords).
    max_new_tokens: int | None = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))


@dataclasses.dataclass
class Ticket:
    request_id: int
    submit_time: float
    start_time: float | None = None
    finish_time: float | None = None
    result: dict | None = None
    error: str | None = None

    @property
    def response_time(self) -> float:
        return (self.finish_time or time.perf_counter()) - self.submit_time


def _merge_key(req: Request) -> tuple | None:
    for n in req.graph.nodes:
        if n.op == "grad_get":
            return None  # grads never merge — sequential fallback
        if n.op == "tap_set" and n.step == ALL_STEPS:
            return None  # broadcast setters run solo (see merge_graphs)
    items = []
    for k in sorted(req.batch):
        v = np.asarray(req.batch[k])
        if v.ndim == 0:
            return None
        items.append((k, v.shape[1:], str(v.dtype)))
    # generation requests only merge with equal step counts
    return (req.max_new_tokens, tuple(items))


class CoTenantScheduler:
    def __init__(
        self,
        engine: Any,
        *,
        policy: str = "parallel",
        max_batch_rows: int = 64,
    ) -> None:
        assert policy in ("sequential", "parallel")
        self.engine = engine
        self.policy = policy
        self.max_batch_rows = max_batch_rows
        self.queue: list[tuple[Request, Ticket]] = []
        self.completed: list[Ticket] = []

    def submit(self, req: Request) -> Ticket:
        ticket = Ticket(req.request_id, submit_time=time.perf_counter())
        self.queue.append((req, ticket))
        return ticket

    # ------------------------------------------------------------- draining
    def drain(self) -> list[Ticket]:
        """Process the whole queue; returns finished tickets in order."""
        done: list[Ticket] = []
        while self.queue:
            if self.policy == "sequential":
                done.append(self._run_one(*self.queue.pop(0)))
            else:
                done.extend(self._run_group(self._take_group()))
        self.completed.extend(done)
        return done

    def _run_one(self, req: Request, ticket: Ticket) -> Ticket:
        ticket.start_time = time.perf_counter()
        try:
            if req.max_new_tokens is not None:
                res = self.engine.generate_interleaved(
                    req.graph, req.batch, req.max_new_tokens
                )
                # reserved keys win: "tokens"/"logits" always mean the
                # generated output, never a same-named user save
                ticket.result = {
                    **res.saves,
                    "tokens": np.asarray(res.tokens),
                    "logits": np.asarray(res.logits),
                }
            else:
                saves, _ = self.engine.execute(req.graph, req.batch)
                ticket.result = saves
        except Exception as e:  # surface per-request, keep serving
            ticket.error = f"{type(e).__name__}: {e}"
        ticket.finish_time = time.perf_counter()
        return ticket

    def _take_group(self) -> list[tuple[Request, Ticket]]:
        head_req, _ = self.queue[0]
        key = _merge_key(head_req)
        if key is None:
            return [self.queue.pop(0)]
        group = []
        rows = 0
        remaining = []
        for item in self.queue:
            req, _t = item
            b = int(np.asarray(next(iter(req.batch.values()))).shape[0])
            if _merge_key(req) == key and rows + b <= self.max_batch_rows:
                group.append(item)
                rows += b
            else:
                remaining.append(item)
        self.queue = remaining
        return group

    def _run_group(self, group: list[tuple[Request, Ticket]]) -> list[Ticket]:
        if len(group) == 1:
            return [self._run_one(*group[0])]
        t0 = time.perf_counter()
        reqs = [r for r, _ in group]
        tickets = [t for _, t in group]
        for t in tickets:
            t.start_time = t0
        try:
            sizes = [
                int(np.asarray(next(iter(r.batch.values()))).shape[0])
                for r in reqs
            ]
            merged = merge_graphs([r.graph for r in reqs], sizes)
            batch = {
                k: np.concatenate([np.asarray(r.batch[k]) for r in reqs])
                for k in reqs[0].batch
            }
            n_new = reqs[0].max_new_tokens
            if n_new is not None:
                res = self.engine.generate_interleaved(
                    merged.graph, batch, n_new
                )
                per_req = split_results(res.saves, merged)
                toks = np.asarray(res.tokens)
                logits = np.asarray(res.logits)
                for t, (start, size), saves_r in zip(
                    tickets, merged.row_slices, per_req
                ):
                    t.result = {
                        **saves_r,
                        "tokens": toks[start:start + size],
                        "logits": logits[start:start + size],
                    }
            else:
                saves, _ = self.engine.execute(merged.graph, batch)
                per_req = split_results(saves, merged)
                for t, res in zip(tickets, per_req):
                    t.result = res
        except Exception as e:
            for t in tickets:
                t.error = f"{type(e).__name__}: {e}"
        t1 = time.perf_counter()
        for t in tickets:
            t.finish_time = t1
        return tickets
