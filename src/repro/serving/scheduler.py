"""Request scheduler: sequential and parallel co-tenancy.

The paper ships *sequential* co-tenancy (one queue per model instance,
Appendix D.2 — response time grows linearly with concurrent users) and
sketches *parallel* co-tenancy via batch grouping (Appendix B.2, future
work).  Both are implemented here; fig9 benchmarks them against each other.

Grouping rule for parallel mode: requests are batch-mergeable when they
share every non-batch input dim and dtype and use no ``.grad`` — the merger
(:mod:`repro.core.batching`) then rewrites getters/setters into row slices
and ONE forward serves the whole group.

Ragged lengths (padding-aware merging): for the declared ragged inputs
(``tokens``, ``src_embeds``) requests only need to land in the same LENGTH
BUCKET — lengths within ``pad_slack`` of each other merge; shorter requests
are right-padded to the group max and a per-request lengths record drives
position-aware unpadding of saves (see :mod:`repro.core.batching`) plus
sentinel-masked model execution, so results are identical to solo runs.
``pad_slack=0`` degenerates to the old exact-shape match.

Generation requests (``max_new_tokens`` set) merge the same way: groups
additionally require an equal step count, their graphs merge with the step
coordinate preserved, and ONE prefill + decode loop serves the whole group —
ragged prompts included (each row's last real token decodes as step 0 at its
own position; per-request rows split back out of tokens and saves).

Continuous batching (``policy="continuous"``): generation requests are no
longer grouped per drain burst — the engine owns a persistent slot-table
decode loop (:class:`repro.core.generation.DecodeLoop`) and the scheduler
ADMITS requests into free slots at decode-step boundaries.  A request
arriving one step after another started decoding waits one step, not one
whole decode loop; rows retire independently (per-request
``max_new_tokens`` may differ) and their slots are immediately reusable.
Admission keeps the ``pad_slack`` bucketing for prefill merging — arrivals
in one length bucket share one prefill, padded to the bucket CEILING so
repeated admissions reuse one compiled prefill shape — and queueing is FIFO
within a bucket.  Single-forward traces still burst-merge between steps.

Group sizing is length-aware: both the burst grouper and continuous prefill
admission bound ``rows x padded_length`` by ``max_batch_cells`` (on top of
the ``max_batch_rows`` row cap); cap-split decisions are recorded in
``EngineStats``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any

import numpy as np

from repro.core.batching import (
    RAGGED_INPUTS,
    merge_graphs,
    merge_invoke_batches,
    split_results,
)
from repro.core.generation import SlotAllocationError
from repro.core.graph import ALL_STEPS, InterventionGraph

__all__ = ["Request", "Ticket", "CoTenantScheduler", "RAGGED_INPUTS"]

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    graph: InterventionGraph
    batch: dict  # model inputs; leading dim of each array = this user's rows
    # None => single interleaved forward; an int => generation request
    # (prefill + that many decode steps, graph nodes carry step coords).
    max_new_tokens: int | None = None
    # A multi-invoke trace lowered client-side: the graph already contains
    # per-invoke row slices, so it executes as-is — never re-merged with
    # co-tenant requests (a double merge would re-slice its slices).
    premerged: bool = False
    # tracer.stop(): truncate the forward after the last referenced site.
    # Runs solo (schedule truncation is per-request) on a compiled+cached
    # truncated program — the partial trace IS the jaxpr.
    stop: bool = False
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))


@dataclasses.dataclass
class Ticket:
    """Per-request lifecycle record.

    ``response_time`` is THIS request's submit -> finish span: under batched
    execution every ticket keeps its own ``submit_time`` (queue wait counts
    toward the request that waited) and gets its own ``finish_time`` — in
    continuous mode that is the moment ITS rows retire from the decode loop,
    not when the whole drain returns, so a short request co-resident with a
    long one reports the shorter latency.  ``start_time`` is when execution
    (or slot admission) actually began.
    """

    request_id: int
    submit_time: float
    start_time: float | None = None
    finish_time: float | None = None
    # when the request's FIRST decoded token (or, for single-forward
    # traces, its result) became available — the live front door stamps
    # this at the first streamed chunk, so time_to_first_token measures
    # what a streaming client actually observes
    first_token_time: float | None = None
    result: dict | None = None
    error: str | None = None
    # machine-readable failure class riding next to ``error`` on the wire
    # ("deadline" | "cancelled" | "engine_restart" | "engine_failed" |
    # "closed" | ...); None for ordinary per-request execution failures
    error_code: str | None = None
    # admission attempts bounced by slot/page exhaustion; capped by the
    # scheduler so a request that will never fit terminates with a
    # structured deficit instead of requeue-spinning forever
    alloc_retries: int = 0

    @property
    def response_time(self) -> float:
        return (self.finish_time or time.perf_counter()) - self.submit_time

    @property
    def queue_wait(self) -> float:
        """Time spent queued before execution/admission began."""
        return (self.start_time or self.submit_time) - self.submit_time

    @property
    def time_to_first_token(self) -> float | None:
        """Submit -> first output span (None until something was emitted;
        falls back to the finish time for batch-style completions)."""
        t = self.first_token_time or self.finish_time
        return None if t is None else t - self.submit_time


def _merge_key(req: Request, pad_slack: int = 0) -> tuple | None:
    if req.premerged or req.stop:
        # premerged graphs already encode their row structure; stopped
        # traces truncate the site schedule per-request — both run solo
        return None
    for n in req.graph.nodes:
        if n.op == "grad_get":
            # merge_graphs CAN merge grads (shared grad_get + summed
            # per-request losses), but the scheduler keeps them solo:
            # co-tenant grad batching is a ROADMAP residual.
            return None
        if n.op == "tap_set" and n.step == ALL_STEPS:
            return None  # broadcast setters run solo (see merge_graphs)
    items = []
    for k in sorted(req.batch):
        v = np.asarray(req.batch[k])
        if v.ndim == 0:
            return None
        if k in RAGGED_INPUTS and v.ndim >= 2 and pad_slack > 0:
            # length-bucketed: lengths within one bucket merge (padding a
            # request wastes at most pad_slack positions per row)
            bucket = v.shape[1] // (pad_slack + 1)
            items.append((k, ("bucket", bucket) + v.shape[2:], str(v.dtype)))
        else:
            items.append((k, v.shape[1:], str(v.dtype)))
    if req.max_new_tokens is not None:
        t = req.batch.get("tokens")
        if t is not None and np.asarray(t).shape[1] == 1:
            # S == 1 decodes from an EMPTY cache (no prefill execution);
            # merged into a longer-prompt group it would get a zero-length
            # prefill instead of the solo path's clear error/eager init.
            return None
    # generation requests only merge with equal step counts
    return (req.max_new_tokens, tuple(items))


def _bucket_ceiling(width: int, pad_slack: int) -> int:
    """Top width of the length bucket containing ``width`` — admissions pad
    to this so every request in a bucket shares one compiled prefill."""
    return (width // (pad_slack + 1) + 1) * (pad_slack + 1) - 1


def _admit_key(req: Request, pad_slack: int = 0) -> tuple | None:
    """Continuous-admission compatibility key: requests with equal keys may
    share ONE prefill when admitted at the same step boundary.  Unlike
    ``_merge_key``, ``max_new_tokens`` is NOT part of the key (rows retire
    independently) and ``all_steps()`` setters are fine (per-execution
    slices are merged, so the broadcast has already been expanded).
    ``None`` means: admit alone (S == 1 empty-cache init) or fall back to a
    solo run (grads, scalar inputs)."""
    for n in req.graph.nodes:
        if n.op == "grad_get":
            # .grad now rides the fused generation scan — but solo: the
            # solo fallback path runs run_generation(fused=True), which
            # compiles the grad step into the lax.scan body.  Co-tenant
            # grad admission is a ROADMAP residual.
            return None
    t = req.batch.get("tokens")
    if t is None or np.asarray(t).ndim < 2 or np.asarray(t).shape[1] == 1:
        return None
    items = []
    for k in sorted(req.batch):
        v = np.asarray(req.batch[k])
        if v.ndim == 0:
            return None
        if k in RAGGED_INPUTS and v.ndim >= 2:
            bucket = v.shape[1] // (pad_slack + 1)
            items.append((k, ("bucket", bucket) + v.shape[2:], str(v.dtype)))
        else:
            items.append((k, v.shape[1:], str(v.dtype)))
    return tuple(items)


#: Reserved result key carrying per-request ``log()`` values over the wire
#: as ``[(node_id, value), ...]`` — the tracer pops it back into
#: ``tracer.logs`` client-side, so remote logs survive the roundtrip.
LOGS_KEY = "__logs__"


def _attach_logs(result: dict, logs) -> None:
    """Attach a request's logged values to its wire result (only when any
    exist, so log-free results keep their exact historical key set)."""
    if logs:
        result[LOGS_KEY] = [
            (int(nid), np.asarray(val)) for nid, val in logs
        ]


def _req_rows(req: Request) -> int:
    if not req.batch:
        raise ValueError("request batch has no model inputs")
    return int(np.asarray(next(iter(req.batch.values()))).shape[0])


def _req_width(req: Request) -> int:
    """Max ragged-input width (the padded-length term of the cost model)."""
    w = 1
    for k in RAGGED_INPUTS:
        v = req.batch.get(k)
        if v is not None and np.asarray(v).ndim >= 2:
            w = max(w, int(np.asarray(v).shape[1]))
    return w


class CoTenantScheduler:
    def __init__(
        self,
        engine: Any,
        *,
        policy: str = "parallel",
        max_batch_rows: int = 64,
        pad_slack: int = 16,
        max_batch_cells: int = 8192,
        num_slots: int = 8,
        slot_max_len: int = 160,
        alloc_retry_cap: int = 100,
    ) -> None:
        """``pad_slack`` bounds the wasted padding compute per merged row:
        requests whose ragged-input lengths fall in one bucket of width
        ``pad_slack + 1`` merge (0 = exact-length match only).
        ``max_batch_cells`` bounds ``rows x padded_length`` per merged group
        (length-aware sizing; ``max_batch_rows`` alone would let many long
        rows form an oversized forward).  ``num_slots``/``slot_max_len``
        size the continuous-batching slot table (policy="continuous")."""
        assert policy in ("sequential", "parallel", "continuous")
        assert pad_slack >= 0
        self.engine = engine
        self.policy = policy
        self.max_batch_rows = max_batch_rows
        self.pad_slack = pad_slack
        self.max_batch_cells = max_batch_cells
        self.num_slots = num_slots
        self.slot_max_len = slot_max_len
        # step boundaries one ticket may bounce on slot/page exhaustion
        # before its admission fails with the allocator's deficit
        self.alloc_retry_cap = int(alloc_retry_cap)
        self.queue: list[tuple[Request, Ticket]] = []
        self.completed: list[Ticket] = []
        self._loop = None  # lazily-started persistent DecodeLoop
        self._slot_tickets: dict[Any, Ticket] = {}

    @property
    def loop(self):
        """The persistent slot-table decode loop (continuous policy)."""
        if self._loop is None:
            self._loop = self.engine.start_decode_loop(
                self.num_slots, self.slot_max_len
            )
        return self._loop

    def submit(self, req: Request) -> Ticket:
        ticket = Ticket(req.request_id, submit_time=time.perf_counter())
        self.queue.append((req, ticket))
        return ticket

    # ------------------------------------------------------------- draining
    def drain(self) -> list[Ticket]:
        """Process the whole queue; returns finished tickets in order."""
        done: list[Ticket] = []
        if self.policy == "continuous":
            done = self._drain_continuous()
            self.completed.extend(done)
            return done
        while self.queue:
            if self.policy == "sequential":
                done.append(self._run_one(*self.queue.pop(0)))
            else:
                done.extend(self._run_group(self._take_group()))
        self.completed.extend(done)
        return done

    def _run_one(self, req: Request, ticket: Ticket) -> Ticket:
        ticket.start_time = time.perf_counter()
        try:
            if req.max_new_tokens is not None:
                res = self.engine.generate_interleaved(
                    req.graph, req.batch, req.max_new_tokens
                )
                # reserved keys win: "tokens"/"logits" always mean the
                # generated output, never a same-named user save
                ticket.result = {
                    **res.saves,
                    "tokens": np.asarray(res.tokens),
                    "logits": np.asarray(res.logits),
                }
                _attach_logs(ticket.result, res.logs)
            else:
                saves, _, logs = self.engine.execute_logged(
                    req.graph, req.batch, stop=req.stop
                )
                ticket.result = dict(saves)
                _attach_logs(ticket.result, logs)
        except Exception as e:  # surface per-request, keep serving
            ticket.error = f"{type(e).__name__}: {e}"
        ticket.finish_time = time.perf_counter()
        return ticket

    def _take_group(self) -> list[tuple[Request, Ticket]]:
        head_req, _ = self.queue[0]
        key = _merge_key(head_req, self.pad_slack)
        if key is None:
            return [self.queue.pop(0)]
        group = []
        rows = 0
        width = 0  # group's padded length (the cost-model term)
        remaining = []
        for item in self.queue:
            req, _t = item
            if _merge_key(req, self.pad_slack) != key:
                remaining.append(item)
                continue
            b = _req_rows(req)
            w = max(width, _req_width(req))
            if group and rows + b > self.max_batch_rows:
                self.engine.stats.record_cap_split("rows")
                remaining.append(item)
                continue
            if group and (rows + b) * w > self.max_batch_cells:
                # length-aware sizing: admitting this request would pad the
                # whole group past the compute budget — split instead
                self.engine.stats.record_cap_split("cells")
                remaining.append(item)
                continue
            group.append(item)
            rows += b
            width = w
        self.queue = remaining
        return group

    def _merge_batch(
        self, reqs: list[Request]
    ) -> tuple[dict, list[dict[str, int]] | None, int, int]:
        """Right-pad ragged inputs to the group max and concatenate rows.

        Thin wrapper over :func:`repro.core.batching.merge_invoke_batches`
        (the same lowering the multi-invoke tracer uses client-side).
        Returns ``(batch, tap_lengths, real_cells, padded_cells)`` where
        ``tap_lengths`` is the per-request record driving save unpadding
        (None when the group is shape-uniform).  Per-row valid-length arrays
        (``lengths`` / ``src_lengths``) are synthesized for the model unless
        the requests already carry them.
        """
        batch, tap_lengths, _sizes, real, padded = merge_invoke_batches(
            [r.batch for r in reqs],
            generation=reqs[0].max_new_tokens is not None,
        )
        return batch, tap_lengths, real, padded

    def _run_group(self, group: list[tuple[Request, Ticket]]) -> list[Ticket]:
        if len(group) == 1:
            return [self._run_one(*group[0])]
        t0 = time.perf_counter()
        reqs = [r for r, _ in group]
        tickets = [t for _, t in group]
        for t in tickets:
            t.start_time = t0
        try:
            sizes = [
                int(np.asarray(next(iter(r.batch.values()))).shape[0])
                for r in reqs
            ]
            batch, tap_lengths, real, padded = self._merge_batch(reqs)
            merged = merge_graphs(
                [r.graph for r in reqs], sizes,
                lengths=tap_lengths,
                site_length_key=getattr(
                    self.engine.model, "site_length_key", None
                ),
            )
            self.engine.stats.record_group(len(group), padded, real)
            n_new = reqs[0].max_new_tokens
            if n_new is not None:
                res = self.engine.generate_interleaved(
                    merged.graph, batch, n_new
                )
                per_req = split_results(res.saves, merged)
                toks = np.asarray(res.tokens)
                logits = np.asarray(res.logits)
                for i, (t, (start, size), saves_r) in enumerate(zip(
                    tickets, merged.row_slices, per_req
                )):
                    t.result = {
                        **saves_r,
                        "tokens": toks[start:start + size],
                        "logits": logits[start:start + size],
                    }
                    # logs attributed by merged-graph node-id segment so a
                    # ticket never sees a co-tenant's logged values
                    _attach_logs(t.result, [
                        e for e in res.logs if merged.owner_of(e[0]) == i
                    ])
                    t.finish_time = time.perf_counter()
            else:
                saves, _, logs = self.engine.execute_logged(
                    merged.graph, batch
                )
                per_req = split_results(saves, merged)
                for i, (t, res) in enumerate(zip(tickets, per_req)):
                    t.result = dict(res)
                    _attach_logs(t.result, [
                        e for e in logs if merged.owner_of(e[0]) == i
                    ])
                    t.finish_time = time.perf_counter()
        except Exception as e:
            for t in tickets:
                t.error = f"{type(e).__name__}: {e}"
        for t in tickets:
            if t.finish_time is None:
                t.finish_time = time.perf_counter()
        return tickets

    # ------------------------------------------------- continuous batching
    def _drain_continuous(self) -> list[Ticket]:
        """Drive the persistent decode loop until queue and slots are empty.

        Each iteration is one admission/retirement boundary: single-forward
        traces burst-merge (they have no loop to join), queued generation
        requests are admitted into free slots (FIFO within a length bucket,
        arrivals in one bucket sharing one prefill), then the loop advances
        to the next retirement — ONE fused ``lax.scan`` dispatch for
        step-uniform graphs, per-step eager execution otherwise — and
        retired requests get their tickets finalized immediately.
        (:meth:`pump` stays single-step: a live driver interleaves arrivals
        with the loop, so its boundary is every step.)
        """
        loop = self.loop
        done: list[Ticket] = []
        while self.queue or loop.resident:
            self._serve_single_forwards(done)
            self._admit_arrivals(loop, done)
            if loop.resident:
                # After admission, anything still queued is waiting for
                # slots — the next boundary is the next RETIREMENT, so the
                # whole stretch until then fuses into one scan dispatch
                # (step-uniform graphs; eager fallback otherwise).
                for sr in loop.step_fused(loop.fusable_steps()):
                    done.append(self._finish_slot(sr))
        return done

    def _serve_single_forwards(self, done: list[Ticket]) -> None:
        """Single-forward traces have no decode loop to join: burst-merge
        them between decode steps, exactly as in parallel policy."""
        nongen = [it for it in self.queue if it[0].max_new_tokens is None]
        if not nongen:
            return
        saved = [it for it in self.queue if it[0].max_new_tokens is not None]
        self.queue = nongen
        while self.queue:
            done.extend(self._run_group(self._take_group()))
        self.queue = saved

    def pump(self) -> list[Ticket]:
        """One decode-step boundary (benchmark/driver hook): admit whatever
        fits, advance the loop one step, finalize retirements.  Unlike
        :meth:`drain` this returns after a single step so a driver can
        interleave arrivals with the running loop."""
        assert self.policy == "continuous"
        loop = self.loop
        done: list[Ticket] = []
        self._serve_single_forwards(done)
        self._admit_arrivals(loop, done)
        if loop.resident:
            for sr in loop.step():
                done.append(self._finish_slot(sr))
        self.completed.extend(done)
        return done

    def _finish_slot(self, sr) -> Ticket:
        ticket = self._slot_tickets.pop(sr.request_id)
        if sr.error is not None:
            # evicted by a step-time failure of its own graph — surface
            # per-request, co-tenants keep decoding
            ticket.error = sr.error
            ticket.error_code = sr.error_code
        else:
            res = sr.result()
            ticket.result = {
                **res.saves,
                "tokens": np.asarray(res.tokens),
                "logits": np.asarray(res.logits),
            }
            _attach_logs(ticket.result, res.logs)
        # per-request accounting: THIS request's rows retired now, even if
        # co-tenants keep decoding
        ticket.finish_time = time.perf_counter()
        return ticket

    def _admit_arrivals(self, loop, done: list[Ticket]) -> None:
        """Admit queued generation requests into free slots, FIFO within
        each length bucket; same-boundary arrivals of one bucket share a
        single prefill padded to the bucket ceiling."""
        queue, self.queue = self.queue, []
        # rest carries (original queue index, item) so requeues — including
        # a whole plan bounced by slot fragmentation — restore SUBMIT order
        # within each bucket, not admission-attempt order
        rest: list[tuple[int, tuple[Request, Ticket]]] = []
        free = loop.free_rows()
        # admit-key -> [(idx, (req, ticket)), ...] planned for this boundary
        plans: dict[tuple, list[tuple[int, tuple[Request, Ticket]]]] = {}
        plan_rows: dict[tuple, int] = {}
        plan_pad: dict[tuple, int] = {}   # tokens bucket ceiling (pad target)
        plan_cost: dict[tuple, int] = {}  # widest ragged input (cells model)
        blocked: set[tuple] = set()
        order: list[tuple] = []

        for idx, item in enumerate(queue):
            req, ticket = item
            if req.max_new_tokens is None:
                rest.append((idx, item))  # single-forward: caller handles
                continue
            try:
                rows = _req_rows(req)
                key = _admit_key(req, self.pad_slack)
            except Exception as e:  # malformed batch: fail THIS ticket only
                ticket.finish_time = time.perf_counter()
                ticket.error = f"{type(e).__name__}: {e}"
                done.append(ticket)
                continue
            if not self._preflight_admit(loop, req, ticket, done):
                continue  # rejected statically: ZERO model forwards spent
            t = np.asarray(req.batch.get("tokens", np.zeros((1, 1))))
            tw = int(t.shape[1]) if t.ndim >= 2 else 1
            # the bucket ceiling the PROMPT pads to (cache-length term);
            # the cells cost below still counts every ragged input's width
            ceil = _bucket_ceiling(tw, self.pad_slack)
            if rows > loop.num_slots or (
                (ceil - 1 if tw > 1 else 0) + req.max_new_tokens
                > loop.max_len
            ):
                # cannot ever fit the slot table — classic solo fallback
                done.append(self._run_one(req, ticket))
                continue
            if getattr(loop, "paged", False):
                # pages-aware never-fits: a request whose LIFETIME page
                # need exceeds the whole pool would requeue to the retry
                # cap and fail — serve it solo instead
                lens = req.batch.get("lengths")
                if lens is not None:
                    need = sum(
                        loop.request_page_need(int(L), req.max_new_tokens)
                        for L in np.asarray(lens).reshape(-1)
                    )
                else:
                    need = rows * loop.request_page_need(
                        tw, req.max_new_tokens
                    )
                if need > loop.usable_pages():
                    done.append(self._run_one(req, ticket))
                    continue
            if key is None:
                # S == 1 / unbucketable: admit alone (empty-cache init) as
                # its OWN plan so slot allocation happens strictly in plan
                # order — a later solo arrival can't claim rows promised to
                # an earlier bucketed plan
                if rows > free:
                    rest.append((idx, item))
                    continue
                solo_key = ("__solo__", idx)
                plans[solo_key] = [(idx, item)]
                plan_rows[solo_key] = rows
                plan_pad[solo_key] = None
                plan_cost[solo_key] = rows
                order.append(solo_key)
                free -= rows
                continue
            if key in blocked:
                rest.append((idx, item))  # FIFO in bucket: don't overtake
                continue
            if rows > free:
                blocked.add(key)
                rest.append((idx, item))
                continue
            cur = plans.get(key)
            cost_w = max(ceil, _req_width(req))
            if cur is not None:
                new_rows = plan_rows[key] + rows
                new_cost = max(plan_cost[key], cost_w)
                if new_rows * new_cost > self.max_batch_cells:
                    self.engine.stats.record_cap_split("cells")
                    blocked.add(key)
                    rest.append((idx, item))
                    continue
                cur.append((idx, item))
                plan_rows[key] = new_rows
                plan_cost[key] = new_cost
            else:
                plans[key] = [(idx, item)]
                plan_rows[key] = rows
                plan_pad[key] = ceil
                plan_cost[key] = cost_w
                order.append(key)
            free -= rows

        for key in order:
            self._admit_plan(loop, plans[key], plan_pad[key], rest, done)
        # restore submit order for everything that did not admit
        rest.sort(key=lambda pair: pair[0])
        self.queue = [item for _, item in rest] + self.queue

    def _preflight_admit(self, loop, req, ticket, done) -> bool:
        """Layer-3 admission preflight: a statically-broken graph fails its
        ticket here, before any prefill/decode executes — the old path
        discovered these at step time and evicted the offender mid-loop
        (``_isolate_offenders``, now the fallback for what statics cannot
        see)."""
        from repro.core import analysis

        if analysis.preflight_mode() != "enforce":
            return True
        if req.premerged or not req.graph.nodes:
            return True  # premerged graphs were preflighted at lowering
        try:
            report = self.engine.preflight_generation(
                req.graph,
                req.batch,
                req.max_new_tokens,
                max_len=getattr(loop, "max_len", None),
            )
        except Exception:
            return True  # analyzer trouble must never block admission
        if report.ok():
            return True
        ticket.finish_time = time.perf_counter()
        ticket.error = "preflight rejected: " + "; ".join(
            d.format() for d in report.errors()
        )
        done.append(ticket)
        return False

    def _admit_plan(self, loop, plan, pad_to, rest, done) -> bool:
        """Admit one prefill group (``plan`` is [(queue_idx, (req,
        ticket)), ...]); on fragmentation put it back at its submit order,
        on a per-request validation error fail that ticket only."""
        t0 = time.perf_counter()
        try:
            srs = loop.admit_group(
                [(req.graph, req.batch, req.max_new_tokens, req.request_id)
                 for _, (req, _t) in plan],
                pad_to=pad_to,
            )
        except SlotAllocationError as e:
            # rows/pages genuinely exhausted right now: requeue for the
            # next step boundary (capacity frees as co-tenants retire),
            # but CAP the retries — a ticket that keeps losing the race
            # terminates with the allocator's structured deficit instead
            # of spinning in the queue forever
            stats = getattr(self.engine, "stats", None)
            for _idx, (req, ticket) in plan:
                ticket.alloc_retries += 1
                if stats is not None and hasattr(stats,
                                                 "record_alloc_retry"):
                    stats.record_alloc_retry()
                if ticket.alloc_retries >= self.alloc_retry_cap:
                    ticket.start_time = t0
                    ticket.finish_time = time.perf_counter()
                    ticket.error = (
                        f"admission failed after {ticket.alloc_retries} "
                        f"allocation retries: {e.deficit()}"
                    )
                    done.append(ticket)
                else:
                    rest.append((_idx, (req, ticket)))
            return False
        except Exception as e:
            if len(plan) == 1:
                _idx, (req, ticket) = plan[0]  # surface per-request
                ticket.start_time = t0
                ticket.finish_time = time.perf_counter()
                ticket.error = f"{type(e).__name__}: {e}"
                done.append(ticket)
                return False
            # isolate the failing request; valid ones still admit
            for entry in plan:
                self._admit_plan(loop, [entry], pad_to, rest, done)
            return True
        for (_idx, (req, ticket)), sr in zip(plan, srs):
            ticket.start_time = t0
            self._slot_tickets[sr.request_id] = ticket
        return True
