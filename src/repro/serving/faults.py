"""Deterministic fault injection for the serving stack.

Operational reliability — not raw speed — is the gating concern for
running an NDIF-style fabric in production (the eDIF feasibility study).
This module makes failure a FIRST-CLASS, reproducible input: named fault
points are instrumented at the seams where a real deployment breaks

  ``transport.send``    the request is lost before the server sees it
  ``transport.recv``    the reply is lost after the server processed it
  ``prefill.dispatch``  admission-time prefill execution
  ``decode.step``       a decode window dispatch (engine crash surface)
  ``fused.compile``     building a fused-window executable
  ``page.alloc``        KV page-pool allocation (exhaustion bursts)
  ``engine.tick``       the front door's engine-thread loop body

and a :class:`FaultPlan` decides — deterministically, from a seed —
which hits of which points fire what: an injected exception type, a
latency spike, or both.  The same plan over the same workload produces
the same fault sequence, so chaos runs (benchmarks/chaos_serving.py)
are replayable bit-for-bit and recovery assertions are meaningful.

Zero overhead when disabled: every instrumented site calls :func:`fire`,
which is a single module-global ``None`` check until a plan is armed.
The ``REPRO_FAULTS`` environment variable (default ``off``) gates
persistent arming via :func:`install` — production code cannot be
fault-injected by accident; tests and the chaos harness use the
:func:`inject` context manager, an explicit, scoped, always-restored
opt-in that needs no environment mutation.

Schedules (per :class:`FaultSpec`):

  * ``nth=N``            fire on the Nth hit of the point (1-based);
  * ``nth=N, every=M``   fire on hit N and every Mth hit after it;
  * ``every=M``          fire on every Mth hit;
  * ``p=q``              fire each hit with seeded probability q —
                         decisions are drawn from a per-spec
                         ``np.random.default_rng([seed, spec_index])``
                         stream in hit order, so they depend only on the
                         hit sequence, never on wall clock or thread
                         interleaving;
  * ``max_fires``        cap on total fires (default 1; ``None`` = no cap);
  * ``delay_s``          latency spike before (or instead of) the raise —
                         ``error=None`` makes the spec a pure stall.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from typing import Any, Callable

import numpy as np

__all__ = [
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "POINTS",
    "active",
    "enabled",
    "fire",
    "inject",
    "install",
    "uninstall",
]

#: The named fault points instrumented across the serving stack.
POINTS = (
    "transport.send",
    "transport.recv",
    "prefill.dispatch",
    "decode.step",
    "fused.compile",
    "page.alloc",
    "engine.tick",
)


class FaultError(RuntimeError):
    """Default injected exception — unambiguously synthetic in tracebacks."""


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault at one named point.  See the module docstring
    for schedule semantics; exactly one of ``nth``/``every``/``p`` drives
    the schedule (``nth`` + ``every`` combine into nth-then-every-Mth)."""

    point: str
    nth: int | None = None
    every: int | None = None
    p: float | None = None
    max_fires: int | None = 1
    error: Callable[[str], BaseException] | None = FaultError
    message: str = ""
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.point not in POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r} (known: {POINTS})"
            )
        if self.nth is None and self.every is None and self.p is None:
            raise ValueError(
                f"spec for {self.point!r} has no schedule: set nth, every "
                "or p"
            )

    def _due(self, hit: int, draw: float | None) -> bool:
        """Does this spec fire on the ``hit``-th hit of its point?"""
        if self.p is not None:
            return draw is not None and draw < float(self.p)
        if self.nth is not None:
            if hit < self.nth:
                return False
            if hit == self.nth:
                return True
            return (self.every is not None
                    and (hit - self.nth) % self.every == 0)
        return hit % self.every == 0


class FaultPlan:
    """A seeded set of :class:`FaultSpec`s plus its runtime counters.

    Thread-safe: hits arrive from client threads (transport points) and
    the engine thread (everything else) concurrently.  Probability draws
    come from per-spec seeded streams consumed in hit order, so the fault
    sequence is a pure function of (seed, per-point hit counts).

    ``stats`` may be an :class:`~repro.serving.engine.EngineStats`; every
    fire then lands in its ``faults_injected`` counter so the fault load
    shows up in the ``stats`` wire kind next to the recovery counters.
    """

    def __init__(self, specs: list[FaultSpec], *, seed: int = 0,
                 stats: Any = None) -> None:
        self.specs = list(specs)
        self.seed = int(seed)
        self.stats = stats
        self._lock = threading.Lock()
        self.hits: dict[str, int] = {}
        self.fired: dict[str, int] = {}
        self._spec_fires = [0] * len(self.specs)
        self._rngs = [
            np.random.default_rng([self.seed, i])
            for i in range(len(self.specs))
        ]

    # ------------------------------------------------------------- firing
    def fire(self, point: str) -> None:
        """One hit of ``point``: decide under the lock, stall/raise outside
        it (an injected latency spike must not serialize other threads'
        fault decisions)."""
        delay = 0.0
        err: BaseException | None = None
        with self._lock:
            hit = self.hits.get(point, 0) + 1
            self.hits[point] = hit
            for i, spec in enumerate(self.specs):
                if spec.point != point:
                    continue
                draw = float(self._rngs[i].random()) if spec.p is not None \
                    else None
                if spec.max_fires is not None \
                        and self._spec_fires[i] >= spec.max_fires:
                    continue
                if not spec._due(hit, draw):
                    continue
                self._spec_fires[i] += 1
                self.fired[point] = self.fired.get(point, 0) + 1
                delay = max(delay, spec.delay_s)
                if spec.error is not None and err is None:
                    msg = spec.message or (
                        f"injected fault at {point} (hit {hit})"
                    )
                    err = spec.error(msg)
                if self.stats is not None and hasattr(
                        self.stats, "record_fault_injected"):
                    self.stats.record_fault_injected(point)
        if delay > 0.0:
            time.sleep(delay)
        if err is not None:
            raise err

    # ----------------------------------------------------------- counters
    def fires(self) -> int:
        with self._lock:
            return sum(self.fired.values())

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "seed": self.seed,
                "hits": dict(self.hits),
                "fired": dict(self.fired),
                "total_fired": sum(self.fired.values()),
            }


# ---------------------------------------------------------------- arming
_PLAN: FaultPlan | None = None
_ARM_LOCK = threading.Lock()


def enabled() -> bool:
    """Whether ``REPRO_FAULTS`` permits persistent arming (default off)."""
    return os.environ.get("REPRO_FAULTS", "off").lower() not in (
        "off", "0", "false", ""
    )


def fire(point: str) -> None:
    """Instrumented-site hook: a no-op ``None`` check unless a plan is
    armed — the whole fault plane costs one global read when disabled."""
    plan = _PLAN
    if plan is not None:
        plan.fire(point)


def active() -> FaultPlan | None:
    return _PLAN


def install(plan: FaultPlan) -> None:
    """Persistently arm a plan.  Refused unless ``REPRO_FAULTS`` is set
    (e.g. ``on``): an unset environment means production semantics, and
    production must not be fault-injectable by a stray code path.  Scoped
    callers (tests, the chaos harness) should prefer :func:`inject`."""
    if not enabled():
        raise RuntimeError(
            "fault injection is disabled (REPRO_FAULTS=off); set "
            "REPRO_FAULTS=on or use faults.inject(...) for a scoped plan"
        )
    global _PLAN
    with _ARM_LOCK:
        _PLAN = plan


def uninstall() -> None:
    global _PLAN
    with _ARM_LOCK:
        _PLAN = None


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Scoped arming: the plan is live inside the ``with`` body and ALWAYS
    disarmed on exit, regardless of how the body leaves.  This is the
    explicit opt-in path — it works with ``REPRO_FAULTS=off`` because the
    call site itself is the consent."""
    global _PLAN
    with _ARM_LOCK:
        prev, _PLAN = _PLAN, plan
    try:
        yield plan
    finally:
        with _ARM_LOCK:
            _PLAN = prev
