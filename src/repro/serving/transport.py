"""Transport abstraction with byte accounting and modeled link bandwidth.

The wire format is what the paper standardizes; sockets are incidental.
``LoopbackTransport`` runs the server in-process but meters every byte both
ways and can model a network bandwidth (the paper's Petals comparison ran on
a ~60 MB/s link), exposing ``modeled_transfer_seconds`` so benchmarks can
report transfer cost without real NICs.

Live serving additions: metering is lock-guarded (the front door serves
many client THREADS over one transport — unsynchronized ``+=`` would drop
counts under contention) and :meth:`LoopbackTransport.session` opens a
multi-message :class:`TransportSession` for streaming conversations —
one submit, many polls — that meters into its own stats AND the parent
transport's, so per-conversation byte accounting coexists with the
door-wide totals.

Fault tolerance: the dispatch path carries the two transport fault
points (``transport.send`` fires BEFORE the handler — the request is
lost and the server never saw it; ``transport.recv`` fires AFTER — the
reply is lost although the server fully processed the message, the
AMBIGUOUS failure mode that motivates idempotency keys).  Both surface
as :class:`TransportError`, the exception class the client-side
``RetryPolicy`` treats as retryable.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from repro.serving import faults

__all__ = ["LoopbackTransport", "TransportError", "TransportSession",
           "TransportStats"]


class TransportError(RuntimeError):
    """A message was lost in flight (either direction).  Retryable: the
    client cannot tell whether the server processed the request, so
    retried submits must carry an idempotency key."""


class TransportStats:
    """Byte/request counters; all mutation goes through :meth:`record`
    under the owning transport's lock."""

    def __init__(self) -> None:
        self.requests = 0
        self.bytes_sent = 0
        self.bytes_received = 0

    def record(self, sent: int, received: int) -> None:
        self.requests += 1
        self.bytes_sent += sent
        self.bytes_received += received

    def modeled_transfer_seconds(self, bandwidth_bytes_per_s: float) -> float:
        return (self.bytes_sent + self.bytes_received) / bandwidth_bytes_per_s


class TransportSession:
    """A multi-message conversation over one transport (live streaming:
    one submit then repeated poll/stream messages share the session).
    Byte metering lands in ``self.stats`` and the parent's totals."""

    def __init__(self, parent: "LoopbackTransport") -> None:
        self._parent = parent
        self.stats = TransportStats()
        self.closed = False

    def request(self, payload: bytes) -> bytes:
        if self.closed:
            raise RuntimeError("transport session is closed")
        reply = self._parent._dispatch(payload, extra=self.stats)
        return reply

    def close(self) -> None:
        self.closed = True

    def __enter__(self) -> "TransportSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LoopbackTransport:
    def __init__(
        self,
        handler: Callable[[bytes], bytes],
        *,
        bandwidth_bytes_per_s: float | None = 60e6,
    ) -> None:
        self.handler = handler
        self.bandwidth = bandwidth_bytes_per_s
        self.stats = TransportStats()
        # one lock guards ALL metering through this transport (parent and
        # session stats alike): concurrent client threads are the live
        # front door's normal operating mode
        self._lock = threading.Lock()

    def _dispatch(self, payload: bytes,
                  extra: TransportStats | None = None) -> bytes:
        # fault point: the request never reaches the server (nothing was
        # processed — a plain retry is always safe)
        faults.fire("transport.send")
        # the handler itself runs outside the lock — it may block (a
        # streaming poll waits on the engine thread) and other client
        # threads must keep flowing
        reply = self.handler(payload)
        # fault point: the reply is lost AFTER the server processed the
        # message — the ambiguous case idempotency keys exist for
        faults.fire("transport.recv")
        with self._lock:
            self.stats.record(len(payload), len(reply))
            if extra is not None:
                extra.record(len(payload), len(reply))
        return reply

    def request(self, payload: bytes) -> bytes:
        return self._dispatch(payload)

    def session(self) -> TransportSession:
        """Open a multi-message session (streaming conversations)."""
        return TransportSession(self)

    def last_modeled_latency(self, req_bytes: int, rep_bytes: int) -> float:
        if not self.bandwidth:
            return 0.0
        return (req_bytes + rep_bytes) / self.bandwidth
