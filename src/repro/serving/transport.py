"""Transport abstraction with byte accounting and modeled link bandwidth.

The wire format is what the paper standardizes; sockets are incidental.
``LoopbackTransport`` runs the server in-process but meters every byte both
ways and can model a network bandwidth (the paper's Petals comparison ran on
a ~60 MB/s link), exposing ``modeled_transfer_seconds`` so benchmarks can
report transfer cost without real NICs.
"""
from __future__ import annotations

from typing import Any, Callable

__all__ = ["LoopbackTransport", "TransportStats"]


class TransportStats:
    def __init__(self) -> None:
        self.requests = 0
        self.bytes_sent = 0
        self.bytes_received = 0

    def modeled_transfer_seconds(self, bandwidth_bytes_per_s: float) -> float:
        return (self.bytes_sent + self.bytes_received) / bandwidth_bytes_per_s


class LoopbackTransport:
    def __init__(
        self,
        handler: Callable[[bytes], bytes],
        *,
        bandwidth_bytes_per_s: float | None = 60e6,
    ) -> None:
        self.handler = handler
        self.bandwidth = bandwidth_bytes_per_s
        self.stats = TransportStats()

    def request(self, payload: bytes) -> bytes:
        self.stats.requests += 1
        self.stats.bytes_sent += len(payload)
        reply = self.handler(payload)
        self.stats.bytes_received += len(reply)
        return reply

    def last_modeled_latency(self, req_bytes: int, rep_bytes: int) -> float:
        if not self.bandwidth:
            return 0.0
        return (req_bytes + rep_bytes) / self.bandwidth
