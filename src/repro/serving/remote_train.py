"""Remote module training (paper Code Example 5 / 8).

The paper trains LoRA adapters and probes *remotely*: "parameters are
created remotely and never sent, only retrieved".  In this framework that
falls out of purity: an intervention graph is a pure function of its
``input`` nodes, so the server can differentiate the interleaved program
w.r.t. any named inputs and run an optimizer loop around it — the client
ships the experiment once and pulls back only the trained parameters and
the loss curve.

A LoRA adapter is *literally an intervention graph*::

    h_in  = tap_get(layers.input,  L)            # getter
    delta = (h_in @ WA) @ WB * alpha             # WA/WB are graph inputs
    h_out = tap_get(layers.output, L) + delta
    tap_set(layers.output, L)                    # setter
    loss  = nll(logits, labels).mean().save("loss")

which also makes the adapter serializable, auditable, and co-tenant-safe
like any other experiment.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import (
    Interleaver,
    last_referenced_site,
    run_interleaved,
)

__all__ = ["train_graph_inputs", "lora_graph"]


def train_graph_inputs(
    engine: Any,
    graph: InterventionGraph,
    batch: dict,
    *,
    trainable: dict[str, np.ndarray],
    loss_name: str,
    fixed_inputs: dict[str, np.ndarray] | None = None,
    steps: int = 50,
    lr: float = 1e-2,
) -> tuple[dict[str, np.ndarray], list[float]]:
    """Differentiate the interleaved program w.r.t. named graph inputs and
    run Adam on them server-side.  Returns (trained inputs, loss history).
    """
    graph.validate(engine.schedule.order)
    plan = Interleaver(graph, engine.schedule, mode=engine.mode)
    if plan.grad_nodes:
        raise ValueError("train_graph_inputs drives its own backward; "
                         "remove .grad nodes from the graph")
    if loss_name not in graph.saves:
        raise KeyError(f"loss save {loss_name!r} not in graph")
    fixed = {k: jnp.asarray(v) for k, v in (fixed_inputs or {}).items()}
    params0 = {k: jnp.asarray(v) for k, v in trainable.items()}
    # the loss only needs sites up to the last one the graph references:
    # a probe on layer L trains on a forward truncated right after L (the
    # EarlyStop fires at trace time, so the jitted step compiles the
    # truncated program — same machinery as tracer.stop()).
    stop_idx = last_referenced_site(graph, engine.schedule)

    def loss_fn(train_params, model_params, batch_):
        _out, saves, _logs = run_interleaved(
            engine._model_fn,
            graph,
            engine.schedule,
            (model_params, batch_),
            {},
            mode=engine.mode,
            inputs={**fixed, **train_params},
            stop_after_site=stop_idx,
        )
        return saves[loss_name]

    @partial(jax.jit, donate_argnums=(0,))
    def step(train_params, opt, model_params, batch_):
        loss, grads = jax.value_and_grad(loss_fn)(
            train_params, model_params, batch_
        )
        mu, nu, t = opt
        t = t + 1
        mu = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, mu, grads)
        nu = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, nu, grads)
        new = jax.tree.map(
            lambda p, m, v: p - lr * (m / (1 - 0.9**t))
            / (jnp.sqrt(v / (1 - 0.999**t)) + 1e-8),
            train_params, mu, nu,
        )
        return new, (mu, nu, t), loss

    zeros = jax.tree.map(jnp.zeros_like, params0)
    opt = (zeros, jax.tree.map(jnp.copy, zeros), jnp.zeros((), jnp.int32))
    params = params0
    history: list[float] = []
    for _ in range(steps):
        params, opt, loss = step(params, opt, engine.params, batch)
        history.append(float(loss))
    return {k: np.asarray(v) for k, v in params.items()}, history


def lora_graph(
    layer: int,
    d_model: int,
    rank: int,
    vocab_size: int,
    *,
    alpha: float = 1.0,
    in_site: str = "layers.input",
    out_site: str = "layers.output",
) -> tuple[InterventionGraph, dict[str, np.ndarray]]:
    """Build the LoRA-as-intervention-graph + its initial trainable inputs."""
    g = InterventionGraph()
    h_in = g.add("tap_get", site=in_site, layer=layer)
    wa = g.add("input", "WA")
    wb = g.add("input", "WB")
    a_x = g.add("matmul", Ref(h_in.id), Ref(wa.id))
    ba_x = g.add("matmul", Ref(a_x.id), Ref(wb.id))
    delta = g.add("mul", Ref(ba_x.id), float(alpha))
    h_out = g.add("tap_get", site=out_site, layer=layer)
    new = g.add("add", Ref(h_out.id), Ref(delta.id))
    g.add("tap_set", Ref(new.id), site=out_site, layer=layer)

    logits = g.add("tap_get", site="logits")
    labels = g.add("input", "labels")
    nll = g.add("nll", Ref(logits.id), Ref(labels.id))
    loss = g.add("jnp.mean", Ref(nll.id))
    s = g.add("save", Ref(loss.id))
    g.mark_saved("loss", s)

    rng = np.random.default_rng(0)
    init = {
        "WA": (rng.standard_normal((d_model, rank)) / np.sqrt(d_model)
               ).astype(np.float32),
        "WB": np.zeros((rank, d_model), np.float32),
    }
    return g, init
