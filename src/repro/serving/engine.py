"""Inference engine: executes intervention graphs against a preloaded model.

The NDIF compute core (paper §3.3 / B.2).  One engine per hosted model:

  * compiles ``run_interleaved(model_fn, graph, …)`` under ``jax.jit`` with
    explicit in/out shardings when a mesh is active;
  * caches executables by the graph's *structural key* + input shapes, with
    constant values passed as runtime args (no recompile per patched value);
  * serves generation (prefill + decode loop) through ONE cached compiled
    step function — the decode step is traced once per (batch, cache) shape
    and every later ``generate()`` call reuses the executable
    (``EngineStats.compiles`` is bumped only at trace time, so a second
    identical call reports zero new compiles);
  * serves *intervention-aware* generation: a step-annotated graph
    (:mod:`repro.core.generation`) rides the same decode loop, with
    uninstrumented steps taking the cached compiled fast path;
  * fuses step-uniform decode stretches into ONE ``lax.scan`` dispatch
    (``EngineStats.fused_segments``/``fused_steps``), caching the compiled
    program by structural graph signature — a second identically-shaped
    generation request compiles nothing and dispatches once per segment.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import taps
from repro.core.generation import (
    GenerationResult,
    _step_order,
    make_fused_step,
    run_generation,
)
from repro.core.graph import InterventionGraph
from repro.core.interleave import SiteSchedule, run_interleaved
from repro.core.serialize import structural_key
from repro.serving import faults

__all__ = ["InferenceEngine", "EngineStats"]


class EngineStats:
    # group_sizes keeps the most recent merged-group sizes only (the
    # aggregate counters are unbounded; the ring is for dashboards)
    GROUP_HISTORY = 512

    def __init__(self) -> None:
        self.compiles = 0        # XLA traces (graph execs + prefill/decode)
        self.executions = 0
        self.cache_hits = 0
        self.exec_seconds = 0.0
        self.generations = 0     # generate() calls served
        self.gen_tokens = 0      # total tokens decoded
        self.merged_groups = 0   # parallel co-tenancy groups executed
        self.merged_requests = 0  # requests served inside merged groups
        self.group_sizes: list[int] = []  # recent merged-group sizes
        self.padded_tokens = 0   # padding cells added by ragged merging
        self.real_tokens = 0     # real cells in merged ragged inputs
        # length-aware group sizing: groups split because admitting one more
        # request would exceed the row cap / the rows x padded-length cap
        self.cap_splits_rows = 0
        self.cap_splits_cells = 0
        # continuous batching (slot-table decode loop)
        self.admissions = 0      # requests admitted into a running loop
        self.admitted_rows = 0   # slot rows those admissions occupied
        self.retires = 0         # requests retired from the loop
        self.slot_steps = 0      # decode steps run by the loop
        self.slot_busy = 0       # sum of occupied rows over steps
        self.slot_capacity = 0   # sum of total rows over steps
        # fused decode (one lax.scan dispatch per step-uniform stretch)
        self.fused_segments = 0  # fused scan dispatches
        self.fused_steps = 0     # decode steps served by those dispatches
        self.eager_steps = 0     # decode steps served per-step (non-uniform)
        # fused segments carrying log/grad/cross-layer work — the "eager
        # islands" the harvest interpreter now compiles
        self.islands_compiled = 0
        # paged KV cache (block-table indirection over a shared page pool)
        self.page_allocs = 0     # pages handed out (admission + growth)
        self.page_frees = 0      # pages returned at retirement
        self.pages_in_use = 0    # gauge: pages currently allocated
        self.pages_free = 0      # gauge: pages currently free
        self.alloc_retries = 0   # admissions requeued on pool exhaustion
        self.frag_events_avoided = 0  # admissions served NON-contiguously
        # live front door (threaded serving: repro.serving.frontdoor)
        self.queue_depth = 0           # gauge: submissions waiting right now
        self.queue_depth_max = 0       # high-water mark of the gauge
        self.rejected_submissions = 0  # submits refused (backpressure / SLO)
        self.stream_chunks = 0         # incremental chunks pushed to clients
        # measured per-step / per-prefill cost EMAs (seconds) — the SLO
        # planner prices admission decisions against these
        self.step_cost_ema = 0.0
        self.prefill_cost_ema = 0.0
        self._cost_alpha = 0.3
        # recent completed front-door tickets (queue_wait / ttft / response)
        self.ticket_records: list[dict] = []
        # fault tolerance (repro.serving.faults + the front-door supervisor)
        self.faults_injected = 0     # injected faults that actually fired
        self.engine_restarts = 0     # supervised engine-loop restarts
        self.tickets_requeued = 0    # in-flight tickets requeued by recovery
        self.cancellations = 0       # tickets cancelled via the cancel kind
        self.deadline_evictions = 0  # tickets evicted past their deadline_ms

    def record_group(self, n_requests: int, padded: int, real: int) -> None:
        """Scheduler hook: one parallel co-tenancy group was executed."""
        self.merged_groups += 1
        self.merged_requests += int(n_requests)
        self.group_sizes.append(int(n_requests))
        del self.group_sizes[:-self.GROUP_HISTORY]
        self.padded_tokens += int(padded)
        self.real_tokens += int(real)

    def record_cap_split(self, kind: str) -> None:
        """A group/admission was split by a batch cap (kind: rows|cells)."""
        if kind == "rows":
            self.cap_splits_rows += 1
        else:
            self.cap_splits_cells += 1

    def record_admission(self, rows: int) -> None:
        self.admissions += 1
        self.admitted_rows += int(rows)

    def record_retire(self, rows: int, n_tokens: int) -> None:
        self.retires += 1
        self.generations += 1
        self.gen_tokens += int(rows) * int(n_tokens)

    def record_slot_step(self, busy_rows: int, total_rows: int) -> None:
        self.slot_steps += 1
        self.slot_busy += int(busy_rows)
        self.slot_capacity += int(total_rows)

    def record_fused_segment(self, n_steps: int) -> None:
        """One fused lax.scan dispatch served ``n_steps`` decode steps."""
        self.fused_segments += 1
        self.fused_steps += int(n_steps)

    def record_eager_step(self) -> None:
        """One decode step ran the eager per-step path."""
        self.eager_steps += 1

    def record_islands_compiled(self) -> None:
        """One fused segment carried log/grad/cross-layer work that the
        pre-harvest loop would have served eagerly."""
        self.islands_compiled += 1

    def record_page_alloc(self, n: int, in_use: int, free: int) -> None:
        """The paged allocator handed out ``n`` pages (admission scatter or
        decode growth); gauges reflect the pool after the allocation."""
        self.page_allocs += int(n)
        self.pages_in_use = int(in_use)
        self.pages_free = int(free)

    def record_page_free(self, n: int, in_use: int, free: int) -> None:
        """A retirement returned ``n`` pages to the pool."""
        self.page_frees += int(n)
        self.pages_in_use = int(in_use)
        self.pages_free = int(free)

    def record_alloc_retry(self) -> None:
        """An admission hit pool/row exhaustion and was requeued."""
        self.alloc_retries += 1

    def record_frag_avoided(self) -> None:
        """An admission was served by NON-contiguous rows — under the old
        contiguous-run allocator this would have been a fragmentation
        rejection (a requeue or a failure)."""
        self.frag_events_avoided += 1

    # ---------------------------------------------------------- front door
    def record_queue_depth(self, depth: int) -> None:
        """Gauge update from the front door's submission inbox."""
        self.queue_depth = int(depth)
        if self.queue_depth > self.queue_depth_max:
            self.queue_depth_max = self.queue_depth

    def record_rejected_submission(self) -> None:
        """A submit was refused with structured backpressure / SLO error."""
        self.rejected_submissions += 1

    def record_stream_chunks(self, n: int) -> None:
        """``n`` incremental chunks were pushed onto result channels."""
        self.stream_chunks += int(n)

    def record_step_cost(self, seconds_per_step: float) -> None:
        """EMA of the measured per-decode-step wall cost."""
        s = float(seconds_per_step)
        a = self._cost_alpha
        self.step_cost_ema = (
            s if self.step_cost_ema == 0.0
            else (1 - a) * self.step_cost_ema + a * s
        )

    def record_prefill_cost(self, seconds: float) -> None:
        """EMA of the measured admission (prefill) wall cost."""
        s = float(seconds)
        a = self._cost_alpha
        self.prefill_cost_ema = (
            s if self.prefill_cost_ema == 0.0
            else (1 - a) * self.prefill_cost_ema + a * s
        )

    # ------------------------------------------------------ fault tolerance
    def record_fault_injected(self, point: str) -> None:
        """A :class:`~repro.serving.faults.FaultPlan` spec fired."""
        self.faults_injected += 1

    def record_engine_restart(self) -> None:
        """The front-door supervisor rebuilt and restarted the engine loop."""
        self.engine_restarts += 1

    def record_ticket_requeued(self) -> None:
        """Recovery requeued an in-flight ticket instead of failing it."""
        self.tickets_requeued += 1

    def record_cancellation(self) -> None:
        """A ticket was cancelled (queued removal or mid-decode eviction)."""
        self.cancellations += 1

    def record_deadline_eviction(self) -> None:
        """A ticket blew its ``deadline_ms`` and was evicted, freeing its
        slot rows and KV pages for co-tenants."""
        self.deadline_evictions += 1

    def record_ticket(self, record: dict) -> None:
        """One front-door ticket completed; keep a bounded recent history
        (queue_wait and time_to_first_token per ticket, for the ``stats``
        wire endpoint)."""
        self.ticket_records.append(dict(record))
        del self.ticket_records[:-self.GROUP_HISTORY]

    def snapshot(self) -> dict:
        """JSON-ready view for the server's ``stats`` endpoint."""
        cells = self.padded_tokens + self.real_tokens
        return {
            "compiles": self.compiles,
            "executions": self.executions,
            "cache_hits": self.cache_hits,
            "exec_seconds": self.exec_seconds,
            "generations": self.generations,
            "gen_tokens": self.gen_tokens,
            "merged_groups": self.merged_groups,
            "merged_requests": self.merged_requests,
            "group_sizes": list(self.group_sizes),
            "mean_group_size": (
                self.merged_requests / self.merged_groups
                if self.merged_groups else 0.0
            ),
            "padded_tokens": self.padded_tokens,
            "real_tokens": self.real_tokens,
            "padding_waste": self.padded_tokens / cells if cells else 0.0,
            "cap_splits_rows": self.cap_splits_rows,
            "cap_splits_cells": self.cap_splits_cells,
            "admissions": self.admissions,
            "admitted_rows": self.admitted_rows,
            "retires": self.retires,
            "slot_steps": self.slot_steps,
            "slot_occupancy": (
                self.slot_busy / self.slot_capacity
                if self.slot_capacity else 0.0
            ),
            "fused_segments": self.fused_segments,
            "fused_steps": self.fused_steps,
            "eager_steps": self.eager_steps,
            "islands_compiled": self.islands_compiled,
            "page_allocs": self.page_allocs,
            "page_frees": self.page_frees,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.pages_free,
            "page_occupancy": (
                self.pages_in_use / (self.pages_in_use + self.pages_free)
                if (self.pages_in_use + self.pages_free) else 0.0
            ),
            "alloc_retries": self.alloc_retries,
            "frag_events_avoided": self.frag_events_avoided,
            "queue_depth": self.queue_depth,
            "queue_depth_max": self.queue_depth_max,
            "rejected_submissions": self.rejected_submissions,
            "stream_chunks": self.stream_chunks,
            "step_cost_ema": self.step_cost_ema,
            "prefill_cost_ema": self.prefill_cost_ema,
            "tickets": [dict(r) for r in self.ticket_records],
            "faults_injected": self.faults_injected,
            "engine_restarts": self.engine_restarts,
            "tickets_requeued": self.tickets_requeued,
            "cancellations": self.cancellations,
            "deadline_evictions": self.deadline_evictions,
        }


class _FusedCountersOnly:
    """Stats adapter for the engine's INTERNAL solo decode loops.

    ``run_generation`` executes through a private DecodeLoop; its fused /
    eager step counters should flow to :class:`EngineStats`, but admission
    / retirement / slot-occupancy accounting stays reserved for the SHARED
    continuous loop (``admissions == 0`` still means "nothing rode the
    slot table")."""

    def __init__(self, stats: EngineStats) -> None:
        self._stats = stats

    def record_admission(self, rows: int) -> None:
        pass

    def record_retire(self, rows: int, n_tokens: int) -> None:
        pass

    def record_slot_step(self, busy_rows: int, total_rows: int) -> None:
        pass

    def record_fused_segment(self, n_steps: int) -> None:
        self._stats.record_fused_segment(n_steps)

    def record_eager_step(self) -> None:
        self._stats.record_eager_step()

    def record_islands_compiled(self) -> None:
        self._stats.record_islands_compiled()


class InferenceEngine:
    def __init__(
        self,
        model: Any,
        params: Any,
        *,
        mode: str = "unrolled",
        name: str | None = None,
    ) -> None:
        self.model = model
        self.params = params
        self.mode = mode
        self.name = name or model.cfg.name
        self.schedule = self._full_schedule()
        self.stats = EngineStats()
        self._cache: dict[Any, Callable] = {}
        # Cached compiled generation step functions.  Built ONCE; jax.jit
        # re-traces only for unseen shape signatures, so repeated generate()
        # calls with the same shapes perform zero new compiles (the
        # stats.compiles bump below runs at trace time only).
        self._prefill_jit = jax.jit(
            self._prefill_counted, static_argnames=("max_len",)
        )
        self._decode_jit = jax.jit(self._decode_counted)
        self._empty_cache_jit = jax.jit(
            self._empty_cache_counted,
            static_argnames=("batch_size", "max_len", "kind"),
        )
        # Slot-table row scatter/clear for continuous batching: traced once
        # per (row-count, cache-shape) signature, then reused across every
        # admission/retirement — slot reuse never recompiles.
        self._write_rows_jit = jax.jit(self._write_rows_counted)
        self._clear_rows_jit = jax.jit(self._clear_rows_counted)
        # Fused decode executables, keyed by the merged step graph's
        # structural signature + scan length: a second identically-shaped
        # request reuses the compiled lax.scan program — zero compiles,
        # exactly like the prefill/decode caches above.
        self._fused_exec: dict[Any, Callable] = {}
        self._step_schedule = _step_order(model.site_schedule(mode))
        # Static preflight (repro.core.analysis): captured site avals per
        # batch signature, and analysis reports per (graph, signature) —
        # admission rejects bad graphs before they touch any executable.
        self._aval_cache: dict[Any, Any] = {}
        self._preflight_cache: dict[Any, Any] = {}

    def _full_schedule(self) -> SiteSchedule:
        sched = self.model.site_schedule(self.mode)
        order = list(sched.order)
        if ("output", None) not in order:
            order.append(("output", None))
        return SiteSchedule(order, sched.scan_sites, sched.n_layers)

    # ----------------------------------------------------------------- fwd
    def _model_fn(self, params: Any, batch: dict) -> Any:
        out = self.model.forward(params, batch, mode=self.mode)["logits"]
        return taps.site("output", out)

    def _prefill_counted(self, params: Any, batch: dict, max_len: int):
        self.stats.compiles += 1  # fires at trace time only
        return self.model.prefill(
            params, batch, mode=self.mode, max_len=max_len
        )

    def _decode_counted(self, params: Any, cache: Any, token, pos):
        self.stats.compiles += 1  # fires at trace time only
        return self.model.decode_step(
            params, cache, {"token": token, "pos": pos}, mode=self.mode
        )

    def _empty_cache_counted(self, params, batch, batch_size, max_len, kind):
        self.stats.compiles += 1  # fires at trace time only
        return self.model.empty_cache(
            params, batch, batch_size, max_len, kind=kind
        )

    def _write_rows_counted(self, table, rows, src, src_rows):
        self.stats.compiles += 1  # fires at trace time only
        return self.model.cache_write_rows(table, rows, src, src_rows)

    def _clear_rows_counted(self, table, rows):
        self.stats.compiles += 1  # fires at trace time only
        return self.model.cache_clear_rows(table, rows)

    def _fused_factory(self, graph: InterventionGraph, n_steps: int):
        """Compiled fused-decode program for one step-uniform segment.

        Passed to :class:`~repro.core.generation.DecodeLoop` as
        ``fused_fn``; cached by (structural graph key, scan length) so a
        second identically-shaped request performs zero new compiles."""
        key = (structural_key(graph), int(n_steps))
        fn = self._fused_exec.get(key)
        if fn is None:
            # fault point: a failed build degrades this window to the eager
            # per-step path (step_fused memoizes the key as bad), it never
            # crashes the loop
            faults.fire("fused.compile")
            runner = make_fused_step(
                self.model, graph, self._step_schedule, int(n_steps),
                mode=self.mode,
            )

            def counted(params, cache, token, base_pos, consts, xs, inputs):
                self.stats.compiles += 1  # fires at trace time only
                return runner(params, cache, token, base_pos, consts, xs,
                              inputs)

            fn = jax.jit(counted)
            self._fused_exec[key] = fn
        return fn

    # ------------------------------------------------------------ preflight
    def preflight(self, graph: InterventionGraph, batch: dict) -> Any:
        """Static analysis of a single-forward request (admission layer).

        Zero model FLOPs: site avals come from ONE ``jax.eval_shape`` of
        the forward per batch signature (cached), reports are cached per
        (structural graph key, batch signature).  Callers enforce via
        ``report.enforce()``."""
        from repro.core import analysis

        sig = ("fwd", analysis.aval_signature(batch))
        key = (structural_key(graph), sig)
        report = self._preflight_cache.get(key)
        if report is not None:
            return report
        if sig in self._aval_cache:
            site_avals = self._aval_cache[sig]
        else:
            try:
                site_avals = analysis.capture_forward_avals(
                    self._model_fn, (self.params, dict(batch)), {}
                )
            except Exception:
                site_avals = None  # structural lint only
            self._aval_cache[sig] = site_avals
        report = analysis.analyze(
            graph,
            site_order=list(self.schedule.order),
            site_avals=site_avals,
        )
        self._preflight_cache[key] = report
        return report

    def preflight_generation(
        self,
        graph: InterventionGraph,
        batch: dict,
        max_new_tokens: int,
        *,
        max_len: int | None = None,
    ) -> Any:
        """Static analysis of a generation request before it touches the
        decode loop: step-flow rules, per-execution shape facts (prefill
        avals are prompt-shaped, decode avals are ``(B, 1, ...)``), fusion
        verdicts.  Zero model FLOPs; cached like :meth:`preflight`."""
        from repro.core import analysis

        n_new = int(max_new_tokens)
        batch = {k: v for k, v in batch.items() if k != "lengths"}
        sig = ("gen", analysis.aval_signature(batch), n_new, max_len)
        key = (structural_key(graph), sig)
        report = self._preflight_cache.get(key)
        if report is not None:
            return report
        if sig in self._aval_cache:
            pre_avals, dec_avals = self._aval_cache[sig]
        else:
            try:
                cap = dict(batch)
                tokens = np.asarray(cap["tokens"])
                # runtime prefills on the prompt minus its last token
                if tokens.shape[1] > 1:
                    cap["tokens"] = tokens[:, :-1]
                ml = max_len
                if ml is None:
                    ml = int(np.shape(cap["tokens"])[1]) + n_new
                pre_avals, dec_avals = analysis.capture_generation_avals(
                    self.model, self.params, cap,
                    max_len=int(ml), mode=self.mode,
                )
            except Exception:
                pre_avals = dec_avals = None  # structural lint only
            self._aval_cache[sig] = (pre_avals, dec_avals)
        step_order = list(self._step_schedule.order)
        report = analysis.analyze(
            graph,
            site_order=step_order,
            decode_order=step_order,
            site_avals=pre_avals,
            decode_avals=dec_avals,
            n_steps=n_new,
            schedule=self._step_schedule,
        )
        self._preflight_cache[key] = report
        return report

    # ------------------------------------------------------------- execute
    def execute(
        self, graph: InterventionGraph, batch: dict, *, stop: bool = False
    ) -> tuple[dict[str, Any], Any]:
        """Run ``graph`` interleaved with one forward. Returns (saves, out).

        Compatibility wrapper over :meth:`execute_logged` — callers that
        need ``log()`` values (the scheduler, which attributes them per
        ticket) use that form directly.
        """
        saves, out, _logs = self.execute_logged(graph, batch, stop=stop)
        return saves, out

    def execute_logged(
        self, graph: InterventionGraph, batch: dict, *, stop: bool = False
    ) -> tuple[dict[str, Any], Any, list[tuple[int, Any]]]:
        """Run ``graph`` interleaved with one forward.
        Returns ``(saves, out, logs)``.

        ``log`` nodes lower to ``jax.debug.callback`` into the module
        :data:`~repro.core.interleave.LOG_SINK` INSIDE the jitted program —
        the callback fires on every execution (cache hits included), so the
        single-forward jit path no longer drops ``log()`` values.  The sink
        is cleared before dispatch (stale entries from unrelated dispatches
        must not be attributed here) and drained after; entries keep the
        graph's node ids for per-request attribution by merged-graph
        segment.

        ``stop=True`` (``tracer.stop()`` shipped over the wire) truncates
        the forward after the last site the graph references — BEFORE
        lowering: the interleaver raises ``EarlyStop`` inside the traced
        function, so the partial trace IS the jaxpr and the truncated
        program compiles and caches like any other (keyed separately from
        the full-forward program of the same graph).  The saving is both
        model compute AND per-call dispatch.
        """
        from repro.core import analysis
        from repro.core.interleave import LOG_SINK

        pmode = analysis.preflight_mode()
        if pmode != "off" and graph.nodes:
            self.preflight(graph, batch).enforce(pmode)
        graph.validate(self.schedule.order)
        has_log = any(n.op == "log" for n in graph.nodes)
        log_cb = LOG_SINK.emit if has_log else None
        if stop:
            from repro.core.interleave import last_referenced_site

            stop_idx = last_referenced_site(graph, self.schedule)
            const_env = {
                n.id: n.args[0] for n in graph.nodes if n.op == "constant"
            }
            key = (
                "stop",
                structural_key(graph),
                tuple(sorted(
                    (k, tuple(np.shape(v)),
                     str(np.asarray(v).dtype) if not hasattr(v, "dtype")
                     else str(v.dtype))
                    for k, v in batch.items()
                )),
            )
            fn = self._cache.get(key)
            if fn is None:
                self.stats.compiles += 1

                @jax.jit
                def fn(params, batch_, consts):
                    _out, saves, _logs = run_interleaved(
                        self._model_fn,
                        graph,
                        self.schedule,
                        (params, batch_),
                        {},
                        mode=self.mode,
                        const_env=consts,
                        stop_after_site=stop_idx,
                        log_cb=log_cb,
                    )
                    return saves

                self._cache[key] = fn
            else:
                self.stats.cache_hits += 1
            t0 = time.perf_counter()
            if has_log:
                LOG_SINK.drain()  # clear stale entries before this dispatch
            saves = fn(self.params, batch, const_env)
            saves = jax.tree.map(lambda x: jax.device_get(x), saves)
            logs = LOG_SINK.drain() if has_log else []
            self.stats.exec_seconds += time.perf_counter() - t0
            self.stats.executions += 1
            return saves, None, logs
        const_env = {
            n.id: n.args[0] for n in graph.nodes if n.op == "constant"
        }
        key = (
            structural_key(graph),
            tuple(sorted(
                (k, tuple(np.shape(v)), str(np.asarray(v).dtype) if not hasattr(v, "dtype") else str(v.dtype))
                for k, v in batch.items()
            )),
        )
        fn = self._cache.get(key)
        if fn is None:
            self.stats.compiles += 1

            @partial(jax.jit, static_argnames=())
            def fn(params, batch_, consts):
                out, saves, _logs = run_interleaved(
                    self._model_fn,
                    graph,
                    self.schedule,
                    (params, batch_),
                    {},
                    mode=self.mode,
                    const_env=consts,
                    log_cb=log_cb,
                )
                return saves, out

            self._cache[key] = fn
        else:
            self.stats.cache_hits += 1
        t0 = time.perf_counter()
        if has_log:
            LOG_SINK.drain()  # clear stale entries before this dispatch
        saves, out = fn(self.params, batch, const_env)
        saves = jax.tree.map(lambda x: jax.device_get(x), saves)
        logs = LOG_SINK.drain() if has_log else []
        self.stats.exec_seconds += time.perf_counter() - t0
        self.stats.executions += 1
        return saves, out, logs

    # ------------------------------------------------------------ generate
    def generate(
        self, tokens: jax.Array, max_new_tokens: int = 16, **extras
    ) -> tuple[np.ndarray, np.ndarray]:
        """Greedy generation via the cached compiled step.

        Returns ``(tokens, logits)`` where tokens is ``(B, N)`` and logits
        is the LAST step's ``(B, 1, V)`` — the same shape for every value of
        ``max_new_tokens`` (including 1).
        """
        res = self.generate_interleaved(
            InterventionGraph(),
            {"tokens": jnp.asarray(tokens), **extras},
            max_new_tokens,
        )
        return np.asarray(res.tokens), np.asarray(res.logits)

    def generate_interleaved(
        self,
        graph: InterventionGraph,
        batch: dict,
        max_new_tokens: int = 16,
        *,
        fused: bool = True,
    ) -> GenerationResult:
        """Generation with a step-annotated intervention graph interleaved.

        Step-uniform decode stretches run as ONE compiled ``lax.scan``
        dispatch (``fused=False`` forces the eager per-step path — the
        benchmark baseline); uninstrumented eager steps run the cached
        compiled prefill/decode; non-uniform instrumented steps run the
        eager interleaver (see repro.core.generation).
        """
        from repro.core import analysis

        pmode = analysis.preflight_mode()
        if pmode != "off" and graph.nodes:
            self.preflight_generation(
                graph, batch, max_new_tokens
            ).enforce(pmode)
        batch = dict(batch)
        tokens = jnp.asarray(batch.pop("tokens"))
        lengths = batch.pop("lengths", None)
        t0 = time.perf_counter()
        res = run_generation(
            self.model,
            self.params,
            graph,
            tokens,
            max_new_tokens,
            mode=self.mode,
            extras=batch,
            prefill_fn=lambda p, b, ml: self._prefill_jit(p, b, max_len=ml),
            decode_fn=self._decode_jit,
            empty_cache_fn=lambda p, b, bs, ml, kind: self._empty_cache_jit(
                p, b, batch_size=bs, max_len=ml, kind=kind
            ),
            lengths=lengths,
            fused=fused,
            fused_fn=self._fused_factory,
            stats=_FusedCountersOnly(self.stats),
        )
        res.saves = jax.tree.map(lambda x: jax.device_get(x), res.saves)
        self.stats.exec_seconds += time.perf_counter() - t0
        self.stats.executions += 1
        self.stats.generations += 1
        self.stats.gen_tokens += int(res.tokens.shape[0] * res.tokens.shape[1])
        return res

    def generate_invokes(self, items: list[tuple]) -> list[GenerationResult]:
        """Serve a multi-invoke generation request as ONE decode loop.

        ``items`` is ``[(graph, batch, max_new_tokens), ...]`` — the wire
        form of a multi-invoke ``lm.generate()`` trace.  Every invoke is a
        row-group of one slot-table loop (shared prefill for multi-token
        prompts, independent retirement) built on the engine's cached
        compiled step functions, so repeated identically-shaped requests
        perform zero new compiles.
        """
        from repro.core.generation import run_generation_invokes

        t0 = time.perf_counter()
        results = run_generation_invokes(
            self.model,
            self.params,
            items,
            mode=self.mode,
            prefill_fn=lambda p, b, ml: self._prefill_jit(p, b, max_len=ml),
            decode_fn=self._decode_jit,
            empty_cache_fn=lambda p, b, bs, ml, kind: self._empty_cache_jit(
                p, b, batch_size=bs, max_len=ml, kind=kind
            ),
            write_rows_fn=self._write_rows_jit,
            clear_rows_fn=self._clear_rows_jit,
            stats=self.stats,
            fused_fn=self._fused_factory,
        )
        for res in results:
            res.saves = jax.tree.map(lambda x: jax.device_get(x), res.saves)
        self.stats.exec_seconds += time.perf_counter() - t0
        self.stats.executions += 1
        return results

    # ------------------------------------------------------ continuous loop
    def start_decode_loop(
        self, num_slots: int, max_len: int, *, cache_kind: str = "full",
        paged: bool = True, page_size: int = 16,
        num_pages: int | None = None, on_segment: Callable | None = None,
    ):
        """A persistent slot-table decode loop (continuous batching).

        ONE jitted decode step specialized on ``num_slots`` serves every
        resident request; admissions prefill through the cached prefill jit
        and scatter their cache rows in, retirements clear rows for reuse —
        zero decode-step retraces across the loop's lifetime.

        ``paged=True`` (the serving default) backs the KV cache with a
        shared page pool behind per-slot block tables: rows are allocated
        by ACTUAL request length (growing page-by-page during decode), so
        short requests no longer pin ``max_len`` worth of memory and
        admissions never fail on row fragmentation.  Families with nothing
        to page (Mamba2) silently keep the dense table.
        """
        from repro.core.generation import DecodeLoop

        return DecodeLoop(
            self.model,
            self.params,
            num_slots,
            max_len,
            mode=self.mode,
            cache_kind=cache_kind,
            paged=paged,
            page_size=page_size,
            num_pages=num_pages,
            on_segment=on_segment,
            prefill_fn=lambda p, b, ml: self._prefill_jit(p, b, max_len=ml),
            decode_fn=self._decode_jit,
            empty_cache_fn=lambda p, b, bs, ml, kind: self._empty_cache_jit(
                p, b, batch_size=bs, max_len=ml, kind=kind
            ),
            write_rows_fn=self._write_rows_jit,
            clear_rows_fn=self._clear_rows_jit,
            stats=self.stats,
            fused_fn=self._fused_factory,
        )

    def hidden_states(self, tokens: jax.Array, **extras) -> np.ndarray:
        """Petals-style API: run the stack, return FINAL hidden states.

        Used by the Fig. 6c comparison — this is what a swarm client receives
        when it must do interventions locally."""
        with_graph = InterventionGraph()
        g = with_graph.add("tap_get", site="final_norm")
        s = with_graph.add("save", _ref(g))
        with_graph.mark_saved("hidden", with_graph.nodes[s.id])
        saves, _ = self.execute(with_graph, {"tokens": tokens, **extras})
        return np.asarray(saves["hidden"])


def _ref(node):
    from repro.core.graph import Ref

    return Ref(node.id)
