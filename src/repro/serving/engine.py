"""Inference engine: executes intervention graphs against a preloaded model.

The NDIF compute core (paper §3.3 / B.2).  One engine per hosted model:

  * compiles ``run_interleaved(model_fn, graph, …)`` under ``jax.jit`` with
    explicit in/out shardings when a mesh is active;
  * caches executables by the graph's *structural key* + input shapes, with
    constant values passed as runtime args (no recompile per patched value);
  * supports plain generation (prefill + decode loop) for the inference-API
    comparison benchmarks (Fig. 6c "standard remote inference").
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import taps
from repro.core.graph import InterventionGraph
from repro.core.interleave import SiteSchedule, run_interleaved
from repro.core.serialize import structural_key

__all__ = ["InferenceEngine", "EngineStats"]


class EngineStats:
    def __init__(self) -> None:
        self.compiles = 0
        self.executions = 0
        self.cache_hits = 0
        self.exec_seconds = 0.0


class InferenceEngine:
    def __init__(
        self,
        model: Any,
        params: Any,
        *,
        mode: str = "unrolled",
        name: str | None = None,
    ) -> None:
        self.model = model
        self.params = params
        self.mode = mode
        self.name = name or model.cfg.name
        self.schedule = self._full_schedule()
        self.stats = EngineStats()
        self._cache: dict[Any, Callable] = {}

    def _full_schedule(self) -> SiteSchedule:
        sched = self.model.site_schedule(self.mode)
        order = list(sched.order)
        if ("output", None) not in order:
            order.append(("output", None))
        return SiteSchedule(order, sched.scan_sites, sched.n_layers)

    # ----------------------------------------------------------------- fwd
    def _model_fn(self, params: Any, batch: dict) -> Any:
        out = self.model.forward(params, batch, mode=self.mode)["logits"]
        return taps.site("output", out)

    # ------------------------------------------------------------- execute
    def execute(
        self, graph: InterventionGraph, batch: dict
    ) -> tuple[dict[str, Any], Any]:
        """Run ``graph`` interleaved with one forward. Returns (saves, out)."""
        graph.validate(self.schedule.order)
        const_env = {
            n.id: n.args[0] for n in graph.nodes if n.op == "constant"
        }
        key = (
            structural_key(graph),
            tuple(sorted(
                (k, tuple(np.shape(v)), str(np.asarray(v).dtype) if not hasattr(v, "dtype") else str(v.dtype))
                for k, v in batch.items()
            )),
        )
        fn = self._cache.get(key)
        if fn is None:
            self.stats.compiles += 1

            @partial(jax.jit, static_argnames=())
            def fn(params, batch_, consts):
                out, saves, logs = run_interleaved(
                    self._model_fn,
                    graph,
                    self.schedule,
                    (params, batch_),
                    {},
                    mode=self.mode,
                    const_env=consts,
                )
                return saves, out

            self._cache[key] = fn
        else:
            self.stats.cache_hits += 1
        t0 = time.perf_counter()
        saves, out = fn(self.params, batch, const_env)
        saves = jax.tree.map(lambda x: jax.device_get(x), saves)
        self.stats.exec_seconds += time.perf_counter() - t0
        self.stats.executions += 1
        return saves, out

    # ------------------------------------------------------------ generate
    def generate(
        self, tokens: jax.Array, max_new_tokens: int = 16, **extras
    ) -> tuple[np.ndarray, np.ndarray]:
        """Greedy generation (prefill + decode loop). Returns (tokens, logits)."""
        B, S = tokens.shape
        out, cache = self.model.prefill(
            self.params, {"tokens": tokens, **extras},
            max_len=S + max_new_tokens,
        )
        logits = out["logits"][:, -1]
        new = [jnp.argmax(logits, axis=-1).astype(jnp.int32)]
        step = jax.jit(
            lambda params, cache, token, pos: self.model.decode_step(
                params, cache, {"token": token, "pos": pos}
            )
        )
        for t in range(max_new_tokens - 1):
            pos = jnp.full((B,), S + t, jnp.int32)
            out, cache = step(self.params, cache, new[-1][:, None], pos)
            new.append(jnp.argmax(out["logits"][:, 0], axis=-1).astype(jnp.int32))
        gen = jnp.stack(new, axis=1)
        return np.asarray(gen), np.asarray(out["logits"])

    def hidden_states(self, tokens: jax.Array, **extras) -> np.ndarray:
        """Petals-style API: run the stack, return FINAL hidden states.

        Used by the Fig. 6c comparison — this is what a swarm client receives
        when it must do interventions locally."""
        with_graph = InterventionGraph()
        g = with_graph.add("tap_get", site="final_norm")
        s = with_graph.add("save", _ref(g))
        with_graph.mark_saved("hidden", with_graph.nodes[s.id])
        saves, _ = self.execute(with_graph, {"tokens": tokens, **extras})
        return np.asarray(saves["hidden"])


def _ref(node):
    from repro.core.graph import Ref

    return Ref(node.id)
