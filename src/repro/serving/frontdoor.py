"""Live NDIF front door: a threaded serving loop with streaming results.

Everything before this module drives the serving stack *synchronously* —
a caller submits, then calls ``drain()``/``pump()`` on the scheduler and
blocks until results exist.  The :class:`FrontDoor` turns that into a live
service: a dedicated **engine thread** steps the persistent
:class:`~repro.core.generation.DecodeLoop` continuously, a thread-safe
submission inbox admits new work at decode-step boundaries (a request
arriving mid-decode joins at the next boundary, it never waits for the
loop to empty), and every ticket gets a :class:`~repro.serving.stream.
StreamChannel` that the engine thread pushes incremental chunks onto as
the loop crosses segment boundaries.

Threading model — ALL JAX compute happens on the ONE engine thread.
Client threads only append to the inbox (under the door lock) and drain
stream channels (each channel has its own lock); nothing else is shared
mutable state.  The engine thread owns the scheduler queue, the decode
loop and the channels' producer side, so the synchronous scheduler
internals (`_serve_single_forwards`, `_admit_arrivals`) are reused as-is,
single-threaded, with zero locking added inside them.

Backpressure + SLO-aware admission happen in :meth:`FrontDoor.submit`,
on the CLIENT's thread, before anything is queued:

  * bounded queue depth — when inbox + scheduler backlog reach
    ``max_queue_depth`` the submission is refused with a structured
    :class:`AdmissionError` carrying ``retry_after_ms`` (the projected
    drain time of the current backlog from measured step costs);
  * capacity preflight (pages-aware) — a request whose rows, positions or
    lifetime KV page need exceed the slot table / page pool is refused
    immediately (``code="capacity"``) instead of being accepted and then
    stalling the live loop with a solo fallback;
  * SLO admission — a request submitted with ``slo_ms`` is refused
    (``code="slo"``) when even the OPTIMISTIC completion projection
    (queue wait + prefill + N decode steps, all from the
    ``EngineStats`` cost EMAs) exceeds its budget: admitting it would
    burn slots on an answer that arrives too late.

The SLO planner also shapes execution: the fused-window picker quantizes
``fusable_steps()`` down a power-of-two ladder (so steady state touches a
handful of compiled window sizes — zero recompiles) and caps the window
so the tightest streaming ticket gets chunks at its SLO-derived cadence
instead of waiting for the slowest co-tenant's retirement.

Fault tolerance (the supervisor): the engine thread runs the serve loop
UNDER a supervisor.  A crash escaping the loop is contained — the
supervisor classifies the failing phase (``admit`` | ``decode`` |
``single_forward`` | ``cancel`` | ``deadline`` | ``tick``), blames the
residents of the crashed loop (a co-tenant resident across
``quarantine_after`` crashes is quarantined: its ticket fails with
``code="engine_restart"`` instead of riding along forever), rebuilds the
scheduler and :class:`DecodeLoop` from scratch, and REQUEUES every
surviving in-flight ticket from its admission record (kept in
``_Progress.req``) in submit order.  Re-execution is deterministic
greedy decode, and the streaming cursors in ``_Progress`` survive the
restart, so a streaming client sees a seamless, bit-exact continuation —
tokens already chunked are never re-sent.  Restarts are budgeted
(``max_restarts``, exponential backoff); past the budget the door is
declared FAILED: every pending ticket gets a terminal structured error
(``code="engine_failed"``) and later submissions are refused — nothing
ever hangs.  An optional watchdog thread (``stall_timeout_s``; off by
default, long XLA compiles look like stalls) detects a STUCK engine step
— not just a dead thread — via a heartbeat the serve loop touches at
every boundary, and fails the door (``code="engine_stalled"``) so
blocked pollers wake immediately.

Deadlines and cancellation ride the same boundary machinery: a ticket
submitted with ``deadline_ms`` is evicted mid-decode once its budget
expires (rows and KV pages freed, ``code="deadline"``), and ``cancel()``
evicts or dequeues a ticket cooperatively (``code="cancelled"``).
Retried submits carry an ``idempotency_key`` so an ambiguous transport
failure never double-admits, and ``take(since=...)`` re-reads delivered
chunks from channel history (terminal channels are parked in a bounded
done-history) so a lost poll reply is never data loss.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any

import numpy as np

from repro.serving import faults
from repro.serving.scheduler import (
    LOGS_KEY,
    CoTenantScheduler,
    Request,
    Ticket,
    _attach_logs,
    _bucket_ceiling,
    _req_rows,
)
from repro.serving.stream import StreamChannel

__all__ = ["AdmissionError", "FrontDoor"]


class AdmissionError(RuntimeError):
    """Structured submission refusal (backpressure / capacity / SLO / closed).

    ``payload`` is the wire form: always ``error`` (human-readable) and
    ``code`` (machine-readable: ``backpressure`` | ``capacity`` | ``slo``
    | ``closed``), plus refusal-specific fields — backpressure carries
    ``retry_after_ms`` and the queue depths, SLO refusals carry the
    projection that blew the budget.
    """

    def __init__(self, message: str, code: str, **fields: Any) -> None:
        super().__init__(message)
        self.code = code
        self.payload = {"error": message, "code": code, **fields}


class _Progress:
    """Engine-thread-private per-ticket streaming cursor: how much of the
    resident SlotRequest's accumulated state has already been chunked."""

    __slots__ = ("req", "ticket", "stream", "slo_ms", "deadline", "steps",
                 "save_keys", "logs", "single_forward")

    def __init__(self, req: Request, ticket: Ticket, stream: bool,
                 slo_ms: float | None,
                 deadline_ms: float | None = None) -> None:
        self.req = req
        self.ticket = ticket
        self.stream = bool(stream)
        self.slo_ms = slo_ms
        # absolute eviction deadline (perf_counter clock), None = no limit
        self.deadline = (
            None if deadline_ms is None
            else ticket.submit_time + float(deadline_ms) / 1000.0
        )
        self.steps = 0                  # decode steps already emitted
        self.save_keys: set = set()     # save names already emitted
        self.logs = 0                   # log entries already emitted
        self.single_forward = req.max_new_tokens is None


class FrontDoor:
    """The live, threaded admission/streaming layer over one engine.

    One front door owns one engine's continuous decode loop; create it,
    ``submit()`` from any number of client threads, drain chunks via
    ``take()`` (the server's poll/stream kinds call this), ``close()``
    when done — residents drain, queued work is rejected with a
    structured error, and the engine thread joins.
    """

    #: fused-window ladder — steady state compiles only these step counts
    WINDOW_LADDER = (1, 2, 4, 8, 16, 32, 64)
    #: terminal channels retained for idempotent poll redelivery
    DONE_HISTORY = 256
    #: idempotency keys remembered for submit dedup
    IDEM_HISTORY = 1024
    #: healthy boundaries after which the restart budget heals back to 0
    HEAL_AFTER = 64

    def __init__(
        self,
        engine: Any,
        *,
        num_slots: int = 8,
        slot_max_len: int = 160,
        max_queue_depth: int = 32,
        pad_slack: int = 16,
        stream_chunk_ms: float = 50.0,
        idle_wait: float = 0.05,
        max_restarts: int = 3,
        restart_backoff_s: float = 0.05,
        stall_timeout_s: float | None = None,
        quarantine_after: int = 2,
        retry_after_bounds: tuple[float, float] = (10.0, 10_000.0),
    ) -> None:
        self.engine = engine
        self.max_queue_depth = int(max_queue_depth)
        # SLO-derived default cadence for streaming tickets without a
        # budget of their own: cap fused windows so a chunk lands roughly
        # this often once step costs are measured.
        self.stream_chunk_ms = float(stream_chunk_ms)
        self.idle_wait = float(idle_wait)
        self.num_slots = int(num_slots)
        self.slot_max_len = int(slot_max_len)
        self.pad_slack = int(pad_slack)
        # supervisor knobs: restart budget with exponential backoff, blame
        # threshold for quarantining crash-adjacent co-tenants, optional
        # stuck-step watchdog (None = off: a long XLA compile inside one
        # step is indistinguishable from a stall)
        self.max_restarts = int(max_restarts)
        self.restart_backoff_s = float(restart_backoff_s)
        self.stall_timeout_s = (
            None if stall_timeout_s is None else float(stall_timeout_s)
        )
        self.quarantine_after = int(quarantine_after)
        lo, hi = retry_after_bounds
        self.retry_after_bounds = (float(lo), float(hi))
        # The front door owns its OWN continuous scheduler (and loop): the
        # engine thread is the only caller of its internals, so the
        # synchronous wire kinds on a co-hosted server never race it.
        self.sched = CoTenantScheduler(
            engine,
            policy="continuous",
            num_slots=num_slots,
            slot_max_len=slot_max_len,
            pad_slack=pad_slack,
        )
        self.loop = engine.start_decode_loop(
            num_slots, slot_max_len, on_segment=self._on_segment
        )
        self.sched._loop = self.loop  # pre-wired with the segment hook
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        # client threads append here; the engine thread moves entries into
        # sched.queue at the next boundary
        self._inbox: list[tuple[Request, Ticket, bool, float | None]] = []
        # published by the engine thread after every boundary so submit()
        # can read the scheduler backlog without touching sched.queue
        self._sched_backlog = 0
        self._channels: dict[Any, StreamChannel] = {}
        self._progress: dict[Any, _Progress] = {}
        # terminal channels parked here (bounded) so a retried poll whose
        # previous reply was lost can still re-read the final chunks
        self._done_hist: OrderedDict[Any, StreamChannel] = OrderedDict()
        # idempotency_key -> request_id (bounded): a retried submit after
        # an ambiguous transport failure dedupes to the original ticket
        self._idem: OrderedDict[Any, Any] = OrderedDict()
        self._cancels: set = set()
        self._crash_blame: dict[Any, int] = {}
        self._restarts = 0
        self._healthy_boundaries = 0
        self._phase = "idle"
        self._heartbeat = time.monotonic()
        self._closing = False
        # terminal door failure (supervised): the structured error payload
        # every pending ticket received; submit() refuses with its code
        self._failed: dict | None = None
        # the supervisor itself crashed — a bug, re-raised by close()
        self._exc: BaseException | None = None
        self._thread = threading.Thread(
            target=self._run, name="frontdoor-engine", daemon=True
        )
        self._thread.start()
        self._watchdog: threading.Thread | None = None
        if self.stall_timeout_s is not None:
            self._watchdog = threading.Thread(
                target=self._watch, name="frontdoor-watchdog", daemon=True
            )
            self._watchdog.start()

    # ------------------------------------------------------------ submission
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._inbox) + self._sched_backlog

    def submit(
        self,
        req: Request,
        *,
        stream: bool = False,
        slo_ms: float | None = None,
        deadline_ms: float | None = None,
        idempotency_key: Any = None,
    ) -> Any:
        """Admit a request into the live loop; returns its ticket id.

        Runs entirely on the caller's thread: backpressure, capacity and
        SLO checks happen here and raise :class:`AdmissionError` BEFORE
        anything is queued, so a refused submission costs zero engine
        work.  ``stream=True`` asks for incremental chunks (tokens per
        fused segment, saves/logs as they flush); the default emits one
        ``done`` chunk at retirement with the full result.

        ``deadline_ms`` is a hard per-ticket budget enforced SERVER-side:
        past it the ticket is evicted mid-decode (rows and KV pages
        freed) with ``code="deadline"``.  ``idempotency_key`` makes the
        submit retry-safe — a key seen before returns the ORIGINAL
        ticket id without admitting anything, so a client retrying after
        an ambiguous transport failure never double-executes.
        """
        stats = self.engine.stats
        with self._wake:
            if idempotency_key is not None:
                prior = self._idem.get(idempotency_key)
                if prior is not None:
                    return prior
        self._preflight_capacity(req, stats)
        ticket = Ticket(req.request_id, submit_time=time.perf_counter())
        with self._wake:
            if idempotency_key is not None:
                # re-check under the lock: two racing retries of the same
                # submit must still admit exactly once
                prior = self._idem.get(idempotency_key)
                if prior is not None:
                    return prior
            if self._failed is not None:
                stats.record_rejected_submission()
                raise AdmissionError(
                    self._failed["error"], self._failed["code"]
                )
            if self._closing:
                stats.record_rejected_submission()
                raise AdmissionError(
                    "front door is closed", "closed"
                )
            depth = len(self._inbox) + self._sched_backlog
            stats.record_queue_depth(depth)
            if depth >= self.max_queue_depth:
                stats.record_rejected_submission()
                raise AdmissionError(
                    f"queue full: {depth} pending >= "
                    f"max_queue_depth={self.max_queue_depth}",
                    "backpressure",
                    retry_after_ms=self._retry_after_ms(depth, stats),
                    queue_depth=depth,
                    position=depth,
                    max_queue_depth=self.max_queue_depth,
                )
            if slo_ms is not None:
                projected = self._project_ms(req, depth, stats)
                if projected is not None and projected > float(slo_ms):
                    stats.record_rejected_submission()
                    raise AdmissionError(
                        f"SLO infeasible: projected {projected:.1f}ms "
                        f"> budget {float(slo_ms):.1f}ms",
                        "slo",
                        projected_ms=projected,
                        slo_ms=float(slo_ms),
                        retry_after_ms=self._retry_after_ms(depth, stats),
                    )
            chan = StreamChannel(req.request_id)
            self._channels[req.request_id] = chan
            self._progress[req.request_id] = _Progress(
                req, ticket, stream, slo_ms, deadline_ms
            )
            self._inbox.append((req, ticket, stream, slo_ms))
            if idempotency_key is not None:
                self._idem[idempotency_key] = req.request_id
                while len(self._idem) > self.IDEM_HISTORY:
                    self._idem.popitem(last=False)
            self._wake.notify()
        return req.request_id

    def cancel(self, ticket_id: Any) -> bool:
        """Request cooperative cancellation of an in-flight ticket.

        Returns True when the ticket is still live (queued or resident) —
        the engine thread evicts it at the next step boundary and its
        channel gets a terminal error chunk with ``code="cancelled"``.
        False means the ticket already terminated (or was never known):
        nothing to cancel, the existing result/error stands.
        """
        with self._wake:
            known = ticket_id in self._progress
            if known:
                self._cancels.add(ticket_id)
                self._wake.notify()
            return known

    def _preflight_capacity(self, req: Request, stats) -> None:
        """Refuse requests the slot table / page pool can NEVER hold.

        The synchronous scheduler serves these via a solo fallback run;
        on the live path that fallback would stall every co-tenant for
        the full solo duration, so the front door refuses instead —
        pages-aware: a paged loop is sized by its pool, not its rows.
        """
        if req.max_new_tokens is None:
            return  # single-forward traces never touch the slot table
        loop = self.loop
        try:
            rows = _req_rows(req)
        except Exception:
            return  # malformed batches fail per-ticket downstream
        t = np.asarray(req.batch.get("tokens", np.zeros((1, 1))))
        tw = int(t.shape[1]) if t.ndim >= 2 else 1
        ceil = _bucket_ceiling(tw, self.sched.pad_slack)
        if rows > loop.num_slots or (
            (ceil - 1 if tw > 1 else 0) + req.max_new_tokens > loop.max_len
        ):
            stats.record_rejected_submission()
            raise AdmissionError(
                f"request can never fit the slot table: {rows} rows / "
                f"{tw}+{req.max_new_tokens} positions vs "
                f"{loop.num_slots} slots x {loop.max_len}",
                "capacity",
                rows=rows, num_slots=loop.num_slots,
                positions=tw + req.max_new_tokens, max_len=loop.max_len,
            )
        if getattr(loop, "paged", False):
            lens = req.batch.get("lengths")
            if lens is not None:
                need = sum(
                    loop.request_page_need(int(L), req.max_new_tokens)
                    for L in np.asarray(lens).reshape(-1)
                )
            else:
                need = rows * loop.request_page_need(tw, req.max_new_tokens)
            if need > loop.usable_pages():
                stats.record_rejected_submission()
                raise AdmissionError(
                    f"request needs {need} KV pages, pool holds "
                    f"{loop.usable_pages()}",
                    "capacity",
                    page_need=need, usable_pages=loop.usable_pages(),
                )

    # -------------------------------------------------------- SLO projection
    def _retry_after_ms(self, depth: int, stats) -> float:
        """How long until the backlog plausibly drains one slot's worth —
        the client's structured backoff hint.  Clamped to
        ``retry_after_bounds``: a cold ``step_cost_ema`` would otherwise
        hint 0ms (hot retry loop) and a pathological EMA spike would
        park clients for minutes."""
        lo, hi = self.retry_after_bounds
        per = stats.step_cost_ema or 0.005
        return float(min(hi, max(lo, 1000.0 * depth * per)))

    def _project_ms(self, req: Request, depth: int, stats) -> float | None:
        """Optimistic completion projection: queue wait (one boundary per
        queued request ahead) + one prefill + N decode steps, from the
        measured cost EMAs.  None until costs exist (a cold door admits —
        it cannot honestly refuse on numbers it has not measured)."""
        if stats.step_cost_ema <= 0.0:
            return None
        n = req.max_new_tokens or 0
        wait = depth * stats.step_cost_ema
        return 1000.0 * (
            wait + stats.prefill_cost_ema + n * stats.step_cost_ema
        )

    def _pick_window(self) -> int:
        """Fused-window size for the next segment: the largest ladder rung
        that fits ``fusable_steps()``, capped by the tightest streaming
        ticket's chunk cadence (SLO budget over its remaining steps, else
        the door-wide ``stream_chunk_ms``).  The ladder bounds the set of
        compiled window executables; the cap bounds time-to-next-chunk."""
        base = self.loop.fusable_steps()
        cap = base
        step = self.engine.stats.step_cost_ema
        if step > 0.0:
            now = time.perf_counter()
            for sr in self.loop.resident:
                prog = self._progress.get(sr.request_id)
                if prog is None:
                    continue
                if prog.deadline is not None:
                    # a boundary must land near the nearest deadline, or
                    # an expired ticket burns a whole window before its
                    # eviction can happen
                    left = max(0.0, prog.deadline - now)
                    cap = min(cap, max(1, int(left / step)))
                if not prog.stream:
                    continue
                if prog.slo_ms is not None:
                    remaining = max(1, sr.max_new_tokens - sr.t)
                    budget_ms = float(prog.slo_ms) / remaining
                else:
                    budget_ms = self.stream_chunk_ms
                cap = min(cap, max(1, int(budget_ms / (1000.0 * step))))
        k = 1
        for rung in self.WINDOW_LADDER:
            if rung <= min(base, cap):
                k = rung
        return k

    # ------------------------------------------------------- engine thread
    def _run(self) -> None:
        """Supervisor: run the serve loop, contain crashes, restart.

        The serve loop runs in THIS thread under the supervisor — a crash
        escaping it triggers :meth:`_recover` (blame, rebuild, requeue)
        and re-enters the loop; past the restart budget the door fails
        terminally instead.  Only a bug in the supervisor itself lands in
        ``_exc`` (re-raised by ``close()``)."""
        try:
            while True:
                try:
                    self._serve_forever()
                    return  # clean close() drain, or door declared failed
                except BaseException as e:
                    if not self._recover(e):
                        return
        except BaseException as e:  # the supervisor must never die silently
            self._exc = e
            self._fail_door(
                f"front door supervisor crashed: {type(e).__name__}: {e}",
                "engine_failed",
            )

    def _serve_forever(self) -> None:
        sched, loop = self.sched, self.loop
        while True:
            self._heartbeat = time.monotonic()
            if self._failed is not None:
                return  # the watchdog declared the door dead mid-stall
            self._phase = "tick"
            faults.fire("engine.tick")
            with self._wake:
                while (not self._inbox and not sched.queue
                       and not loop.resident and not self._closing
                       and not self._cancels and self._failed is None):
                    self._heartbeat = time.monotonic()
                    self._wake.wait(self.idle_wait)
                if self._failed is not None:
                    return
                closing = self._closing
                moved, self._inbox = self._inbox, []
                if not closing:
                    # move inbox -> sched.queue UNDER the lock and refresh
                    # the published backlog in the same step: submit()'s
                    # depth (inbox + backlog) must never undercount the
                    # moved entries, or a burst admitted during boundary
                    # processing could overshoot max_queue_depth
                    for req, ticket, _stream, _slo in moved:
                        sched.queue.append((req, ticket))
                    self._sched_backlog = len(sched.queue)
            if closing:
                self._reject_pending(moved)
                if not sched.queue and not loop.resident:
                    self._publish_depth()
                    return
            self._phase = "cancel"
            self._process_cancels()
            self._phase = "deadline"
            self._enforce_deadlines()
            done: list[Ticket] = []
            self._phase = "single_forward"
            sched._serve_single_forwards(done)
            self._phase = "admit"
            before_admitted = len(sched._slot_tickets)
            t0 = time.perf_counter()
            sched._admit_arrivals(loop, done)
            if len(sched._slot_tickets) > before_admitted:
                self.engine.stats.record_prefill_cost(
                    time.perf_counter() - t0
                )
            for ticket in done:
                # single-forward completions + admission-time failures
                self._finalize(ticket)
            self._publish_depth()
            if loop.resident:
                self._phase = "decode"
                steps0 = loop.steps_run
                t0 = time.perf_counter()
                # retirement/streaming happens inside _on_segment; the
                # return value is already handled
                loop.step_fused(self._pick_window())
                dt = time.perf_counter() - t0
                if loop.steps_run > steps0:
                    self.engine.stats.record_step_cost(
                        dt / (loop.steps_run - steps0)
                    )
            self._healthy_boundaries += 1
            if self._restarts and self._healthy_boundaries >= self.HEAL_AFTER:
                # sustained health heals the restart budget: transient
                # storms are forgiven, only persistent crash loops fail
                self._restarts = 0
                self._healthy_boundaries = 0

    # --------------------------------------------------- supervisor internals
    def _recover(self, exc: BaseException) -> bool:
        """Crash containment: blame, quarantine, rebuild, requeue.

        Runs on the engine thread after a crash escaped the serve loop.
        Returns True to re-enter the loop with a fresh scheduler/decode
        loop and every surviving ticket requeued from its admission
        record, False when the restart budget is exhausted (the door is
        failed; every pending ticket already got its terminal error)."""
        phase = self._phase
        self._restarts += 1
        self._healthy_boundaries = 0
        self.engine.stats.record_engine_restart()
        if self._restarts > self.max_restarts:
            self._fail_door(
                f"engine failed permanently after {self.max_restarts} "
                f"restarts (last crash in phase {phase!r}: "
                f"{type(exc).__name__}: {exc})",
                "engine_failed",
            )
            return False
        time.sleep(self.restart_backoff_s * (2 ** (self._restarts - 1)))
        # blame the residents of the crashed loop: a ticket resident
        # across quarantine_after crashes is the likely offender —
        # quarantine it instead of requeueing it into the next crash
        quarantined: set = set()
        for sr in list(self.loop.resident):
            n = self._crash_blame.get(sr.request_id, 0) + 1
            self._crash_blame[sr.request_id] = n
            if n >= self.quarantine_after:
                quarantined.add(sr.request_id)
        # rebuild the execution state from scratch — the crashed loop's
        # slot table / page pool may be mid-mutation and unrecoverable
        self.sched = CoTenantScheduler(
            self.engine,
            policy="continuous",
            num_slots=self.num_slots,
            slot_max_len=self.slot_max_len,
            pad_slack=self.pad_slack,
        )
        self.loop = self.engine.start_decode_loop(
            self.num_slots, self.slot_max_len, on_segment=self._on_segment
        )
        self.sched._loop = self.loop
        # requeue every surviving in-flight ticket from its admission
        # record, in submit order; inbox entries are untouched (they move
        # at the next boundary as usual).  Deterministic re-execution +
        # the _Progress streaming cursors make the restart invisible to
        # streaming clients: already-chunked tokens are skipped, the
        # continuation is bit-exact.
        with self._lock:
            inbox_ids = {req.request_id for req, *_ in self._inbox}
            progs = [
                p for rid, p in self._progress.items()
                if rid not in inbox_ids
            ]
        progs.sort(key=lambda p: p.ticket.submit_time)
        now = time.perf_counter()
        for prog in progs:
            rid = prog.req.request_id
            if rid in quarantined:
                prog.ticket.finish_time = now
                prog.ticket.error = (
                    f"quarantined after {self._crash_blame[rid]} engine "
                    f"crashes while resident (last in phase {phase!r}: "
                    f"{type(exc).__name__}: {exc})"
                )
                prog.ticket.error_code = "engine_restart"
                self._finalize(prog.ticket)
            else:
                self.sched.queue.append((prog.req, prog.ticket))
                self.engine.stats.record_ticket_requeued()
        self._publish_depth()
        return True

    def _fail_door(self, message: str, code: str) -> None:
        """Terminal door failure: every pending ticket gets a structured
        error chunk, every blocked poller wakes, later submissions are
        refused with this code.  Nothing ever hangs.  Safe from the
        engine thread AND the watchdog (idempotent terminal pushes)."""
        payload = {"error": message, "code": code}
        with self._wake:
            if self._failed is None:
                self._failed = payload
            channels = list(self._channels.values())
            progs = list(self._progress.values())
            self._progress.clear()
            self._inbox = []
            self._cancels.clear()
            self._sched_backlog = 0
            self._wake.notify_all()
        now = time.perf_counter()
        for prog in progs:
            t = prog.ticket
            if t.finish_time is None:
                t.finish_time = now
                t.error = message
                t.error_code = code
                self._record_ticket(t, "error")
        for chan in channels:
            chan.push_final_once("error", dict(payload))

    def _watch(self) -> None:
        """Watchdog thread: detect a STUCK engine step (not just a dead
        thread) via the boundary heartbeat and fail the door so blocked
        pollers get their structured error immediately instead of
        timing out one by one."""
        period = max(0.005, min(self.stall_timeout_s / 4.0, 0.05))
        while True:
            time.sleep(period)
            if (self._closing or self._failed is not None
                    or self._exc is not None):
                return
            if not self._thread.is_alive():
                return  # the supervisor already handled its own exit
            stalled = time.monotonic() - self._heartbeat
            if stalled > self.stall_timeout_s:
                self._fail_door(
                    f"engine step stalled for {stalled:.2f}s in phase "
                    f"{self._phase!r} (stall_timeout_s="
                    f"{self.stall_timeout_s})",
                    "engine_stalled",
                )
                return

    def _process_cancels(self) -> None:
        """Cooperative cancellation at a step boundary (engine thread):
        resident tickets are evicted (rows + KV pages freed), queued
        tickets are dequeued; either way the channel terminates with
        ``code="cancelled"``."""
        with self._lock:
            cancels, self._cancels = self._cancels, set()
        for rid in cancels:
            self._kill_ticket(rid, "cancelled by client", "cancelled")
            self.engine.stats.record_cancellation()

    def _enforce_deadlines(self) -> None:
        """Server-side ``deadline_ms`` enforcement at a step boundary:
        expired residents are evicted mid-decode (their rows and KV pages
        free immediately for co-tenants), expired queued tickets fail
        before burning a prefill."""
        now = time.perf_counter()
        expired: list = []
        for sr in list(self.loop.resident):
            prog = self._progress.get(sr.request_id)
            if (prog is not None and prog.deadline is not None
                    and now > prog.deadline):
                expired.append(sr.request_id)
        for req, ticket in list(self.sched.queue):
            prog = self._progress.get(req.request_id)
            if (prog is not None and prog.deadline is not None
                    and now > prog.deadline):
                expired.append(req.request_id)
        for rid in expired:
            self._kill_ticket(rid, "deadline_ms exceeded", "deadline")
            self.engine.stats.record_deadline_eviction()

    def _kill_ticket(self, rid: Any, error: str, code: str) -> None:
        """Terminate one live ticket (engine thread, between windows):
        evict it if resident, dequeue it if still queued, then finalize
        with the structured error."""
        sr = self.loop.evict(rid, error, code=code)
        if sr is not None:
            ticket = self.sched._finish_slot(sr)
            self.sched.completed.append(ticket)
            self._finalize(ticket)
            return
        for i, (req, ticket) in enumerate(self.sched.queue):
            if req.request_id == rid:
                del self.sched.queue[i]
                ticket.finish_time = time.perf_counter()
                ticket.error = error
                ticket.error_code = code
                self._finalize(ticket)
                return
        # not queued, not resident: it may still sit in the inbox (moved
        # next boundary) or have terminated already — check progress
        with self._lock:
            prog = self._progress.get(rid)
            inbox_hit = None
            for i, entry in enumerate(self._inbox):
                if entry[0].request_id == rid:
                    inbox_hit = i
                    break
            if inbox_hit is not None:
                del self._inbox[inbox_hit]
        if prog is not None:
            ticket = prog.ticket
            ticket.finish_time = time.perf_counter()
            ticket.error = error
            ticket.error_code = code
            self._finalize(ticket)

    def _publish_depth(self) -> None:
        with self._lock:
            self._sched_backlog = len(self.sched.queue)
            depth = len(self._inbox) + self._sched_backlog
        self.engine.stats.record_queue_depth(depth)

    def _reject_pending(self, moved) -> None:
        """Closing: everything not yet resident gets a structured error
        chunk; residents keep decoding to completion."""
        sched = self.sched
        queued = [(r, t) for r, t in sched.queue]
        sched.queue = []
        for req, ticket, *_ in moved:
            queued.append((req, ticket))
        for req, ticket in queued:
            ticket.finish_time = time.perf_counter()
            ticket.error = "front door closed before execution"
            ticket.error_code = "closed"
            self._finalize(ticket)

    # ------------------------------------------------------------- streaming
    def _on_segment(self, k: int, retired: list) -> None:
        """DecodeLoop segment hook (engine thread): stream fresh state for
        every resident, then finalize the retirements."""
        for sr in list(self.loop.resident) + [
            sr for sr in retired if sr.error is None
        ]:
            prog = self._progress.get(sr.request_id)
            if prog is None or not prog.stream:
                continue
            self._emit_increments(sr, prog)
        for sr in retired:
            ticket = self.sched._finish_slot(sr)
            self.sched.completed.append(ticket)
            self._finalize(ticket)

    def _emit_increments(self, sr, prog: _Progress) -> None:
        chan = self._channels.get(sr.request_id)
        if chan is None or chan.closed:
            return
        sent = 0
        if len(sr.new_tokens) > prog.steps:
            fresh = sr.new_tokens[prog.steps:]
            chan.push("tokens", {
                "tokens": np.stack([np.asarray(t) for t in fresh], axis=1)
            })
            prog.steps = len(sr.new_tokens)
            sent += 1
            if prog.ticket.first_token_time is None:
                prog.ticket.first_token_time = time.perf_counter()
        fresh_saves = {
            k: np.asarray(v) for k, v in sr.saves.items()
            if k not in prog.save_keys
        }
        if fresh_saves:
            chan.push("saves", fresh_saves)
            prog.save_keys.update(fresh_saves)
            sent += 1
        if len(sr.logs) > prog.logs:
            chan.push("logs", [
                (int(n), np.asarray(v)) for n, v in sr.logs[prog.logs:]
            ])
            prog.logs = len(sr.logs)
            sent += 1
        if sent:
            self.engine.stats.record_stream_chunks(sent)

    def _finalize(self, ticket: Ticket) -> None:
        """Terminal chunk + stats for one finished ticket (engine thread).
        Terminal pushes are idempotent (``push_final_once``): the
        watchdog's fail-everything path may have already closed the
        channel from its own thread."""
        with self._lock:
            prog = self._progress.pop(ticket.request_id, None)
            chan = self._channels.get(ticket.request_id)
        if chan is None or chan.closed:
            return
        if ticket.error is not None:
            pushed = chan.push_final_once("error", {
                "error": ticket.error,
                "code": ticket.error_code or "error",
            })
            if pushed is not None:
                self.engine.stats.record_stream_chunks(1)
                self._record_ticket(ticket, "error")
            return
        result = dict(ticket.result or {})
        if prog is not None and prog.stream and not prog.single_forward:
            # streamed tickets already received tokens/saves/logs
            # incrementally — the done chunk carries only the remainder
            result.pop("tokens", None)
            for k in prog.save_keys:
                result.pop(k, None)
            logs = result.pop(LOGS_KEY, [])
            _attach_logs(result, logs[prog.logs:])
        if ticket.first_token_time is None:
            ticket.first_token_time = ticket.finish_time
        if chan.push_final_once("done", result) is not None:
            self.engine.stats.record_stream_chunks(1)
            self._record_ticket(ticket, "ok")

    def _record_ticket(self, ticket: Ticket, status: str) -> None:
        self.engine.stats.record_ticket({
            "request_id": ticket.request_id,
            "status": status,
            "queue_wait": ticket.queue_wait,
            "time_to_first_token": ticket.time_to_first_token,
            "response_time": ticket.response_time,
        })

    # --------------------------------------------------------------- results
    def take(
        self, ticket_id: Any, *, blocking: bool = False,
        timeout: float | None = None, since: int | None = None,
    ) -> tuple[list[dict], bool]:
        """Drain a ticket's pending chunks (wire form).  ``blocking`` waits
        for at least one chunk or termination (this blocks the CLIENT's
        thread — the engine thread keeps stepping).  Returns
        ``(chunks, done)``.

        ``since`` switches to IDEMPOTENT cursor reads: every chunk with
        ``seq >= since`` is (re-)delivered from channel history, so a
        client whose previous reply was lost in flight just re-requests
        the same cursor.  Terminal channels are parked in a bounded done
        history rather than forgotten, so redelivery keeps working after
        completion; only tickets never seen (or long since evicted from
        the history) raise ``KeyError``."""
        with self._lock:
            chan = self._channels.get(ticket_id)
            if chan is None:
                chan = self._done_hist.get(ticket_id)
        if chan is None:
            raise KeyError(f"unknown ticket {ticket_id!r}")
        if since is not None:
            chunks, done = chan.read_since(
                since, blocking=blocking, timeout=timeout
            )
        elif blocking:
            chunks, done = chan.get(timeout)
        else:
            chunks, done = chan.drain()
        if done:
            with self._lock:
                if self._channels.pop(ticket_id, None) is not None:
                    self._done_hist[ticket_id] = chan
                    while len(self._done_hist) > self.DONE_HISTORY:
                        self._done_hist.popitem(last=False)
                elif ticket_id in self._done_hist:
                    self._done_hist.move_to_end(ticket_id)
        return [c.to_wire() for c in chunks], done

    def result(self, ticket_id: Any, timeout: float | None = None) -> dict:
        """Convenience: block until the ticket completes, assemble the full
        result (local callers / tests; the wire path uses ``take``)."""
        from repro.serving.stream import assemble_result, check_frames

        deadline = None if timeout is None else time.perf_counter() + timeout
        chunks: list[dict] = []
        while True:
            left = None if deadline is None else deadline - time.perf_counter()
            if left is not None and left <= 0:
                raise TimeoutError(f"ticket {ticket_id!r} still running")
            got, done = self.take(ticket_id, blocking=True, timeout=left)
            chunks.extend(got)
            if done:
                break
        check_frames(chunks, ticket_id)
        result, logs = assemble_result(chunks)
        if logs:
            _attach_logs(result, logs)
        return result

    # -------------------------------------------------------------- shutdown
    def close(self, timeout: float | None = 60.0) -> None:
        """Drain residents, reject queued work with a structured error,
        join the engine (and watchdog) threads.  Idempotent; submit()
        afterwards raises ``AdmissionError(code="closed")``.

        A SUPERVISED failure (restart budget exhausted, watchdog stall)
        does not raise here — every affected ticket already received its
        structured error, which is the contract.  Only a bug in the
        supervisor itself re-raises."""
        with self._wake:
            self._closing = True
            self._wake.notify_all()
        self._thread.join(timeout)
        if self._watchdog is not None:
            self._watchdog.join(timeout)
        if self._thread.is_alive():
            raise RuntimeError("front door engine thread failed to stop")
        if self._exc is not None:
            raise RuntimeError(
                f"front door engine thread died: {self._exc!r}"
            ) from self._exc

    def __enter__(self) -> "FrontDoor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
