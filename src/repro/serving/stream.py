"""Streaming result channels for the live NDIF front door.

A submitted request gets a :class:`StreamChannel`: the engine thread pushes
:class:`Chunk`s onto it as the decode loop crosses segment boundaries —
tokens per fused window, saves and ``log()`` values as they flush — and the
client side drains them through the wire ``poll``/``stream`` kinds (see
repro.serving.frontdoor / server).  Channels are the ONLY hand-off between
the engine thread and client threads, so everything here is lock-guarded
and every chunk carries a per-ticket strictly-increasing ``seq`` — frame
integrity under concurrent polling is checkable by the receiver
(:func:`check_frames`).

Chunk kinds:

  ``tokens``  payload ``{"tokens": (rows, j) int32}`` — j newly decoded
              steps, concatenating bit-exact to the solo result;
  ``saves``   payload ``{name: value, ...}`` — saves that appeared since
              the previous chunk;
  ``logs``    payload ``[(node_id, value), ...]`` — log() flushes;
  ``done``    payload the FINAL result dict (batch clients get everything
              here; streaming clients get logits + anything not yet
              streamed), always the last chunk, ``final=True``;
  ``error``   payload ``{"error": msg, "code": str}``, terminal like
              ``done`` — ``code`` is the machine-readable failure class
              ("deadline" | "cancelled" | "engine_restart" |
              "engine_failed" | "closed" | "error") that
              :func:`assemble_result` surfaces as :class:`TicketError`.

Fault tolerance: every chunk ever pushed is retained in the channel's
``history`` until the channel is dropped, and :meth:`StreamChannel.
read_since` re-delivers from an arbitrary ``seq`` cursor.  This makes
``poll``/``stream`` IDEMPOTENT reads: a client whose reply was lost in
flight re-requests the same cursor and loses nothing — the transport only
has to be at-least-once, exactly-once delivery is reconstructed from the
seq numbers (duplicates drop client-side).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

import numpy as np

__all__ = ["Chunk", "StreamChannel", "TicketError", "assemble_result",
           "check_frames"]


class TicketError(RuntimeError):
    """A ticket terminated with a structured error chunk.

    ``payload`` is the error chunk's payload; ``code`` distinguishes the
    failure class machine-readably (``deadline``, ``cancelled``,
    ``engine_restart``, ``engine_failed``, ``closed``, plain ``error``
    for per-request execution failures) so retry/deadline logic never
    string-matches messages.  Subclasses ``RuntimeError`` for
    compatibility with pre-fault-tolerance callers."""

    def __init__(self, payload: dict) -> None:
        super().__init__(payload.get("error", "ticket failed"))
        self.payload = dict(payload)
        self.code = payload.get("code", "error")


@dataclasses.dataclass
class Chunk:
    """One framed increment of a ticket's result stream."""

    ticket: Any           # the request id this chunk belongs to
    seq: int              # strictly increasing per ticket, from 0
    kind: str             # tokens | saves | logs | done | error
    payload: Any
    final: bool = False   # True on the terminal done/error chunk

    def to_wire(self) -> dict:
        return {
            "ticket": self.ticket,
            "seq": int(self.seq),
            "kind": self.kind,
            "payload": self.payload,
            "final": bool(self.final),
        }


class StreamChannel:
    """Thread-safe chunk queue between the engine thread and one client.

    The engine thread is the only producer (:meth:`push` / :meth:`close`);
    any client thread may consume.  ``get`` blocks (condition variable, no
    spinning) until at least one chunk or the terminal state arrives;
    ``drain`` is the non-blocking poll.  Sequence numbers are assigned
    HERE, under the lock, so concurrent producers could never interleave
    two chunks with the same seq.
    """

    def __init__(self, ticket: Any) -> None:
        self.ticket = ticket
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)
        self._chunks: list[Chunk] = []
        # every chunk ever pushed, in seq order — read_since() re-delivers
        # from here, so a reply lost in flight is never data loss
        self.history: list[Chunk] = []
        self._seq = 0
        self._closed = False

    def push(self, kind: str, payload: Any, *, final: bool = False) -> Chunk:
        with self._ready:
            if self._closed:
                raise RuntimeError(
                    f"channel for ticket {self.ticket!r} is closed"
                )
            chunk = Chunk(self.ticket, self._seq, kind, payload, final)
            self._seq += 1
            self._chunks.append(chunk)
            self.history.append(chunk)
            if final:
                self._closed = True
            self._ready.notify_all()
            return chunk

    def push_final_once(self, kind: str, payload: Any) -> Chunk | None:
        """Idempotent terminal push: a no-op on an already-terminal channel.

        The supervisor's fail-everything path and a concurrent
        ``take()``'s dead-door check may race to deliver the terminal
        error; whichever arrives second must not raise."""
        with self._ready:
            if self._closed:
                return None
            chunk = Chunk(self.ticket, self._seq, kind, payload, True)
            self._seq += 1
            self._chunks.append(chunk)
            self.history.append(chunk)
            self._closed = True
            self._ready.notify_all()
            return chunk

    def close(self) -> None:
        """Terminal-state close without a chunk (defensive; the front door
        normally closes by pushing a final done/error chunk)."""
        with self._ready:
            self._closed = True
            self._ready.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def drain(self) -> tuple[list[Chunk], bool]:
        """Non-blocking: everything queued right now + whether the stream
        has terminated (no more chunks will ever arrive once the returned
        flag is True and the list drained)."""
        with self._lock:
            out, self._chunks = self._chunks, []
            return out, self._closed and not self._chunks

    def get(self, timeout: float | None = None) -> tuple[list[Chunk], bool]:
        """Block until at least one chunk (or termination), then drain.

        Returns ``(chunks, done)``; an empty list with ``done=False`` means
        the timeout elapsed first.
        """
        with self._ready:
            if not self._chunks and not self._closed:
                self._ready.wait(timeout)
            out, self._chunks = self._chunks, []
            return out, self._closed and not self._chunks

    def read_since(
        self,
        since: int,
        *,
        blocking: bool = False,
        timeout: float | None = None,
    ) -> tuple[list[Chunk], bool]:
        """Cursor read: every chunk with ``seq >= since``, from history.

        Unlike :meth:`drain`/:meth:`get` this does not consume — the same
        cursor re-reads the same chunks, which is what makes retried
        polls idempotent.  Returns ``(chunks, done)`` where ``done`` means
        the terminal chunk has been pushed (it is included in ``chunks``
        whenever ``since`` reaches back far enough).  With ``blocking``,
        waits up to ``timeout`` for something new past the cursor.
        """
        since = max(0, int(since))
        with self._ready:
            if blocking and self._seq <= since and not self._closed:
                self._ready.wait(timeout)
            out = [c for c in self.history if c.seq >= since]
            return out, self._closed


def check_frames(chunks: list[dict], ticket: Any) -> None:
    """Receiver-side frame-integrity check for one ticket's chunk list:
    every chunk belongs to the ticket, seqs are gapless from 0, and only
    the last chunk is terminal.  Raises ``ValueError`` on corruption —
    cross-attributed chunks or torn frames under concurrent polling."""
    for i, c in enumerate(chunks):
        if c["ticket"] != ticket:
            raise ValueError(
                f"frame corruption: chunk for ticket {c['ticket']!r} "
                f"delivered to ticket {ticket!r}"
            )
        if c["seq"] != i:
            raise ValueError(
                f"frame corruption: ticket {ticket!r} seq {c['seq']} "
                f"at position {i}"
            )
        if c["final"] != (i == len(chunks) - 1):
            raise ValueError(
                f"frame corruption: ticket {ticket!r} terminal chunk "
                f"misplaced at {i}/{len(chunks)}"
            )


def assemble_result(chunks: list[dict]) -> tuple[dict, list]:
    """Concatenate one ticket's streamed chunks into the batch-form result.

    Returns ``(result, logs)`` where ``result`` matches what a synchronous
    ``generate``/``trace`` roundtrip returns — token chunks concatenate
    along the step axis (bit-exact vs solo: fused window splits are
    bit-identical), saves merge in arrival order, the done chunk
    contributes logits and any remainder.  Raises :class:`TicketError`
    (a ``RuntimeError`` subclass carrying the payload and ``code``) on an
    error chunk.
    """
    result: dict[str, Any] = {}
    logs: list = []
    token_parts: list[np.ndarray] = []
    for c in chunks:
        kind, payload = c["kind"], c["payload"]
        if kind == "error":
            raise TicketError(payload)
        if kind == "tokens":
            token_parts.append(np.asarray(payload["tokens"]))
        elif kind == "saves":
            result.update(payload)
        elif kind == "logs":
            logs.extend((int(n), v) for n, v in payload)
        elif kind == "done":
            for k, v in (payload or {}).items():
                if k == "__logs__":
                    logs.extend((int(n), v_) for n, v_ in v)
                else:
                    result[k] = v
    if token_parts:
        result["tokens"] = np.concatenate(token_parts, axis=1)
    return result, logs
