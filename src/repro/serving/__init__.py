"""NDIF-style shared inference service (paper §3.3)."""
from repro.serving.client import NDIFClient
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request, Ticket
from repro.serving.server import NDIFServer
from repro.serving.transport import LoopbackTransport

__all__ = [
    "NDIFClient",
    "InferenceEngine",
    "CoTenantScheduler",
    "Request",
    "Ticket",
    "NDIFServer",
    "LoopbackTransport",
]
