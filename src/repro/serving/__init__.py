"""NDIF-style shared inference service (paper §3.3)."""
from repro.serving.client import AdmissionRefused, LiveTicket, NDIFClient
from repro.serving.engine import InferenceEngine
from repro.serving.frontdoor import AdmissionError, FrontDoor
from repro.serving.scheduler import CoTenantScheduler, Request, Ticket
from repro.serving.server import NDIFServer
from repro.serving.stream import Chunk, StreamChannel
from repro.serving.transport import LoopbackTransport, TransportSession

__all__ = [
    "AdmissionError",
    "AdmissionRefused",
    "Chunk",
    "CoTenantScheduler",
    "FrontDoor",
    "InferenceEngine",
    "LiveTicket",
    "LoopbackTransport",
    "NDIFClient",
    "NDIFServer",
    "Request",
    "StreamChannel",
    "Ticket",
    "TransportSession",
]
