"""NDIF-style shared inference service (paper §3.3)."""
from repro.serving.client import (
    AdmissionRefused,
    LiveTicket,
    NDIFClient,
    RetryPolicy,
)
from repro.serving.engine import InferenceEngine
from repro.serving.faults import FaultError, FaultPlan, FaultSpec
from repro.serving.frontdoor import AdmissionError, FrontDoor
from repro.serving.scheduler import CoTenantScheduler, Request, Ticket
from repro.serving.server import NDIFServer
from repro.serving.stream import Chunk, StreamChannel, TicketError
from repro.serving.transport import (
    LoopbackTransport,
    TransportError,
    TransportSession,
)

__all__ = [
    "AdmissionError",
    "AdmissionRefused",
    "Chunk",
    "CoTenantScheduler",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "FrontDoor",
    "InferenceEngine",
    "LiveTicket",
    "LoopbackTransport",
    "NDIFClient",
    "NDIFServer",
    "Request",
    "RetryPolicy",
    "StreamChannel",
    "Ticket",
    "TicketError",
    "TransportError",
    "TransportSession",
]
