"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["reference_attention", "reference_ssd"]

NEG_INF = -1e30


def reference_attention(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, K, T, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, S, hd).astype(jnp.float32)
    s = jnp.einsum("bkgsh,bkth->bkgst", qg, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    d = q_pos - k_pos
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bkth->bkgsh", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)


def reference_ssd(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (post-softplus)
    A: jax.Array,   # (H,) decay rate > 0
    B_: jax.Array,  # (B, S, N)
    C: jax.Array,   # (B, S, N)
    D: jax.Array,   # (H,)
) -> tuple[jax.Array, jax.Array]:
    """Sequential (exact) SSD recurrence — the slowest, clearest oracle.

    h[t] = h[t-1]·exp(-dt[t]·A) + dt[t]·x[t]⊗B[t];  y[t] = C[t]·h[t] + D·x[t]
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, Pd = x.shape
    N = B_.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(-dtt * A[None, :])  # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt
        )
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B_.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + x.astype(jnp.float32) * D[None, None, :, None]
    return y, h
