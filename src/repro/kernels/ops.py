"""jit'd kernel wrappers with runtime-appropriate dispatch.

On the CPU container the kernels execute in interpret mode (Python
evaluation of the kernel body — correctness only); on TPU they compile to
Mosaic.  ``repro.models.common`` calls these when
``set_attention_impl("pallas")`` is active.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import (
    flash_attention_kernel_call,
    paged_flash_attention_kernel_call,
)
from repro.kernels.ssd_scan import ssd_scan_kernel_call

__all__ = ["flash_attention", "paged_flash_attention", "ssd_scan",
           "interpret_mode"]


def interpret_mode() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def flash_attention(
    qg: jax.Array,  # (B, S, K, G, hd) — grouped layout from models/common
    k: jax.Array,   # (B, T, K, hd)
    v: jax.Array,
    *,
    q_pos=None,
    k_pos=None,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Returns (B, S, K, G, hd) to match the chunked/dense paths.

    ``q_pos``/``k_pos`` (broadcastable to (B, S)/(B, T) int32) switch the
    kernel to position-delivered masking: PAD-sentinel keys (right-padded
    ragged rows, unwritten cache slots) are masked for every query, so
    ``set_attention_impl("pallas")`` serves ``batch["lengths"]`` traffic
    with the same semantics as the XLA ``_mask_bias`` paths."""
    B, S, K, G, hd = qg.shape
    T = k.shape[1]
    q = qg.transpose(0, 2, 3, 1, 4).reshape(B, K * G, S, hd)  # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B, K, T, hd)
    vt = v.transpose(0, 2, 1, 3)
    qp = kp = None
    if q_pos is not None or k_pos is not None:
        qp = jnp.broadcast_to(
            jnp.asarray(q_pos if q_pos is not None else jnp.arange(S),
                        jnp.int32), (B, S))
        kp = jnp.broadcast_to(
            jnp.asarray(k_pos if k_pos is not None else jnp.arange(T),
                        jnp.int32), (B, T))
    out = flash_attention_kernel_call(
        q, kt, vt, qp, kp, causal=causal, window=window,
        interpret=interpret_mode(),
    )
    return out.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4)


def paged_flash_attention(
    qg: jax.Array,            # (B, S, K, G, hd) — grouped layout
    k_pool: jax.Array,        # (P, page_size, K, hd) — one layer's pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, n_blocks) int32
    q_pos: jax.Array,         # (B, S) int32
    k_pos: jax.Array,         # (B, n_blocks*page_size) int32 logical
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Attention over a paged KV pool WITHOUT materializing the dense
    view: the pallas kernel walks each row's block table and DMAs pages
    directly (scalar-prefetch index maps).  Layout mirrors
    :func:`flash_attention` on the query side; pools arrive in the models'
    page layout ``(page, slot, kv_head, hd)``."""
    B, S, K, G, hd = qg.shape
    q = qg.transpose(0, 2, 3, 1, 4).reshape(B, K * G, S, hd)  # (B, H, S, hd)
    kp_ = k_pool.transpose(0, 2, 1, 3)  # (P, K, ps, hd)
    vp_ = v_pool.transpose(0, 2, 1, 3)
    out = paged_flash_attention_kernel_call(
        q, kp_, vp_, block_tables,
        jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B, S)),
        jnp.asarray(k_pos, jnp.int32),
        causal=causal, window=window, interpret=interpret_mode(),
    )
    return out.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4)


def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,   # (H,)
    B_: jax.Array,  # (B, S, N)
    C: jax.Array,   # (B, S, N)
    D: jax.Array,   # (H,)
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    return ssd_scan_kernel_call(
        x, dt, A, B_, C, D, chunk=chunk, interpret=interpret_mode()
    )
