"""Pallas TPU kernel for the Mamba2 SSD chunked scan [arXiv:2405.21060 §6].

TPU-native design: grid ``(B, n_chunks)`` with the chunk axis minor-most —
TPU executes the minor axis sequentially, so the inter-chunk recurrent state
(H, P, N) lives in VMEM scratch and is carried across chunks with zero HBM
traffic (the XLA fallback pays an HBM round-trip per chunk for the scan
carry).  Within a chunk everything is phrased as 2-D / head-batched
``dot_general`` so the quadratic intra-chunk term runs on the MXU:

    cb    = C · Bᵀ                          (L,N)·(N,L)     MXU
    y_diag[h] = (cb ∘ decay[h] ∘ dt[h]) · x[h]   per-head (L,L)·(L,P)  MXU
    state upd = (dt ∘ tail ∘ x) ᵀ · B       (H·P,L)·(L,N)   MXU
    y_off = C · stateᵀ                      (L,N)·(N,H·P)   MXU

VMEM working set at defaults (chunk=128, H=64, P=64, N=128):
x 2 MB + decay (L,L,H) 4 MB + state 2 MB + y 2 MB ≈ 11 MB < 16 MB VMEM.
Validated against ``ref.reference_ssd`` (the exact sequential recurrence)
in interpret mode over shape sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_scan_kernel_call"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
            y_ref, final_ref, state_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (L, H, P)
    dt = dt_ref[0].astype(jnp.float32)      # (L, H)
    A = a_ref[...].astype(jnp.float32)      # (H,)
    Bm = b_ref[0].astype(jnp.float32)       # (L, N)
    Cm = c_ref[0].astype(jnp.float32)       # (L, N)
    D = d_ref[...].astype(jnp.float32)      # (H,)
    L, H, P = x.shape
    N = Bm.shape[-1]

    dA = dt * A[None, :]                    # (L, H)
    cum = jnp.cumsum(dA, axis=0)            # (L, H)
    total = cum[-1, :]                      # (H,)

    # ---- intra-chunk (quadratic) term --------------------------------
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (L, L) = C·Bᵀ
    seg = cum[:, None, :] - cum[None, :, :]  # (L, L, H)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # mask before exp (upper-triangle seg < 0 would overflow to inf)
    seg = jnp.where(tri[:, :, None], seg, 0.0)
    decay = jnp.exp(-seg) * jnp.where(tri[:, :, None], 1.0, 0.0)  # (L, L, H)
    w = cb[:, :, None] * decay * dt[None, :, :]              # (L, L, H)
    # per-head batched matmul: (H, L, L) x (H, L, P) -> (H, L, P)
    wh = w.transpose(2, 0, 1)
    xh = x.transpose(1, 0, 2)
    y_diag = jax.lax.dot_general(
        wh, xh, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)                     # (L, H, P)

    # ---- inter-chunk: contribution of the carried state ---------------
    state = state_ref[...]                   # (H, P, N)
    g = jnp.exp(-cum)                        # (L, H)
    t1 = jax.lax.dot_general(
        Cm, state.reshape(H * P, N), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(L, H, P)
    y_off = t1 * g[:, :, None]

    y = y_diag + y_off + x * D[None, :, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # ---- state update --------------------------------------------------
    tail = jnp.exp(-(total[None, :] - cum))  # (L, H)
    u = (dt * tail)[:, :, None] * x          # (L, H, P)
    upd = jax.lax.dot_general(
        u.transpose(1, 2, 0).reshape(H * P, L), Bm,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(H, P, N)
    state_ref[...] = state * jnp.exp(-total)[:, None, None] + upd

    @pl.when(ic == n_chunks - 1)
    def _finish():
        final_ref[0] = state_ref[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan_kernel_call(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,   # (H,)
    B_: jax.Array,  # (B, S, N)
    C: jax.Array,   # (B, S, N)
    D: jax.Array,   # (H,)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    L = min(chunk, S)
    Sp = -(-S // L) * L
    if Sp != S:
        pad = Sp - S
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    n_chunks = Sp // L

    kernel = functools.partial(_kernel, chunk=L, n_chunks=n_chunks)
    y, final = pl.pallas_call(
        kernel,
        grid=(Bb, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, L, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, Sp, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B_, C, D)
    return y[:, :S], final


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
