"""Pallas TPU flash attention (causal / sliding-window, GQA-aware).

TPU-native design (HARDWARE ADAPTATION note — this is *not* a CUDA port):

  * Grid ``(B, H, n_q_blocks, n_kv_blocks)`` with the KV-block axis
    minor-most: TPU grids execute sequentially over the last axis, so the
    online-softmax running state (m, l, acc) lives in VMEM scratch and is
    carried across KV blocks without HBM round-trips — the accumulator
    never touches HBM (this is precisely the traffic the XLA ``lax.scan``
    fallback pays; see EXPERIMENTS.md §Perf).
  * Block shapes default to (Bq, hd) = (256, 128) / (Bk, hd) = (512, 128):
    MXU-aligned (multiples of 128 on the contracting/lane dims), VMEM
    working set ≈ Bq·hd (q) + Bk·hd·2 (k,v) + Bq·Bk (scores) + Bq·hd (acc)
    ≈ 1.3 MB fp32 at defaults — comfortably under ~16 MB VMEM.
  * GQA is folded into the index map: query head h reads KV head h // G,
    so no KV replication in HBM.
  * Causal/window masking is positional arithmetic on block offsets; the
    (q_block, kv_block) pairs that are fully masked under causality are
    skipped via ``@pl.when`` on the compute (loads are pipelined by the
    grid either way).
  * Ragged masking: with explicit per-row ``q_pos``/``k_pos`` arrays the
    mask is computed from the DELIVERED positions instead of rebuilt iota —
    keys at sentinel positions (>= ``PAD_LIMIT``: right-padded rows,
    unwritten cache slots) are masked for every query, exactly like the
    XLA paths' ``_mask_bias``.  This is what lets
    ``set_attention_impl("pallas")`` serve padded co-tenant batches
    (``batch["lengths"]``).  Positions are arbitrary per row, so the
    static causal block skip is disabled on this variant (a per-row length
    hint could re-enable it — TPU perf follow-up).

Validated against ``ref.reference_attention`` in interpret mode over shape/
dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel_call", "paged_flash_attention_kernel_call"]

NEG_INF = -1e30
# Keep in sync with repro.models.common.PAD_LIMIT: any key whose position
# is >= this is a padding/unwritten sentinel and must never be attended.
PAD_LIMIT = (2**31 - 1) // 4


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bk: int, n_kv: int, seq_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        d = q_pos - k_pos
        ok = k_pos < seq_kv
        if causal:
            ok &= d >= 0
        if window is not None:
            ok &= d < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    if causal:
        # Skip KV blocks strictly in the causal future of this q block.
        pl.when(k_start <= q_start + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _kernel_pos(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
                m_ref, l_ref, acc_ref, *,
                scale: float, causal: bool, window: int | None,
                bq: int, bk: int, n_kv: int):
    """Position-aware variant: masks from delivered q/k positions.

    Keys at sentinel positions (>= PAD_LIMIT) are masked for EVERY query —
    causal or not — so right-padded batch rows are provably inert, matching
    the XLA paths' ``_mask_bias``.  No static causal block skip: positions
    are arbitrary per row."""
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    qp = qp_ref[0]  # (bq,) int32
    kp = kp_ref[0]  # (bk,) int32
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # (bq, bk)

    d = qp[:, None] - kp[None, :]
    ok = jnp.broadcast_to((kp < PAD_LIMIT)[None, :], (bq, bk))
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_kernel_call(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, K, T, hd)
    v: jax.Array,  # (B, K, T, hd)
    q_pos: jax.Array | None = None,  # (B, S) int32 — enables ragged masking
    k_pos: jax.Array | None = None,  # (B, T) int32
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention.  Without positions the mask is rebuilt from block
    iota (static causal block skip intact — direct kernel callers only);
    with ``q_pos``/``k_pos`` the mask honours delivered positions,
    including the PAD sentinels of right-padded ragged batches.  Model
    paths always deliver positions (their position arrays may carry
    sentinels), so they take the positional variant — re-enabling the
    causal skip there needs a per-row length hint (ROADMAP note)."""
    if (q_pos is None) != (k_pos is None):
        raise ValueError("q_pos and k_pos must be provided together")
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    bq = min(block_q, S)
    bk = min(block_k, T)
    # pad S/T to block multiples
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    n_q = Sp // bq
    n_kv = Tp // bk

    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bk, hd), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)
    )
    out_spec = pl.BlockSpec(
        (1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)
    )
    scratch = [
        _vmem((bq,), jnp.float32),
        _vmem((bq,), jnp.float32),
        _vmem((bq, hd), jnp.float32),
    ]

    if q_pos is not None:
        # pad positions with the sentinel so block-padding tails mask out
        qp = jnp.asarray(q_pos, jnp.int32)
        kp = jnp.asarray(k_pos, jnp.int32)
        if Sp != S:
            qp = jnp.pad(qp, ((0, 0), (0, Sp - S)),
                         constant_values=PAD_LIMIT)
        if Tp != T:
            kp = jnp.pad(kp, ((0, 0), (0, Tp - T)),
                         constant_values=PAD_LIMIT)
        kernel = functools.partial(
            _kernel_pos, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, n_kv=n_kv,
        )
        out = pl.pallas_call(
            kernel,
            grid=(B, H, n_q, n_kv),
            in_specs=[
                q_spec, kv_spec, kv_spec,
                pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
                pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
            scratch_shapes=scratch,
            interpret=interpret,
        )(q, k, v, qp, kp)
        return out[:, :, :S, :]

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv, seq_kv=T,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]


def _kernel_paged(bt_ref, *args, **kwargs):
    """Paged variant: the block-table scalar-prefetch ref is consumed by
    the INDEX MAPS (each grid step's KV block is fetched straight from its
    page in the pool — no gather materializes the dense view); the compute
    body is byte-for-byte ``_kernel_pos``, so page-order iteration at
    ``bk = page_size`` accumulates in exactly the dense kernel's order."""
    _kernel_pos(*args, **kwargs)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "interpret"),
)
def paged_flash_attention_kernel_call(
    q: jax.Array,             # (B, H, S, hd)
    k_pool: jax.Array,        # (P, K, page_size, hd) — shared page pool
    v_pool: jax.Array,        # (P, K, page_size, hd)
    block_tables: jax.Array,  # (B, n_blocks) int32 page ids per row
    q_pos: jax.Array,         # (B, S) int32
    k_pos: jax.Array,         # (B, n_blocks*page_size) int32 LOGICAL
                              # positions (PAD sentinel at unwritten slots)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention over a paged (non-contiguous) KV cache.

    The block tables ride as a scalar-prefetch operand
    (``PrefetchScalarGridSpec``): grid step ``(b, h, iq, ik)`` DMAs KV
    block ``block_tables[b, ik]`` of the pool, walking each row's logical
    blocks in order.  Masking is position-delivered exactly like
    ``_kernel_pos`` — a null page's slots carry PAD sentinels in ``k_pos``
    and are provably inert, so rows of different allocated lengths share
    one grid.  Parity: bit-exact vs ``flash_attention_kernel_call`` on the
    gathered dense view with ``block_k = page_size`` (same accumulation
    order, same masks)."""
    from jax.experimental.pallas import tpu as pltpu

    B, H, S, hd = q.shape
    P, K, ps, _ = k_pool.shape
    nb = block_tables.shape[1]
    if k_pos.shape[1] != nb * ps:
        raise ValueError(
            f"k_pos width {k_pos.shape[1]} != n_blocks*page_size {nb * ps}"
        )
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    bq = min(block_q, S)
    Sp = -(-S // bq) * bq
    qp = jnp.asarray(q_pos, jnp.int32)
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, Sp - S)), constant_values=PAD_LIMIT)
    n_q = Sp // bq

    kv_spec = pl.BlockSpec(
        (1, 1, ps, hd),
        lambda b, h, iq, ik, bt, G=G: (bt[b, ik], h // G, 0, 0),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, n_q, nb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, h, iq, ik, bt: (b, h, iq, 0)),
            kv_spec,
            kv_spec,
            pl.BlockSpec((1, bq), lambda b, h, iq, ik, bt: (b, iq)),
            pl.BlockSpec((1, ps), lambda b, h, iq, ik, bt: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik, bt: (b, h, iq, 0)),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel_paged, scale=scale, causal=causal, window=window,
        bq=bq, bk=ps, n_kv=nb,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), q, k_pool, v_pool,
      qp, jnp.asarray(k_pos, jnp.int32))
    return out[:, :, :S, :]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
