"""Continuous batching walkthrough: mixed-length clients share ONE running
decode loop backed by a PAGED KV pool.

    PYTHONPATH=src python examples/continuous_serving.py

The engine owns a persistent slot table whose KV cache is a shared pool of
fixed-size pages behind per-slot block tables: each admission allocates
pages for its ACTUAL lifetime extent (prompt + requested tokens) instead of
pinning ``slot_max_len`` cells, and grows page-by-page as decode proceeds.

The cast: Alice asks for a long completion; one decode step later Bob
(short, steered) and Carol (medium) join the RUNNING loop.  Bob retires
first, leaving the free rows NON-CONTIGUOUS — under the old contiguous-run
allocator Dana's 2-row request would now bounce on fragmentation, but the
block-table indirection places her on the scattered free rows and decodes
on — all through the one compiled decode step (zero retraces).
"""
import time

import jax
import numpy as np

from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.serving import NDIFServer, Request


def alice_request(cfg, rng):
    """A long completion with per-step logit saves."""
    g = InterventionGraph()
    n_new = 12
    for s in range(n_new):
        t = g.add("tap_get", site="logits", step=s)
        g.mark_saved(f"lg@step{s}", g.add("save", Ref(t.id)))
    toks = rng.integers(0, cfg.vocab_size, (1, 14)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks}, max_new_tokens=n_new)


def bob_request(cfg, rng):
    """A short completion, steered toward token 7 at step 0."""
    g = InterventionGraph()
    t = g.add("tap_get", site="logits", step=0)
    bias = np.zeros((cfg.vocab_size,), np.float32)
    bias[7] = 1e4
    c = g.add("constant", bias)
    v = g.add("add", Ref(t.id), Ref(c.id))
    g.add("tap_set", Ref(v.id), site="logits", step=0)
    toks = rng.integers(0, cfg.vocab_size, (1, 9)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks}, max_new_tokens=4)


def carol_request(cfg, rng):
    """A medium completion, plain decode."""
    toks = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    return Request(graph=InterventionGraph(), batch={"tokens": toks},
                   max_new_tokens=10)


def dana_request(cfg, rng):
    """TWO rows at once — arrives after Bob retires, when the free rows
    are non-contiguous (Alice and Carol hold rows in between)."""
    toks = rng.integers(0, cfg.vocab_size, (2, 10)).astype(np.int32)
    return Request(graph=InterventionGraph(), batch={"tokens": toks},
                   max_new_tokens=5)


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    t0 = time.time()
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="continuous",
                num_slots=4, slot_max_len=48, pad_slack=7)
    print(f"preloaded {cfg.name} in {time.time() - t0:.2f}s "
          "(slot table: 4 rows x 48 positions, paged KV pool)")

    sched = server.schedulers[cfg.name]
    engine = server.engines[cfg.name]
    rng = np.random.default_rng(0)

    # Alice arrives first and starts decoding...
    t_alice = sched.submit(alice_request(cfg, rng))
    sched.pump()   # admit Alice + one decode step
    loop = sched.loop
    print(f"step 1: occupancy {loop.occupancy():.0%}, "
          f"pages {loop.pages_in_use()}/{loop.usable_pages()} in use, "
          f"resident={[sr.request_id for sr in loop.resident]}")

    # ...Bob and Carol arrive ONE STEP LATER and join the RUNNING loop.
    t_bob = sched.submit(bob_request(cfg, rng))
    t_carol = sched.submit(carol_request(cfg, rng))
    t_dana = None
    done = []
    step = 1
    while len(done) < (4 if t_dana else 3):
        finished = sched.pump()
        step += 1
        for t in finished:
            print(f"step {step}: request {t.request_id} retired, "
                  f"occupancy {loop.occupancy():.0%}, "
                  f"pages {loop.pages_in_use()}/{loop.usable_pages()} — "
                  "its rows AND pages are free while co-tenants decode")
        done += finished
        if t_bob in done and t_dana is None:
            # Bob's retirement left the free rows non-contiguous; Dana's
            # 2-row request lands on them via the block-table indirection
            t_dana = sched.submit(dana_request(cfg, rng))

    for name, t in (("alice", t_alice), ("bob", t_bob),
                    ("carol", t_carol), ("dana", t_dana)):
        assert t.error is None, t.error
        print(f"  {name}: tokens {t.result['tokens'].tolist()} "
              f"[{t.response_time * 1e3:.1f} ms]")
    assert t_bob.result["tokens"][0, 0] == 7, "Bob's steering applied"
    assert t_bob.finish_time < t_alice.finish_time, "Bob retires first"

    snap = engine.stats.snapshot()
    print(f"admissions={snap['admissions']} retires={snap['retires']} "
          f"decode_steps={snap['slot_steps']} "
          f"slot_occupancy={snap['slot_occupancy']:.2f} "
          f"compiles={snap['compiles']}")
    print(f"paged KV: page_allocs={snap['page_allocs']} "
          f"page_frees={snap['page_frees']} "
          f"page_occupancy={snap['page_occupancy']:.2f} "
          f"frag_events_avoided={snap['frag_events_avoided']} "
          f"alloc_retries={snap['alloc_retries']}")
    assert snap["frag_events_avoided"] >= 1, (
        "Dana should have been placed on non-contiguous rows")
    assert snap["pages_in_use"] == 0, "all pages returned on retirement"


if __name__ == "__main__":
    main()
