"""Continuous batching walkthrough: two clients with DIFFERENT generation
lengths share ONE running decode loop.

    PYTHONPATH=src python examples/continuous_serving.py

The engine owns a persistent slot table (here 4 rows of preallocated cache).
Alice asks for a long completion; one decode step later Bob arrives with a
short, steered one.  Under burst-drain scheduling Bob would wait for Alice's
whole decode loop; with ``policy="continuous"`` he is admitted into free
slot rows at the next step boundary, decodes alongside her, RETIRES first
(his ``max_new_tokens`` is smaller), and his slots are immediately reusable
— all through the one compiled decode step (zero retraces).
"""
import time

import jax
import numpy as np

from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.serving import NDIFServer, Request


def alice_request(cfg, rng):
    """A long completion with per-step logit saves."""
    g = InterventionGraph()
    n_new = 12
    for s in range(n_new):
        t = g.add("tap_get", site="logits", step=s)
        g.mark_saved(f"lg@step{s}", g.add("save", Ref(t.id)))
    toks = rng.integers(0, cfg.vocab_size, (1, 14)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks}, max_new_tokens=n_new)


def bob_request(cfg, rng):
    """A short completion, steered toward token 7 at step 0."""
    g = InterventionGraph()
    t = g.add("tap_get", site="logits", step=0)
    bias = np.zeros((cfg.vocab_size,), np.float32)
    bias[7] = 1e4
    c = g.add("constant", bias)
    v = g.add("add", Ref(t.id), Ref(c.id))
    g.add("tap_set", Ref(v.id), site="logits", step=0)
    toks = rng.integers(0, cfg.vocab_size, (1, 9)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks}, max_new_tokens=4)


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    t0 = time.time()
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="continuous",
                num_slots=4, slot_max_len=48, pad_slack=7)
    print(f"preloaded {cfg.name} in {time.time() - t0:.2f}s "
          "(slot table: 4 rows x 48 positions)")

    sched = server.schedulers[cfg.name]
    engine = server.engines[cfg.name]
    rng = np.random.default_rng(0)

    # Alice arrives first and starts decoding...
    t_alice = sched.submit(alice_request(cfg, rng))
    sched.pump()   # admit Alice + one decode step
    print(f"step 1: occupancy {sched.loop.occupancy():.0%}, "
          f"resident={[sr.request_id for sr in sched.loop.resident]}")

    # ...Bob arrives ONE STEP LATER and joins the RUNNING loop.
    t_bob = sched.submit(bob_request(cfg, rng))
    done = []
    step = 1
    while len(done) < 2:
        finished = sched.pump()
        step += 1
        for t in finished:
            print(f"step {step}: request {t.request_id} retired, "
                  f"occupancy {sched.loop.occupancy():.0%} — "
                  "its slots are free while co-tenants keep decoding")
        done += finished

    for name, t in (("alice", t_alice), ("bob", t_bob)):
        assert t.error is None, t.error
        print(f"  {name}: tokens {t.result['tokens'].tolist()} "
              f"[{t.response_time * 1e3:.1f} ms]")
    assert t_bob.result["tokens"][0, 0] == 7, "Bob's steering applied"
    assert t_bob.finish_time < t_alice.finish_time, "Bob retires first"

    snap = engine.stats.snapshot()
    print(f"admissions={snap['admissions']} retires={snap['retires']} "
          f"decode_steps={snap['slot_steps']} "
          f"slot_occupancy={snap['slot_occupancy']:.2f} "
          f"compiles={snap['compiles']}")


if __name__ == "__main__":
    main()
