"""Steered generation: intervene on activations at chosen decode steps.

The paper's multi-invoke tracing (§3.2) applied to a full decode loop —
the workload class FlexModel and nnterp call table stakes for
interpretability tooling: activation steering DURING generation, per-token
logit-lens collection, and cached per-step activations.

Run:  PYTHONPATH=src python examples/steered_generation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving.engine import InferenceEngine

cfg = R.get_config("paper-gpt-small")
model = R.build_model("paper-gpt-small", cfg)
params = model.init(jax.random.key(0))
tokens = jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 6), dtype=np.int32)
)

lm = traced_lm(model, params)
N = 8

# ---------------------------------------------------------------- baseline
engine = InferenceEngine(model, params)
plain, _ = engine.generate(tokens, max_new_tokens=N)
print("plain tokens:   ", plain[0])
c0 = engine.stats.compiles
engine.generate(tokens, max_new_tokens=N)
print(f"decode is cached: {engine.stats.compiles - c0} new compiles "
      "on the second generate()")
# An uninstrumented generation is step-uniform, so the WHOLE decode loop
# ran as one compiled lax.scan dispatch instead of N per-step dispatches:
snap = engine.stats.snapshot()
print(f"fused decode:     {snap['fused_segments']} scan dispatch(es) served "
      f"{snap['fused_steps']} steps ({snap['eager_steps']} eager)")

# ------------------------------------------------- steer + collect per step
with lm.generate(tokens, max_new_tokens=N) as tr:
    # steer one layer's MLP at steps 3..5 only
    for s in tr.steps(3, 6):
        lm.layers[2].mlp.output += 25.0
    # collect the (post-intervention) logits of EVERY step; saving under
    # one name across steps stacks them along the token axis.  log() taps
    # ride the COMPILED decode too: they lower to jax.debug.callback
    # inside the scan body instead of forcing the step eager.  Caveat:
    # callbacks flush when the dispatch completes, so logged values arrive
    # per fused SEGMENT, not live per token — ordering within a segment is
    # preserved (ordered=True), but don't expect a print-as-it-decodes
    # stream.
    for s in tr.steps():
        tr.log(lm.logits.max())
        lm.logits.save("logits")

print("steered tokens: ", tr.output_tokens[0])
print("stacked logits: ", np.asarray(tr.result("logits")).shape)  # (B, N, V)
print("logged max-logit per step:",
      np.round([float(v) for _, v in tr.logs], 2))
# Steering only steps 3..5 makes the schedule non-uniform overall — the
# loop still fuses the three uniform stretches (0..2 / 3..5 / 6..7) and
# the tracer marks the overall schedule:
print("step-uniform?   ", tr.steps_uniform)  # False (per-step structure varies)

# per-token logit lens: entropy of each decode step's distribution
lg = np.asarray(tr.result("logits"))
p = jax.nn.softmax(jnp.asarray(lg), axis=-1)
ent = -np.asarray((p * jnp.log(p + 1e-9)).sum(-1))[:, :, None].squeeze(-1)
print("per-step entropy (row 0):", np.round(ent[0], 2))

# -------------------------------------------------- broadcast + prefill tap
with lm.generate(tokens, max_new_tokens=4) as tr2:
    with tr2.prefill():
        lm.layers[0].output.save("prompt_acts")   # prompt-phase collection
    with tr2.all_steps():
        lm.layers[2].mlp.output += 25.0           # steer every decode step
print("prompt acts:    ", np.asarray(tr2.result("prompt_acts")).shape)
print("broadcast steer:", tr2.output_tokens[0])
print("step-uniform?   ", tr2.steps_uniform)  # True: all_steps() fuses whole

# ------------------------------------------- fused path through the engine
# The same broadcast-steer graph served by the engine compiles ONCE into a
# single lax.scan program; a repeat request reuses the executable.
from repro.core.graph import ALL_STEPS, InterventionGraph, Ref

g = InterventionGraph()
t = g.add("tap_get", site="layers.mlp.output", layer=2, step=ALL_STEPS)
c = g.add("constant", np.float32(25.0))
u = g.add("add", Ref(t.id), Ref(c.id))
g.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=2, step=ALL_STEPS)
res = engine.generate_interleaved(g, {"tokens": tokens}, N)
c0 = engine.stats.compiles
engine.generate_interleaved(g, {"tokens": tokens}, N)
snap = engine.stats.snapshot()
print("engine steered: ", np.asarray(res.tokens)[0])
print(f"fused counters:  segments={snap['fused_segments']} "
      f"fused_steps={snap['fused_steps']} eager_steps={snap['eager_steps']} "
      f"(+{engine.stats.compiles - c0} compiles on repeat)")

# ----------------------------------------------- compiled island: log taps
# A log()-instrumented generation used to be an EAGER island (the callback
# could not live inside the scan); the harvest-mold interpreter lowers it
# into the compiled body, so the whole stretch still fuses — the
# islands_compiled counter records each fused segment that carried
# log/grad/cross-layer work the old interpreter would have served eagerly.
gl = InterventionGraph()
for s in range(N):
    t = gl.add("tap_get", site="logits", step=s)
    m = gl.add("jnp.max", Ref(t.id), step=s)
    gl.add("log", Ref(m.id), step=s)
res_l = engine.generate_interleaved(gl, {"tokens": tokens}, N)
snap = engine.stats.snapshot()
print(f"logged decode:   {len(res_l.logs)} values via jax.debug.callback, "
      f"eager_steps={snap['eager_steps']} "
      f"islands_compiled={snap['islands_compiled']}")
