"""Steered generation: intervene on activations at chosen decode steps.

The paper's multi-invoke tracing (§3.2) applied to a full decode loop —
the workload class FlexModel and nnterp call table stakes for
interpretability tooling: activation steering DURING generation, per-token
logit-lens collection, and cached per-step activations.

Run:  PYTHONPATH=src python examples/steered_generation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving.engine import InferenceEngine

cfg = R.get_config("paper-gpt-small")
model = R.build_model("paper-gpt-small", cfg)
params = model.init(jax.random.key(0))
tokens = jnp.asarray(
    np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 6), dtype=np.int32)
)

lm = traced_lm(model, params)
N = 8

# ---------------------------------------------------------------- baseline
engine = InferenceEngine(model, params)
plain, _ = engine.generate(tokens, max_new_tokens=N)
print("plain tokens:   ", plain[0])
c0 = engine.stats.compiles
engine.generate(tokens, max_new_tokens=N)
print(f"decode is cached: {engine.stats.compiles - c0} new compiles "
      "on the second generate()")

# ------------------------------------------------- steer + collect per step
with lm.generate(tokens, max_new_tokens=N) as tr:
    # steer one layer's MLP at steps 3..5 only
    for s in tr.steps(3, 6):
        lm.layers[2].mlp.output += 25.0
    # collect the (post-intervention) logits of EVERY step; saving under
    # one name across steps stacks them along the token axis
    for s in tr.steps():
        lm.logits.save("logits")

print("steered tokens: ", tr.output_tokens[0])
print("stacked logits: ", np.asarray(tr.result("logits")).shape)  # (B, N, V)

# per-token logit lens: entropy of each decode step's distribution
lg = np.asarray(tr.result("logits"))
p = jax.nn.softmax(jnp.asarray(lg), axis=-1)
ent = -np.asarray((p * jnp.log(p + 1e-9)).sum(-1))[:, :, None].squeeze(-1)
print("per-step entropy (row 0):", np.round(ent[0], 2))

# -------------------------------------------------- broadcast + prefill tap
with lm.generate(tokens, max_new_tokens=4) as tr2:
    with tr2.prefill():
        lm.layers[0].output.save("prompt_acts")   # prompt-phase collection
    with tr2.all_steps():
        lm.layers[2].mlp.output += 25.0           # steer every decode step
print("prompt acts:    ", np.asarray(tr2.result("prompt_acts")).shape)
print("broadcast steer:", tr2.output_tokens[0])
