"""Quickstart: the paper's Figure 3b in this framework.

    PYTHONPATH=src python examples/quickstart.py

Loads a small decoder, enters a tracing context, boosts three MLP neurons at
layer 4, and reads the logits — all deferred and executed on context exit.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analysis import PreflightError
from repro.models import registry as R
from repro.models.traced import traced_lm


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    lm = traced_lm(model, params)

    tokens = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab_size)
    neurons = [394 % cfg.d_model, 149, 37]

    # ------- baseline ---------------------------------------------------
    with lm.trace(tokens):
        base = lm.output.save("base")

    # ------- intervention: boost three neurons at layer 4's MLP ---------
    with lm.trace(tokens):
        lm.layers[4].mlp.output[:, -1, neurons] = 10.0
        out = lm.output.save("out")

    b = np.asarray(base.value)[0, -1]
    o = np.asarray(out.value)[0, -1]
    print(f"argmax before: {b.argmax():5d}  after: {o.argmax():5d}")
    print(f"logit delta (max abs): {np.abs(o - b).max():.3f}")

    # ------- inspect + compute server-side-style metrics ----------------
    with lm.trace(tokens) as tr:
        h = lm.layers[2].output.save("hidden")
        norm = lm.layers[2].output.norm(axis=-1).mean().save("mean_norm")
    print(f"layer-2 hidden: {np.asarray(h.value).shape}, "
          f"mean norm {float(np.asarray(norm.value)):.3f}")

    # ------- gradients (GradProtocol) ------------------------------------
    with lm.trace(tokens) as tr:
        g = lm.layers[2].output.grad.save("grad")
        loss = (lm.output * lm.output).mean().save("loss")
        tr.backward(loss)
    print(f"d(loss)/d(layer-2): shape {np.asarray(tr.result('grad')).shape}, "
          f"|g| {np.abs(np.asarray(tr.result('grad'))).mean():.2e}")

    # ------- preflight: broken ops fail BEFORE anything executes ---------
    # The static analyzer (repro.core.analysis) infers every node's shape
    # abstractly at trace exit; a deliberately wrong-sized steering vector
    # is rejected with the offending node and YOUR source line — zero
    # model forwards spent.
    bad_vec = np.zeros((cfg.d_model + 1,), np.float32)   # off by one!
    try:
        with lm.generate(tokens, max_new_tokens=4) as tr:
            for s in tr.steps(1, 2):
                lm.layers[4].mlp.output += bad_vec
            for s in tr.steps():
                lm.logits.save("logits")
    except PreflightError as e:
        print("preflight rejected the trace before running it:")
        for d in e.diagnostics:
            print("  ", d.format())


if __name__ == "__main__":
    main()
