"""End-to-end serving driver: one preloaded model, many users, batched
co-tenant execution (the paper's Appendix B.2 parallel co-tenancy).

    PYTHONPATH=src python examples/cotenancy_serving.py

Eight simulated researchers submit DIFFERENT experiments (activation saves,
neuron edits, router inspection) against one hosted model.  The scheduler
merges batch-compatible requests into single forwards; each user gets only
their own rows back.
"""
import time

import jax
import numpy as np

from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.serving import NDIFServer, Request


def save_request(cfg, rng, layer):
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=layer)
    s = g.add("save", Ref(t.id))
    g.mark_saved("acts", s)
    toks = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks})


def edit_request(cfg, rng, layer, scale):
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.mlp.output", layer=layer)
    v = g.add("mul", Ref(t.id), float(scale))
    g.add("tap_set", Ref(v.id), site="layers.mlp.output", layer=layer)
    o = g.add("tap_get", site="logits")
    last = g.add("getitem", Ref(o.id), (slice(None), -1, slice(None)))
    am = g.add("jnp.argmax", Ref(last.id), axis=-1)
    s = g.add("save", Ref(am.id))
    g.mark_saved("prediction", s)
    toks = rng.integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks})


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    t0 = time.time()
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="parallel",
                max_batch_rows=64)
    print(f"preloaded {cfg.name} in {time.time()-t0:.2f}s")

    sched = server.schedulers[cfg.name]
    rng = np.random.default_rng(0)
    tickets = []
    kinds = []
    for u in range(8):
        if u % 2 == 0:
            req = save_request(cfg, rng, layer=u % cfg.n_layers)
            kinds.append("save")
        else:
            req = edit_request(cfg, rng, layer=u % cfg.n_layers,
                               scale=(-1.0) ** u * 2.0)
            kinds.append("edit")
        tickets.append(sched.submit(req))

    t0 = time.time()
    sched.drain()
    wall = time.time() - t0
    stats = server.engines[cfg.name].stats
    print(f"8 users served in {wall:.2f}s with {stats.executions} "
          f"model execution(s), {stats.compiles} compile(s)")
    for u, (t, kind) in enumerate(zip(tickets, kinds)):
        assert t.error is None, t.error
        key = "acts" if kind == "save" else "prediction"
        val = t.result[key]
        desc = (f"activations {val.shape}" if kind == "save"
                else f"prediction {val.tolist()}")
        print(f"  user {u} ({kind:4s}): {desc} "
              f"[{t.response_time*1e3:.1f} ms]")


if __name__ == "__main__":
    main()
