"""Multi-invoke tracing: the paper's Figure 3 usage, in this framework.

    PYTHONPATH=src python examples/multi_invoke.py

Declares TWO prompts of different lengths inside one ``lm.trace()`` block —
each with its own interventions — and lets the tracer lower them into ONE
merged, padded forward (getters sliced back to each invoke's rows and true
lengths, setters row-confined).  Then chains two traces in a session whose
second trace consumes a value saved by the first (the cross-trace value
flow DAG), and finishes with a multi-invoke generation where each prompt
retires at its own ``max_new_tokens``.
"""
import jax
import numpy as np

from repro.models import registry as R
from repro.models.traced import traced_lm


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    lm = traced_lm(model, params)

    rng = np.random.default_rng(0)
    prompt_a = rng.integers(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    prompt_b = rng.integers(0, cfg.vocab_size, (1, 7)).astype(np.int32)

    # ------- two ragged invokes, ONE merged forward ---------------------
    with lm.trace() as tr:
        with tr.invoke(prompt_a) as a:          # 12 tokens
            lm.layers[4].mlp.output[:, -1] = 0.0     # intervene on A only
            lm.output.save("out")
        with tr.invoke(prompt_b) as b:          # 7 tokens — ragged is fine
            lm.layers[2].output.save("acts")
            lm.output.save("out")
    print("invoke A logits:", np.asarray(a.result("out")).shape,
          "| invoke B logits:", np.asarray(b.result("out")).shape)
    print("invoke B layer-2 acts:", np.asarray(b.result("acts")).shape,
          "(true solo shape, not padded)")

    # ------- early stop: pay only for the layers you read ----------------
    with lm.trace(prompt_a) as tr:
        h = lm.layers[2].output.save("h")
        tr.stop()                               # layers 3.. never execute
    print("stopped trace read layer 2:", np.asarray(h.value).shape)

    # ------- session: trace 2 consumes a value saved by trace 1 ----------
    with lm.session() as sess:
        with sess.trace(prompt_a):
            acts = lm.layers[2].output.save("acts")
        with sess.trace(prompt_b):
            # patch B's layer-2 stream with A's last-token activation
            lm.layers[2].output[:, -1] = acts[:, -1]
            patched = lm.output.save("out")
    print("cross-trace patched logits:", np.asarray(patched.value).shape)

    # ------- multi-invoke generation: one decode loop, ragged retirement -
    with lm.generate() as tr:
        with tr.invoke(prompt_a, max_new_tokens=4) as ga:
            for _ in tr.steps():
                lm.logits.save("logits")
        with tr.invoke(prompt_b, max_new_tokens=8) as gb:
            pass
    print("generated A:", ga.output_tokens.shape,
          "| stacked per-step logits:", np.asarray(ga.result("logits")).shape)
    print("generated B:", gb.output_tokens.shape,
          "(its own max_new_tokens, same decode loop)")


if __name__ == "__main__":
    main()
