"""Remote probe training (paper Code Example 8, simplified).

    PYTHONPATH=src python examples/remote_probe_training.py

A researcher without local weights collects (layer-0 output, layer-1 output)
pairs from a remotely-hosted model through the intervention API, then trains
a linear probe locally predicting the next layer's representation.  Only the
activations the experiment saves ever cross the wire.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="sequential")
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, cfg.name)
    lm = traced_lm(model, None, backend=client)

    rng = np.random.default_rng(0)
    d = cfg.d_model
    W = jnp.zeros((d, d))
    b = jnp.zeros((d,))
    opt_lr = 0.2

    @jax.jit
    def update(W, b, X, Y):
        def loss_fn(Wb):
            W_, b_ = Wb
            pred = X @ W_ + b_
            return jnp.mean((pred - Y) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)((W, b))
        return W - opt_lr * grads[0], b - opt_lr * grads[1], loss

    # Session: several collection traces ship as ONE request per epoch.
    print(f"{'epoch':>5} {'mse':>10} {'wire KB':>9}")
    for epoch in range(8):
        toks = rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32)
        sent0 = transport.stats.bytes_received
        with lm.session(remote=True, backend=client) as sess:
            with sess.trace(toks) as tr:
                tr_h0 = lm.layers[0].output.save("h0")
                tr_h1 = lm.layers[1].output.save("h1")
        X = jnp.asarray(np.asarray(tr_h0.value).reshape(-1, d))
        Y = jnp.asarray(np.asarray(tr_h1.value).reshape(-1, d))
        for _ in range(25):
            W, b, loss = update(W, b, X, Y)
        kb = (transport.stats.bytes_received - sent0) / 1024
        print(f"{epoch:5d} {float(loss):10.5f} {kb:9.1f}")

    print("probe trained; weights stayed on the server the whole time "
          f"({transport.stats.requests} requests).")


if __name__ == "__main__":
    main()
