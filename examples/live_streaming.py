"""Live streaming: a batch client and a streaming client share one loop.

Everything earlier in the examples drives the server synchronously — you
submit, the server drains, you get the whole answer back.  This example
uses the LIVE front door (repro.serving.frontdoor): a dedicated engine
thread steps the continuous-batching decode loop, submissions are admitted
at decode-step boundaries, and a streaming client watches its tokens
arrive chunk by chunk WHILE a batch client's request decodes in the same
slot table.

Also shown: structured backpressure (the bounded queue refuses an
over-budget burst with a machine-readable ``retry_after_ms``) and clean
shutdown (residents drain, the engine thread joins).

Run:  PYTHONPATH=src python examples/live_streaming.py
"""
import threading
import time

import jax
import numpy as np

from repro.models import registry as R
from repro.serving import (
    AdmissionRefused,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
)

cfg = R.get_config("paper-gpt-small")
model = R.build_model("paper-gpt-small", cfg)
params = model.init(jax.random.key(0))

server = NDIFServer()
server.host("gpt", model, params, policy="continuous",
            num_slots=4, slot_max_len=64, max_queue_depth=6)
client = NDIFClient(LoopbackTransport(server.handle), "gpt")

rng = np.random.default_rng(0)
prompt = rng.integers(0, cfg.vocab_size, (1, 6), dtype=np.int32)

# ------------------------------------------------ two clients, one loop
# The batch client fires from another thread and just waits for its full
# result; the streaming client iterates chunks as the engine produces
# them.  Both requests are co-resident in the same slot table.
batch_out = {}


def batch_client():
    ticket = client.submit(prompt, 12)            # one done-chunk at the end
    batch_out["tokens"] = ticket.result()["tokens"]


t = threading.Thread(target=batch_client)
t.start()

streaming = client.submit(prompt, 12, stream=True)
print("streaming chunks as the loop decodes:")
for chunk in streaming.chunks():
    if chunk["kind"] == "tokens":
        step_tokens = np.asarray(chunk["payload"]["tokens"])
        print(f"  seq={chunk['seq']:<2d} +{step_tokens.shape[1]} token(s): "
              f"{step_tokens[0].tolist()}")
    elif chunk["kind"] == "done":
        print(f"  seq={chunk['seq']:<2d} done (logits + remainder)")
t.join()

stream_tokens = streaming.result()["tokens"]
print("streamed tokens:", stream_tokens[0])
print("batch tokens:   ", batch_out["tokens"][0])
# chunked decode is bit-exact: fused window splits are bit-identical
solo = client.generate(prompt, 12)["tokens"]
assert np.array_equal(stream_tokens, solo)
assert np.array_equal(batch_out["tokens"], solo)
print("both match the solo synchronous result bit-exactly")

# -------------------------------------------------- structured backpressure
# The door bounds its backlog (max_queue_depth=6 here).  An over-budget
# burst is refused with a structured payload — code + retry_after_ms —
# so clients back off instead of parsing error strings.
tickets, refusal = [], None
for _ in range(30):
    try:
        tickets.append(client.submit(prompt, 12))
    except AdmissionRefused as e:
        refusal = e
        break
print(f"\nburst refused after {len(tickets)} admissions: code={refusal.code} "
      f"retry_after_ms={refusal.retry_after_ms:.0f} "
      f"(depth {refusal.payload['queue_depth']}"
      f"/{refusal.payload['max_queue_depth']})")
time.sleep(refusal.retry_after_ms / 1000.0)     # the structured backoff hint
retry = client.submit(prompt, 12)                # now it fits
tickets.append(retry)
for tk in tickets:
    assert np.array_equal(tk.result(timeout=600.0)["tokens"], solo)
print(f"all {len(tickets)} backlogged requests completed bit-exact "
      "after backoff")

# ---------------------------------------------------- front-door telemetry
s = client.stats()
print(f"\nfront-door stats: queue_depth_max={s['queue_depth_max']} "
      f"rejected={s['rejected_submissions']} "
      f"stream_chunks={s['stream_chunks']} "
      f"step_cost_ema={s['step_cost_ema'] * 1e3:.1f}ms")
last = s["tickets"][-1]
print(f"last ticket: queue_wait={last['queue_wait'] * 1e3:.1f}ms "
      f"ttft={last['time_to_first_token'] * 1e3:.1f}ms "
      f"response={last['response_time'] * 1e3:.1f}ms")

# --------------------------------------------------------- clean shutdown
server.shutdown()   # drains residents, rejects queued work, joins the thread
try:
    client.submit(prompt, 4)
except AdmissionRefused as e:
    print(f"after shutdown: submit refused with code={e.code!r}")
