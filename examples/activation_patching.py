"""Activation patching (paper Code Example 2/3) with remote execution.

    PYTHONPATH=src python examples/activation_patching.py

Trains a small model briefly on synthetic data (so the distributions are not
pure noise), hosts it on an in-process NDIF server, and runs the classic
edit-prompt -> base-prompt residual-stream patch REMOTELY, sweeping layers
and reporting the patching effect per layer — the standard causal-tracing
workflow, expressed in three lines per layer.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_lm_data
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train_loop


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    print("training briefly on synthetic data ...")
    data = synthetic_lm_data(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=24, batch_size=8)
    )
    state, hist = train_loop(
        model, params, data, steps=60,
        opt_cfg=AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=60),
        mode="unrolled", log_every=59,
    )
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    params = state["params"]

    # Host on NDIF; the researcher below holds NO weights.
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="sequential")
    client = NDIFClient(LoopbackTransport(server.handle), cfg.name)
    lm = traced_lm(model, None, backend=client)

    rng = np.random.default_rng(0)
    batch = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
    edit_tok, base_tok = 5, 6
    answer_a, answer_b = 7, 11

    # clean run: what does the base prompt (row 1) predict?
    with lm.trace(batch, remote=True):
        logits = lm.output
        clean = (logits[1, -1, answer_a] - logits[1, -1, answer_b]).save("d")
    clean = float(np.asarray(clean.value))

    print(f"clean logit-diff: {clean:+.4f}")
    print(f"{'layer':>5} {'patched':>9} {'effect':>9}")
    for layer in range(cfg.n_layers):
        with lm.trace(batch, remote=True):
            lm.layers[layer].output[1, base_tok, :] = \
                lm.layers[layer].output[0, edit_tok, :]
            logits = lm.output
            d = (logits[1, -1, answer_a] - logits[1, -1, answer_b]).save("d")
        patched = float(np.asarray(d.value))
        print(f"{layer:5d} {patched:+9.4f} {patched - clean:+9.4f}")


if __name__ == "__main__":
    main()
