"""Fault tolerance: break the serving stack on purpose, watch it recover.

The live front door (see examples/live_streaming.py) is supervised: an
engine crash mid-decode is contained, the scheduler and decode loop are
rebuilt, and every in-flight ticket is requeued and re-executed
deterministically — the client never notices beyond latency.  This
example drives all of it with the DETERMINISTIC fault-injection plane
(repro.serving.faults): a seeded ``FaultPlan`` decides which hits of
which named fault points fire what, so every failure shown here replays
bit-for-bit.

Shown:
  1. an injected engine crash -> supervised restart, bit-exact result;
  2. lost transport messages (request AND reply) -> the retrying client
     converges on ONE execution via its idempotency key;
  3. a hard per-ticket ``deadline_ms`` and a client-side ``cancel()`` —
     both terminate with STRUCTURED errors (machine-readable codes);
  4. the fault-tolerance counters in the ``stats`` wire kind.

Run:  PYTHONPATH=src python examples/fault_tolerance.py
"""
import jax
import numpy as np

from repro.models import registry as R
from repro.serving import (
    FaultError,
    FaultPlan,
    FaultSpec,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
    RetryPolicy,
    TicketError,
    TransportError,
)
from repro.serving import faults

cfg = R.get_config("paper-gpt-small")
model = R.build_model("paper-gpt-small", cfg)
params = model.init(jax.random.key(0))

server = NDIFServer()
server.host("gpt", model, params, policy="continuous",
            num_slots=4, slot_max_len=64,
            door_kwargs=dict(restart_backoff_s=0.01))
client = NDIFClient(LoopbackTransport(server.handle), "gpt")

rng = np.random.default_rng(0)
prompt = rng.integers(0, cfg.vocab_size, (1, 6), dtype=np.int32)

# the fault-free answer, for comparison (also warms the executables)
ref = client.generate(prompt, 12)["tokens"]

# ------------------------------------------ 1. crash the engine mid-decode
# decode.step is the engine-crash surface: the 2nd fused window after the
# plan arms raises.  The supervisor blames, rebuilds, requeues — and the
# re-executed result is bit-exact.
plan = FaultPlan(
    [FaultSpec("decode.step", nth=2, error=FaultError,
               message="injected engine crash")],
    seed=0, stats=server.engines["gpt"].stats,
)
with faults.inject(plan):
    out = client.submit(prompt, 12).result()
assert np.array_equal(out["tokens"], ref)
print(f"crash -> restart -> bit-exact ({plan.fires()} fault fired)")

# --------------------------------- 2. lossy transport + idempotent retries
# The retrying client survives a lost REQUEST (safe to resend) and a lost
# REPLY (ambiguous: the server may have admitted).  Its auto-generated
# idempotency key makes the ambiguous retry return the ORIGINAL ticket,
# so the work runs exactly once.
rclient = NDIFClient(LoopbackTransport(server.handle), "gpt",
                     retry=RetryPolicy(max_attempts=5, base_delay_ms=2.0,
                                       seed=7))
plan = FaultPlan(
    [
        FaultSpec("transport.send", nth=1, error=TransportError),
        FaultSpec("transport.recv", nth=1, error=TransportError),
    ],
    seed=0,
)
with faults.inject(plan):
    out = rclient.submit(prompt, 12).result()
assert np.array_equal(out["tokens"], ref)
print(f"2 lost messages -> retried under one idempotency key -> bit-exact")

# ------------------------------------- 3. deadlines and cancellation
# deadline_ms is enforced SERVER-side: past it the ticket is evicted
# mid-decode (its rows and KV pages free immediately for co-tenants).
doomed = client.submit(prompt, 40, deadline_ms=50.0)
try:
    doomed.result()
except TicketError as e:
    print(f"deadline_ms=50 -> structured error code={e.code!r}")

tk = client.submit(prompt, 40)
tk.cancel()
try:
    tk.result()
except TicketError as e:
    print(f"cancel() -> structured error code={e.code!r}")

# ------------------------------------------------ 4. the recovery ledger
snap = client.stats()
print("fault-tolerance counters:",
      {k: snap[k] for k in ("faults_injected", "engine_restarts",
                            "tickets_requeued", "cancellations",
                            "deadline_evictions")})

server.shutdown()
print("clean shutdown")
