"""Remote LoRA training (paper Code Example 5).

    PYTHONPATH=src python examples/remote_lora_training.py

The LoRA adapter IS an intervention graph — getters on a layer's input,
trainable WA/WB graph inputs, a setter on the layer's output, and an
in-graph loss. The client ships it once; the server differentiates the
interleaved program w.r.t. WA/WB and runs Adam.  "The parameters are
created remotely and never sent, only retrieved."
"""
import jax
import numpy as np

from repro.data.pipeline import DataConfig, synthetic_lm_data
from repro.models import registry as R
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer
from repro.serving.remote_train import lora_graph


def main() -> None:
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params)
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, cfg.name)

    data = next(synthetic_lm_data(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=24, batch_size=8)
    ))

    graph, init = lora_graph(
        layer=cfg.n_layers - 2, d_model=cfg.d_model, rank=8,
        vocab_size=cfg.vocab_size, alpha=2.0,
    )
    print(f"training rank-8 LoRA at layer {cfg.n_layers - 2} remotely ...")
    res = client.train_module(
        graph, {"tokens": data["tokens"]},
        trainable=init, fixed_inputs={"labels": data["labels"]},
        steps=60, lr=5e-3,
    )
    losses = res["losses"]
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")
    wa, wb = res["params"]["WA"], res["params"]["WB"]
    print(f"retrieved adapters: WA{wa.shape} |WA|={np.linalg.norm(wa):.3f}, "
          f"WB{wb.shape} |WB|={np.linalg.norm(wb):.3f}")
    print(f"wire traffic: {transport.stats.bytes_sent} B up, "
          f"{transport.stats.bytes_received} B down "
          f"(model weights: 0 B — they never left the server)")


if __name__ == "__main__":
    main()
