"""HLO cost model: trip-count awareness, dot flops, collective bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import model_flops, roofline_report
from repro.roofline.hlo_cost import analyze_hlo


def _compiled(fn, *args, **jit_kw):
    return jax.jit(fn, **jit_kw).lower(*args).compile()


def test_plain_dot_flops():
    x = jnp.ones((32, 48))
    w = jnp.ones((48, 64))
    c = analyze_hlo(_compiled(lambda a, b: a @ b, x, w).as_text())
    assert c.flops == pytest.approx(2 * 32 * 48 * 64, rel=0.05)


def test_scan_trip_count_multiplied():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=9)
        return out

    x = jnp.ones((32, 32))
    c = analyze_hlo(_compiled(f, x).as_text())
    assert c.flops == pytest.approx(9 * 2 * 32**3, rel=0.05)
    assert c.unknown_trip_whiles == 0


def test_nested_scan():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    x = jnp.ones((16, 16))
    c = analyze_hlo(_compiled(f, x).as_text())
    assert c.flops == pytest.approx(12 * 2 * 16**3, rel=0.1)


def test_collective_bytes_inside_scan():
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device; covered by dry-run environment")


def test_bytes_accessed_scale():
    x = jnp.ones((1024, 1024), jnp.float32)
    c = analyze_hlo(_compiled(lambda a: a + 1.0, x).as_text())
    # read + write of 4 MB
    assert 0.5 * 8 * 2**20 <= c.bytes_accessed <= 3 * 8 * 2**20


def test_model_flops_dense_vs_moe():
    from repro.models import registry as R

    dense = R.get_config("qwen3-8b")
    moe = R.get_config("qwen3-moe-30b-a3b")
    shp = R.SHAPES["train_4k"]
    # MoE active params ~3B << total ~30B
    assert moe.active_params() < 0.25 * moe.total_params()
    mf = model_flops(dense, shp)
    assert mf == pytest.approx(6 * dense.active_params() * 4096 * 256)


def test_roofline_report_terms():
    from repro.models import registry as R

    rec = {"n_chips": 256, "flops": 197e12, "bytes_accessed": 819e9,
           "collective_bytes": 50e9}
    rep = roofline_report(rec, R.get_config("qwen3-8b"), R.SHAPES["train_4k"])
    assert rep["t_compute_s"] == pytest.approx(1.0)
    assert rep["t_memory_s"] == pytest.approx(1.0)
    assert rep["t_collective_s"] == pytest.approx(1.0)
    assert rep["dominant"] in ("compute", "memory", "collective")
