"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see ONE real device
(the 512-device override belongs exclusively to repro.launch.dryrun)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.interleave import SiteSchedule
from repro.core.tracer import TracedModel
from repro.core import taps

# test modules that spin up live front doors (engine/watchdog threads);
# every test in them must leave the process thread count where it found it
_THREADED_MODULES = ("test_frontdoor", "test_faults")


@pytest.fixture(autouse=True)
def _no_thread_leaks(request):
    """Front-door tests must not leak threads: a door left open (engine
    thread, watchdog) poisons every later test's timing.  Module-scoped
    live fixtures are forced up FIRST so their long-lived threads are part
    of the baseline, then the test must return to that count."""
    mod = request.module.__name__.rsplit(".", 1)[-1]
    if mod not in _THREADED_MODULES:
        yield
        return
    for name in ("live",):
        if name in request.fixturenames:
            request.getfixturevalue(name)
    before = threading.active_count()
    yield
    deadline = time.time() + 10.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.05)
    after = threading.active_count()
    assert after <= before, (
        f"thread leak: {before} threads before the test, {after} after "
        f"({[t.name for t in threading.enumerate()]})"
    )


def make_tiny_model(n_layers=3, d=4, scan=False):
    """A minimal layered model for core tests: h -> h @ ((i+1)·I)."""
    ws = jnp.stack(
        [jnp.eye(d, dtype=jnp.float32) * (i + 1) for i in range(n_layers)]
    )
    params = {"w": ws}

    if not scan:
        def model_fn(params, x):
            h = taps.site("embed", x)
            for i in range(n_layers):
                h = taps.site("layers.input", h, layer=i)
                h = h @ params["w"][i]
                h = taps.site("layers.output", h, layer=i)
            return taps.site("logits", h)
        scan_sites = ()
    else:
        def model_fn(params, x):
            h = taps.site("embed", x)

            def body(carry, inp):
                h, env_c = carry
                taps.scan_env_provide(env_c)
                w, idx = inp
                h = taps.site("layers.input", h, layer=idx)
                h = h @ w
                h = taps.site("layers.output", h, layer=idx)
                return (h, taps.scan_env_update(env_c)), taps.scan_outputs()

            (h, _), ys = jax.lax.scan(
                body, (h, taps.scan_env_init()),
                (params["w"], jnp.arange(n_layers)),
            )
            taps.deliver_scan(ys)
            return taps.site("logits", h)
        scan_sites = ("layers.input", "layers.output")

    order = [("embed", None)]
    for i in range(n_layers):
        order += [("layers.input", i), ("layers.output", i)]
    order += [("logits", None)]
    schedule = SiteSchedule(order=order, scan_sites=scan_sites,
                            n_layers=n_layers)
    return TracedModel(
        model_fn, params, schedule, name="tiny",
        default_mode="scan" if scan else "unrolled",
    )


@pytest.fixture
def tiny():
    return make_tiny_model()


@pytest.fixture
def tiny_scan():
    return make_tiny_model(scan=True)


@pytest.fixture
def x2x4():
    return jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
